"""AOT pipeline: lower every model preset's surface to HLO text artifacts.

This is the ONLY place python runs in the system; after `make artifacts`
the rust binary is self-contained.  Interchange is HLO **text**, not a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (what the published `xla` 0.1.6
crate links) rejects (`proto.id() <= INT_MAX`); the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (default ../artifacts):
    <preset>.<fn>.hlo.txt   for fn in init/step/grad/apply/eval/sq_dev/qsgd
    manifest.json           shapes + param counts the rust runtime needs

Usage:  cd python && python -m compile.aot --out ../artifacts [--presets a,b]
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_zoo


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_model(m: model_zoo.Model):
    """Returns {fn_name: (hlo_text, [arg_specs])}."""
    w = m.w_spec()
    x = m.x_spec()
    y = m.y_spec()
    f32 = jnp.float32
    i32 = jnp.int32
    scalar_f = jax.ShapeDtypeStruct((), f32)
    scalar_i = jax.ShapeDtypeStruct((), i32)

    entries = {
        "init": (m.init, [scalar_i]),
        "step": (m.step, [w, w, x, y, scalar_f]),
        "grad": (m.grad, [w, x, y]),
        "apply": (m.apply, [w, w, w, scalar_f]),
        "eval": (m.eval, [w, x, y]),
        "sq_dev": (m.sq_dev, [w, w]),
        "qsgd": (m.qsgd, [w, w]),
    }
    out = {}
    for name, (fn, specs) in entries.items():
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        out[name] = (text, specs)
        print(
            f"    {name:7s} {len(text)/1024:9.1f} KiB  {time.time()-t0:6.2f}s",
            file=sys.stderr,
        )
    return out


def build(out_dir: str, presets):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "hlo": "text", "models": {}}
    for pname in presets:
        m = model_zoo.get(pname)
        print(f"[aot] lowering {pname} (P={m.n_params})", file=sys.stderr)
        lowered = lower_model(m)
        files = {}
        fn_specs = {}
        for fn_name, (text, specs) in lowered.items():
            fname = f"{pname}.{fn_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            files[fn_name] = fname
            fn_specs[fn_name] = [_spec_json(s) for s in specs]
        entry = {
            "kind": m.kind,
            "param_count": m.n_params,
            "momentum": m.momentum,
            "qsgd_levels": m.qsgd_levels,
            "batch": m.cfg.batch,
            "x": _spec_json(m.x_spec()),
            "y": _spec_json(m.y_spec()),
            "files": files,
            "args": fn_specs,
        }
        if m.kind == "lm":
            entry["vocab"] = m.cfg.vocab
            entry["seq"] = m.cfg.seq
        else:
            entry["classes"] = m.cfg.classes
            entry["input_dim"] = m.x_spec().shape[1]
        manifest["models"][pname] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote manifest with {len(manifest['models'])} models", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--presets",
        default=",".join(model_zoo.PRESETS),
        help="comma-separated preset names",
    )
    args = ap.parse_args()
    build(args.out, [p for p in args.presets.split(",") if p])


if __name__ == "__main__":
    main()
