"""Fused momentum-SGD parameter update as a 1-D blocked Pallas kernel.

The paper's per-node local step (Algorithm 1/2, line 4) with momentum:

    m' = mu * m + g
    w' = w - lr * m'

On GPU frameworks this is two elementwise kernels (momentum buffer
update, then axpy); fusing them into one VMEM pass halves HBM traffic on
the biggest per-step tensor (the full parameter vector).  1-D tiles of
BLOCK elements: with three f32 inputs + two outputs resident, VMEM use is
5 * BLOCK * 4B = 160KiB per program at BLOCK=8192, far under the ~16MiB
budget, so the kernel is purely bandwidth-bound as intended.

lr is a traced scalar (the coordinator anneals it every step), passed as
a (1, 1) array; mu is compile-time static (fixed per run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _fused_update_kernel(lr_ref, w_ref, m_ref, g_ref, w_out_ref, m_out_ref, *, mu):
    lr = lr_ref[0, 0]
    m_new = mu * m_ref[...] + g_ref[...]
    m_out_ref[...] = m_new
    w_out_ref[...] = w_ref[...] - lr * m_new


@functools.partial(jax.jit, static_argnames=("mu", "block"))
def fused_momentum_update(w, m, g, lr, mu=0.9, block=BLOCK):
    """Returns (w', m').  w, m, g are flat f32[P]; lr is a scalar."""
    (p,) = w.shape
    assert m.shape == (p,) and g.shape == (p,)
    blk = min(block, p)
    pp = (p + blk - 1) // blk * blk
    pad = pp - p
    if pad:
        w = jnp.pad(w, (0, pad))
        m = jnp.pad(m, (0, pad))
        g = jnp.pad(g, (0, pad))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)

    grid = (pp // blk,)
    w_new, m_new = pl.pallas_call(
        functools.partial(_fused_update_kernel, mu=float(mu)),
        grid=grid,
        in_specs=[
            # lr broadcast to every program: constant index map.
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp,), jnp.float32),
            jax.ShapeDtypeStruct((pp,), jnp.float32),
        ],
        interpret=True,
    )(lr_arr, w, m, g)
    if pad:
        w_new, m_new = w_new[:p], m_new[:p]
    return w_new, m_new
