"""Blocked Pallas layernorm — the transformer's per-token normalization.

TPU mapping (DESIGN.md §2): one grid step normalizes a (block_rows, d)
tile held in VMEM; the feature dimension stays resident so mean/var are
single-pass row reductions (the CUDA version does this with a warp
shuffle tree; on TPU the VPU reduces lanes directly).  Forward *and*
backward run through Pallas kernels via a custom VJP, so the layernorm
sits on the AOT hot path in both directions — only the (cheap, batch-
reduction) parameter gradients fall back to jnp sums.

interpret=True everywhere: see matmul.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128
EPS = 1e-5


def _ln_fwd_kernel(x_ref, s_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * s_ref[...] + b_ref[...]


def _ln_bwd_kernel(x_ref, s_ref, g_ref, dx_ref):
    """dx for layernorm: recomputes mu/var from x (cheaper than saving
    them: one extra VPU pass vs. two more HBM streams)."""
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    s = s_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * inv
    gs = g * s
    m1 = jnp.mean(gs, axis=-1, keepdims=True)
    m2 = jnp.mean(gs * xhat, axis=-1, keepdims=True)
    dx_ref[...] = inv * (gs - m1 - xhat * m2)


def _pick_rows(n, pref):
    if n >= pref:
        return pref
    b = 1
    while b * 2 <= n:
        b *= 2
    return b


def _pad_rows(x, rows):
    pr = rows - x.shape[0]
    if pr == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, 0)))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def _ln_fwd(x, s, b, block_rows=DEFAULT_BLOCK_ROWS):
    n, d = x.shape
    br = _pick_rows(n, block_rows)
    np_ = (n + br - 1) // br * br
    x_p = _pad_rows(x.astype(jnp.float32), np_)
    out = pl.pallas_call(
        _ln_fwd_kernel,
        grid=(np_ // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), jnp.float32),
        interpret=True,
    )(x_p, s.astype(jnp.float32), b.astype(jnp.float32))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def _ln_bwd_dx(x, s, g, block_rows=DEFAULT_BLOCK_ROWS):
    n, d = x.shape
    br = _pick_rows(n, block_rows)
    np_ = (n + br - 1) // br * br
    x_p = _pad_rows(x.astype(jnp.float32), np_)
    g_p = _pad_rows(g.astype(jnp.float32), np_)
    dx = pl.pallas_call(
        _ln_bwd_kernel,
        grid=(np_ // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), jnp.float32),
        interpret=True,
    )(x_p, s.astype(jnp.float32), g_p)
    return dx[:n]


@jax.custom_vjp
def layernorm(x, s, b):
    """y = (x - mean) * rsqrt(var + eps) * s + b over the last axis.

    `x: [rows, d]`, `s/b: [d]`.  Differentiable; fwd and dx-bwd are
    Pallas kernels, parameter grads are jnp batch reductions.
    """
    return _ln_fwd(x, s, b)


def _layernorm_fwd(x, s, b):
    return _ln_fwd(x, s, b), (x, s)


def _layernorm_bwd(res, g):
    x, s = res
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xhat = (xf - mu) * jax.lax.rsqrt(var + EPS)
    gf = g.astype(jnp.float32)
    ds = jnp.sum(gf * xhat, axis=0)
    db = jnp.sum(gf, axis=0)
    dx = _ln_bwd_dx(x, s, g)
    return dx.astype(x.dtype), ds.astype(s.dtype), db.astype(s.dtype)


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
