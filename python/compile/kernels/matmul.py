"""Blocked Pallas matmul — the MXU-shaped compute hot-spot of the L2 models.

TPU mapping of the paper's GPU kernels (DESIGN.md §2): where the CUDA
implementation tiles for shared memory per threadblock, we express the
HBM↔VMEM schedule with a (M/bm, N/bn, K/bk) grid and BlockSpecs.  The
MXU wants 128×128 tiles; the K loop is the innermost grid dimension and
accumulates into the f32 output block (classic systolic-array feeding
pattern).

All pallas_call sites use interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the rust runtime runs.  Block-shape choices still encode the real-TPU
schedule; §Perf estimates VMEM/MXU numbers from them.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. For small problems we shrink to the problem size so the
# interpret-mode kernel does not waste work on padding.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


def _pick_block(dim, pref):
    """Largest power-of-two tile <= pref that keeps padding small."""
    if dim >= pref:
        return pref
    b = 1
    while b * 2 <= dim:
        b *= 2
    return b


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """C[M,N] = A[M,K] @ B[K,N] via the blocked Pallas kernel.

    Accepts arbitrary (M, K, N); pads up to tile multiples and slices the
    result back (padding contributes zeros to the accumulation).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    kp = (k + bk - 1) // bk * bk
    a_p = _pad_to(a.astype(jnp.float32), mp, kp)
    b_p = _pad_to(b.astype(jnp.float32), kp, np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


# Pallas kernels have no AD rule; give the matmul a custom VJP whose
# backward pass is *also* the Pallas kernel, so both fwd and bwd of every
# dense layer in the L2 models run through the blocked kernel.
@jax.custom_vjp
def matmul_ad(a, b):
    return matmul(a, b)


def _matmul_ad_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_ad_bwd(res, g):
    a, b = res
    da = matmul(g, b.T)
    db = matmul(a.T, g)
    return da.astype(a.dtype), db.astype(b.dtype)


matmul_ad.defvjp(_matmul_ad_fwd, _matmul_ad_bwd)


def linear(x, w, b=None):
    """Dense layer y = x @ w (+ b) routed through the Pallas matmul
    (differentiable: custom VJP above).

    The L2 models call this for every projection so the kernel sits on the
    AOT-compiled hot path — forward and backward.
    """
    y = matmul_ad(x, w)
    if b is not None:
        y = y + b
    return y
