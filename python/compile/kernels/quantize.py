"""QSGD stochastic quantize+dequantize as a blocked Pallas kernel.

The paper's comparator (§IV, QSGD with 8-bit levels).  The convergence-
relevant part of QSGD is the *information loss* of the quantizer; byte
accounting (4x compression at 8 bits, parameter-server routing) lives in
the rust `quant`/`netsim` modules.  This kernel applies

    x_hat = sign(x) * ||bucket||_2 * floor(|x|/||bucket||_2 * s + u) / s

bucket-by-bucket, with the caller supplying u ~ U[0,1) (randomness stays
outside the kernel so the AOT artifact is a pure function and the rust
side controls seeds).

Bucket == block: each grid program owns exactly one quantization bucket,
computes its 2-norm in VMEM and rounds in the same pass — one HBM read
of x and u, one write of x_hat.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BUCKET = 512


def _qsgd_kernel(x_ref, u_ref, o_ref, *, s):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x))
    scaled = jnp.where(norm > 0.0, jnp.abs(x) / norm * s, 0.0)
    level = jnp.floor(scaled + u)
    o_ref[...] = jnp.sign(x) * norm * level / s


@functools.partial(jax.jit, static_argnames=("num_levels", "bucket_size"))
def qsgd_quantize_dequant(x, u, num_levels=255, bucket_size=DEFAULT_BUCKET):
    """Quantize-dequantize flat f32[P] with s=num_levels per bucket."""
    (p,) = x.shape
    assert u.shape == (p,)
    bs = min(bucket_size, p)
    pp = (p + bs - 1) // bs * bs
    pad = pp - p
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))

    out = pl.pallas_call(
        functools.partial(_qsgd_kernel, s=float(num_levels)),
        grid=(pp // bs,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(x, u)
    return out[:p]
