"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the *definition of correctness* for the matching
Pallas kernel: pytest sweeps shapes/dtypes with hypothesis and asserts
allclose between kernel and oracle. Keep these boring and obviously
right — no tiling, no tricks.
"""

import jax.numpy as jnp


def matmul(a, b):
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def fused_momentum_update(w, m, g, lr, mu):
    """Momentum-SGD fused update (PyTorch convention, as the paper uses):

        m' = mu * m + g
        w' = w - lr * m'
    """
    m_new = mu * m + g
    w_new = w - lr * m_new
    return w_new, m_new


def sq_deviation(a, b):
    """||a - b||^2 as a scalar f32."""
    d = (a - b).astype(jnp.float32)
    return jnp.sum(d * d)


def layernorm(x, s, b, eps=1e-5):
    """y = (x - mean) * rsqrt(var + eps) * s + b over the last axis."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return (xf - mu) * (1.0 / jnp.sqrt(var + eps)) * s + b


def qsgd_quantize_dequant(x, u, num_levels, bucket_size):
    """QSGD (Alistarh et al. 2017) stochastic quantization, fused with
    dequantization (models the information loss of transmitting the
    quantized gradient; byte accounting lives in the rust `quant` module).

    Per bucket of `bucket_size` elements:
        norm  = ||x_bucket||_2
        level = floor(|x|/norm * s + u)   (u ~ U[0,1) supplied by caller)
        x_hat = sign(x) * norm * level / s
    Buckets with zero norm dequantize to zero.
    """
    s = float(num_levels)
    n = x.shape[0]
    assert n % bucket_size == 0, "caller pads to a bucket multiple"
    xb = x.reshape(-1, bucket_size).astype(jnp.float32)
    ub = u.reshape(-1, bucket_size).astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xb * xb, axis=1, keepdims=True))
    scaled = jnp.where(norm > 0.0, jnp.abs(xb) / norm * s, 0.0)
    level = jnp.floor(scaled + ub)
    xq = jnp.sign(xb) * norm * level / s
    return xq.reshape(n)
