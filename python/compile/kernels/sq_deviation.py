"""Blocked squared-deviation reduction — the S_k statistic of Algorithm 2.

After each synchronization the coordinator needs

    S_k = (1/n) * sum_i || w_bar - w_i ||^2

per node, i.e. a full-vector ||a - b||^2.  The GPU original is a grid
reduction with shared-memory trees; the TPU restatement is a 1-D grid
whose programs each reduce one VMEM-resident tile and accumulate into a
single (1, 1) output block (the output BlockSpec maps every program to
block (0, 0), so the accumulation is sequential over the grid — the
standard Pallas reduction idiom).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _sq_dev_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = a_ref[...].astype(jnp.float32) - b_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("block",))
def sq_deviation(a, b, block=BLOCK):
    """||a - b||^2 -> scalar f32, via the blocked Pallas reduction."""
    (p,) = a.shape
    assert b.shape == (p,)
    blk = min(block, p)
    pp = (p + blk - 1) // blk * blk
    pad = pp - p
    if pad:  # zero padding contributes 0 to the sum
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))

    out = pl.pallas_call(
        _sq_dev_kernel,
        grid=(pp // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(a, b)
    return out[0, 0]
