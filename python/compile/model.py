"""Layer-2: the model zoo, written as pure functions over a FLAT f32[P]
parameter vector.

The coordinator (rust L3) never sees parameter structure: a node's state
is (w: f32[P], m: f32[P]) and every model exposes the same AOT surface,
so periodic parameter averaging is elementwise vector math on the rust
side — exactly the algebra of the paper's Algorithms 1/2.

AOT surface per model preset (lowered by aot.py):

    init (seed: i32[])                          -> w0: f32[P]
    step (w, m, x, y, lr)                       -> (w', m', loss)   local SGD step
    grad (w, x, y)                              -> (g, loss)        for QSGD/FULLSGD grad exchange
    apply(w, m, g, lr)                          -> (w', m')         fused momentum update
    eval (w, x, y)                              -> (loss, acc)
    sq_dev(a: f32[P], b: f32[P])                -> f32[]            S_k statistic
    qsgd (g: f32[P], u: f32[P])                 -> f32[P]           quantize-dequant

Dense projections route through the Pallas blocked matmul (fwd + bwd via
its custom VJP); the update uses the fused Pallas kernel; sq_dev/qsgd are
the Pallas reduction/quantizer kernels. Python never runs at train time:
these lower once to artifacts/*.hlo.txt.

Models:
    mlp   — plain MLP classifier (presets straddle compute- vs comm-bound)
    cnn   — small conv net on synthetic CIFAR-like images
    txf   — decoder-only transformer char-LM (the end-to-end driver)
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import fused_update, quantize, sq_deviation
from .kernels.layernorm import layernorm as pallas_layernorm
from .kernels.matmul import linear

# --------------------------------------------------------------------------
# flat parameter plumbing
# --------------------------------------------------------------------------


def param_count(specs):
    n = 0
    for _, shape in specs:
        sz = 1
        for d in shape:
            sz *= d
        n += sz
    return n


def unflatten(w, specs):
    """Flat f32[P] -> dict name->array (static offsets; jit-friendly)."""
    out = {}
    off = 0
    for name, shape in specs:
        sz = 1
        for d in shape:
            sz *= d
        out[name] = w[off : off + sz].reshape(shape)
        off += sz
    return out


def flatten(tree, specs):
    """dict -> flat f32[P] in spec order."""
    return jnp.concatenate([tree[name].reshape(-1) for name, _ in specs])


def _init_dense(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if len(shape) == 4:  # HWIO conv
        fan_in = shape[0] * shape[1] * shape[2]
    s = scale if scale is not None else (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape) * s


# --------------------------------------------------------------------------
# model configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MlpConfig:
    input_dim: int = 256
    hidden: int = 128
    depth: int = 2  # number of hidden layers
    classes: int = 10
    batch: int = 32


@dataclass(frozen=True)
class CnnConfig:
    image: int = 16  # square side
    channels: int = 3
    widths: tuple = (8, 16)  # conv channel widths, pool/2 after each
    classes: int = 10
    batch: int = 32


@dataclass(frozen=True)
class TxfConfig:
    vocab: int = 96
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq: int = 64
    batch: int = 8
    ff_mult: int = 4


# --------------------------------------------------------------------------
# MLP classifier
# --------------------------------------------------------------------------


def mlp_specs(cfg: MlpConfig):
    specs = []
    dims = [cfg.input_dim] + [cfg.hidden] * cfg.depth + [cfg.classes]
    for i in range(len(dims) - 1):
        specs.append((f"w{i}", (dims[i], dims[i + 1])))
        specs.append((f"b{i}", (dims[i + 1],)))
    return specs


def mlp_logits(p, x, cfg: MlpConfig):
    h = x
    n_layers = cfg.depth + 1
    for i in range(n_layers):
        h = linear(h, p[f"w{i}"], p[f"b{i}"])
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def mlp_init_tree(key, cfg: MlpConfig):
    specs = mlp_specs(cfg)
    tree = {}
    for name, shape in specs:
        key, sub = jax.random.split(key)
        tree[name] = (
            _init_dense(sub, shape) if name.startswith("w") else jnp.zeros(shape)
        )
    return tree


# --------------------------------------------------------------------------
# small CNN
# --------------------------------------------------------------------------


def cnn_specs(cfg: CnnConfig):
    specs = []
    cin = cfg.channels
    side = cfg.image
    for i, w in enumerate(cfg.widths):
        specs.append((f"conv{i}", (3, 3, cin, w)))  # HWIO
        specs.append((f"cb{i}", (w,)))
        cin = w
        side //= 2
    flat = side * side * cin
    specs.append(("head_w", (flat, cfg.classes)))
    specs.append(("head_b", (cfg.classes,)))
    return specs


def cnn_logits(p, x, cfg: CnnConfig):
    b = x.shape[0]
    h = x.reshape(b, cfg.image, cfg.image, cfg.channels)
    for i in range(len(cfg.widths)):
        h = jax.lax.conv_general_dilated(
            h,
            p[f"conv{i}"],
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jax.nn.relu(h + p[f"cb{i}"])
        h = jax.lax.reduce_window(
            h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        ) / 4.0
    h = h.reshape(b, -1)
    return linear(h, p["head_w"], p["head_b"])


def cnn_init_tree(key, cfg: CnnConfig):
    tree = {}
    for name, shape in cnn_specs(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("conv") or name.endswith("_w"):
            tree[name] = _init_dense(sub, shape)
        else:
            tree[name] = jnp.zeros(shape)
    return tree


# --------------------------------------------------------------------------
# decoder-only transformer char-LM
# --------------------------------------------------------------------------


def txf_specs(cfg: TxfConfig):
    d, ff = cfg.d_model, cfg.ff_mult * cfg.d_model
    specs = [("tok_emb", (cfg.vocab, d)), ("pos_emb", (cfg.seq, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_s", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.qkv", (d, 3 * d)),
            (f"l{i}.proj", (d, d)),
            (f"l{i}.ln2_s", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.ff1", (d, ff)),
            (f"l{i}.ff1_b", (ff,)),
            (f"l{i}.ff2", (ff, d)),
            (f"l{i}.ff2_b", (d,)),
        ]
    specs += [("lnf_s", (d,)), ("lnf_b", (d,))]
    # output head tied to tok_emb (keeps P down; standard for small LMs)
    return specs


def _layernorm(x, s, b):
    """Layernorm over the last axis, routed through the Pallas kernel
    (fwd + dx-bwd run as blocked kernels; see kernels/layernorm.py)."""
    shape = x.shape
    y = pallas_layernorm(x.reshape(-1, shape[-1]), s, b)
    return y.reshape(shape)


def txf_logits(p, x, cfg: TxfConfig):
    b, s = x.shape
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    h = p["tok_emb"][x] + p["pos_emb"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        # --- attention
        hin = _layernorm(h, p[f"l{i}.ln1_s"], p[f"l{i}.ln1_b"])
        qkv = linear(hin.reshape(b * s, d), p[f"l{i}.qkv"]).reshape(b, s, 3, nh, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [b,s,nh,hd]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd**0.5)
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, d)
        h = h + linear(out, p[f"l{i}.proj"]).reshape(b, s, d)
        # --- mlp
        hin = _layernorm(h, p[f"l{i}.ln2_s"], p[f"l{i}.ln2_b"])
        ff = jax.nn.gelu(
            linear(hin.reshape(b * s, d), p[f"l{i}.ff1"], p[f"l{i}.ff1_b"])
        )
        h = h + linear(ff, p[f"l{i}.ff2"], p[f"l{i}.ff2_b"]).reshape(b, s, d)
    h = _layernorm(h, p["lnf_s"], p["lnf_b"])
    logits = linear(h.reshape(b * s, d), p["tok_emb"].T).reshape(b, s, cfg.vocab)
    return logits


def txf_init_tree(key, cfg: TxfConfig):
    tree = {}
    for name, shape in txf_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_s"):
            tree[name] = jnp.ones(shape)
        elif name.endswith("_b") or name.endswith(".ff1_b") or name.endswith(".ff2_b"):
            tree[name] = jnp.zeros(shape)
        elif "emb" in name:
            tree[name] = jax.random.normal(sub, shape) * 0.02
        else:
            tree[name] = _init_dense(sub, shape)
    return tree


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


# --------------------------------------------------------------------------
# Model: uniform AOT surface
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Model:
    """One AOT-able model preset. `kind` is "class" (x f32[B,Din], y i32[B])
    or "lm" (x i32[B,S], y i32[B,S])."""

    name: str
    kind: str
    cfg: object
    specs: list = field(hash=False)
    logits_fn: object = field(hash=False)
    init_fn: object = field(hash=False)
    momentum: float = 0.9
    qsgd_levels: int = 255

    @property
    def n_params(self):
        return param_count(self.specs)

    # ---- batch example shapes (for lowering + manifest)
    def x_spec(self):
        if self.kind == "class":
            c = self.cfg
            din = (
                c.input_dim
                if isinstance(c, MlpConfig)
                else c.image * c.image * c.channels
            )
            return jax.ShapeDtypeStruct((c.batch, din), jnp.float32)
        return jax.ShapeDtypeStruct((self.cfg.batch, self.cfg.seq), jnp.int32)

    def y_spec(self):
        if self.kind == "class":
            return jax.ShapeDtypeStruct((self.cfg.batch,), jnp.int32)
        return jax.ShapeDtypeStruct((self.cfg.batch, self.cfg.seq), jnp.int32)

    def w_spec(self):
        return jax.ShapeDtypeStruct((self.n_params,), jnp.float32)

    def scalar_spec(self, dtype=jnp.float32):
        return jax.ShapeDtypeStruct((), dtype)

    # ---- the AOT functions ------------------------------------------------
    def loss(self, w, x, y):
        p = unflatten(w, self.specs)
        return _xent(self.logits_fn(p, x, self.cfg), y)

    def init(self, seed):
        key = jax.random.PRNGKey(seed)
        return flatten(self.init_fn(key, self.cfg), self.specs)

    def grad(self, w, x, y):
        loss, g = jax.value_and_grad(self.loss)(w, x, y)
        return g, loss

    def apply(self, w, m, g, lr):
        return fused_update.fused_momentum_update(w, m, g, lr, mu=self.momentum)

    def step(self, w, m, x, y, lr):
        g, loss = self.grad(w, x, y)
        w2, m2 = self.apply(w, m, g, lr)
        return w2, m2, loss

    def eval(self, w, x, y):
        p = unflatten(w, self.specs)
        logits = self.logits_fn(p, x, self.cfg)
        return _xent(logits, y), _accuracy(logits, y)

    def sq_dev(self, a, b):
        return sq_deviation.sq_deviation(a, b)

    def qsgd(self, g, u):
        return quantize.qsgd_quantize_dequant(g, u, num_levels=self.qsgd_levels)


def _mk_mlp(name, **kw):
    cfg = MlpConfig(**kw)
    return Model(name, "class", cfg, mlp_specs(cfg), mlp_logits, mlp_init_tree)


def _mk_cnn(name, **kw):
    cfg = CnnConfig(**kw)
    return Model(name, "class", cfg, cnn_specs(cfg), cnn_logits, cnn_init_tree)


def _mk_txf(name, **kw):
    cfg = TxfConfig(**kw)
    return Model(name, "lm", cfg, txf_specs(cfg), txf_logits, txf_init_tree)


# The preset zoo. `mlp_small`/`cnn_small` are compute-bound stand-ins
# (GoogLeNet role); `mlp_wide` is param-heavy / comm-bound (VGG16 role);
# `txf_*` drive the end-to-end LM example. See DESIGN.md §1.
PRESETS = {
    "mlp_small": _mk_mlp("mlp_small", input_dim=256, hidden=128, depth=2, batch=32),
    "mlp_wide": _mk_mlp("mlp_wide", input_dim=512, hidden=1024, depth=2, batch=32),
    "cnn_small": _mk_cnn("cnn_small", image=16, channels=3, widths=(8, 16), batch=32),
    "txf_tiny": _mk_txf(
        "txf_tiny", vocab=96, d_model=64, n_layers=2, n_heads=4, seq=64, batch=8
    ),
    "txf_small": _mk_txf(
        "txf_small", vocab=96, d_model=256, n_layers=4, n_heads=8, seq=128, batch=8
    ),
}


def get(name: str) -> Model:
    if name not in PRESETS:
        raise KeyError(f"unknown model preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]
