"""AOT pipeline invariants: HLO text is parseable-shaped, manifest complete,
and the lowered computation is numerically identical to the python fn."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as zoo

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

FNS = ["init", "step", "grad", "apply", "eval", "sq_dev", "qsgd"]


def test_to_hlo_text_shape():
    m = zoo.get("mlp_small")
    lowered = jax.jit(m.sq_dev).lower(m.w_spec(), m.w_spec())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["hlo"] == "text"
    for name, entry in man["models"].items():
        m = zoo.get(name)
        assert entry["param_count"] == m.n_params
        for fn in FNS:
            assert fn in entry["files"], (name, fn)
            path = os.path.join(ART, entry["files"][fn])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), path
        assert entry["x"]["shape"] == list(m.x_spec().shape)
        assert entry["y"]["shape"] == list(m.y_spec().shape)
        assert entry["args"]["step"][0]["shape"] == [m.n_params]


def test_lowered_matches_eager():
    """Executing the lowered (AOT) computation gives the same numbers as
    calling the python function — the artifact is faithful."""
    m = zoo.get("mlp_small")
    w = m.init(0)
    mom = jnp.zeros_like(w)
    kx, ky = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.normal(kx, m.x_spec().shape)
    y = jax.random.randint(ky, m.y_spec().shape, 0, m.cfg.classes)
    lowered = jax.jit(m.step).lower(
        m.w_spec(), m.w_spec(), m.x_spec(), m.y_spec(),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    compiled = lowered.compile()
    w2c, m2c, lc = compiled(w, mom, x, y, jnp.float32(0.1))
    w2e, m2e, le = m.step(w, mom, x, y, 0.1)
    np.testing.assert_allclose(np.asarray(w2c), np.asarray(w2e), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(m2c), np.asarray(m2e), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(lc), float(le), rtol=1e-6)
