"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiples, the padding
paths) and dtypes; assert_allclose against compile.kernels.ref.  This is
the CORE correctness signal for the compute layer: the same kernels are
baked into the AOT artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_update, layernorm, matmul, quantize, ref, sq_deviation

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 140),
    k=st.integers(1, 140),
    n=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k))
    b = _rand(k2, (k, n))
    got = matmul.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes_accumulate_f32(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = _rand(k1, (64, 96), dtype)
    b = _rand(k2, (96, 32), dtype)
    got = matmul.matmul(a, b)
    assert got.dtype == jnp.float32
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize(
    "shape",
    [(1, 1, 1), (128, 128, 128), (256, 64, 128), (129, 130, 131), (3, 300, 7)],
)
def test_matmul_tile_boundaries(shape):
    m, k, n = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a = _rand(k1, (m, k))
    b = _rand(k2, (k, n))
    np.testing.assert_allclose(
        matmul.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
    )


def test_linear_bias():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x, w, b = _rand(k1, (17, 33)), _rand(k2, (33, 9)), _rand(k3, (9,))
    np.testing.assert_allclose(
        matmul.linear(x, w, b), ref.matmul(x, w) + b, rtol=1e-4, atol=1e-4
    )


# --------------------------------------------------------- fused update


@settings(**SETTINGS)
@given(
    p=st.integers(1, 40000),
    lr=st.floats(1e-4, 1.0),
    mu=st.sampled_from([0.0, 0.5, 0.9, 0.99]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_update_matches_ref(p, lr, mu, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w, m, g = _rand(k1, (p,)), _rand(k2, (p,)), _rand(k3, (p,))
    wn, mn = fused_update.fused_momentum_update(w, m, g, lr, mu=mu)
    wr, mr = ref.fused_momentum_update(w, m, g, lr, mu)
    np.testing.assert_allclose(mn, mr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(wn, wr, rtol=1e-5, atol=1e-6)


def test_fused_update_zero_momentum_is_plain_sgd():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    w, g = _rand(k1, (1000,)), _rand(k2, (1000,))
    m = jnp.zeros(1000)
    wn, mn = fused_update.fused_momentum_update(w, m, g, 0.1, mu=0.0)
    np.testing.assert_allclose(wn, w - 0.1 * g, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(mn, g, rtol=1e-6)


def test_fused_update_block_boundary_exact():
    # p exactly at / around the block size exercises both padded and
    # unpadded paths.
    for p in [fused_update.BLOCK - 1, fused_update.BLOCK, fused_update.BLOCK + 1]:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(p), 3)
        w, m, g = _rand(k1, (p,)), _rand(k2, (p,)), _rand(k3, (p,))
        wn, mn = fused_update.fused_momentum_update(w, m, g, 0.05, mu=0.9)
        wr, mr = ref.fused_momentum_update(w, m, g, 0.05, 0.9)
        np.testing.assert_allclose(wn, wr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mn, mr, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- sq deviation


@settings(**SETTINGS)
@given(p=st.integers(1, 50000), seed=st.integers(0, 2**31 - 1))
def test_sq_deviation_matches_ref(p, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (p,)), _rand(k2, (p,))
    got = sq_deviation.sq_deviation(a, b)
    want = ref.sq_deviation(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_sq_deviation_identical_is_zero():
    a = _rand(jax.random.PRNGKey(0), (12345,))
    assert float(sq_deviation.sq_deviation(a, a)) == 0.0


def test_sq_deviation_known_value():
    a = jnp.ones(100)
    b = jnp.zeros(100)
    np.testing.assert_allclose(float(sq_deviation.sq_deviation(a, b)), 100.0)


# ------------------------------------------------------------ layernorm


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    d=st.integers(2, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (rows, d), scale=3.0)
    s = 1.0 + 0.1 * _rand(k2, (d,))
    b = 0.1 * _rand(k3, (d,))
    got = layernorm.layernorm(x, s, b)
    want = ref.layernorm(x, s, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_layernorm_output_is_normalized():
    x = _rand(jax.random.PRNGKey(2), (64, 128), scale=10.0)
    y = layernorm.layernorm(x, jnp.ones(128), jnp.zeros(128))
    np.testing.assert_allclose(jnp.mean(y, axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(y, axis=-1), 1.0, atol=1e-3)


def test_layernorm_grad_matches_jnp_autodiff():
    """The custom VJP (Pallas bwd kernel) must agree with jax autodiff of
    the pure-jnp oracle — for dx, ds, and db."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(9), 4)
    x = _rand(k1, (37, 48), scale=2.0)
    s = 1.0 + 0.1 * _rand(k2, (48,))
    b = 0.1 * _rand(k3, (48,))
    ct = _rand(k4, (37, 48))

    def loss_kernel(x, s, b):
        return jnp.sum(layernorm.layernorm(x, s, b) * ct)

    def loss_ref(x, s, b):
        return jnp.sum(ref.layernorm(x, s, b) * ct)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, s, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, s, b)
    for got, want, name in zip(gk, gr, ["dx", "ds", "db"]):
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4, err_msg=name)


def test_layernorm_block_boundaries():
    for rows in [
        layernorm.DEFAULT_BLOCK_ROWS - 1,
        layernorm.DEFAULT_BLOCK_ROWS,
        layernorm.DEFAULT_BLOCK_ROWS + 1,
    ]:
        x = _rand(jax.random.PRNGKey(rows), (rows, 32))
        got = layernorm.layernorm(x, jnp.ones(32), jnp.zeros(32))
        want = ref.layernorm(x, jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- qsgd


@settings(**SETTINGS)
@given(
    p=st.integers(1, 8192),
    levels=st.sampled_from([3, 15, 255]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qsgd_matches_ref_on_bucket_multiples(p, levels, seed):
    bs = quantize.DEFAULT_BUCKET
    p = max(1, p // bs * bs) if p >= bs else p  # kernel shrinks bucket to p
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (p,))
    u = jax.random.uniform(k2, (p,))
    got = quantize.qsgd_quantize_dequant(x, u, levels, bs)
    want = ref.qsgd_quantize_dequant(x, u, levels, min(bs, p))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_qsgd_unbiased_in_expectation():
    # E_u[Q(x)] = x for the stochastic rounding scheme: average over many
    # uniforms converges to x.
    x = _rand(jax.random.PRNGKey(0), (512,))
    acc = jnp.zeros_like(x)
    trials = 200
    for i in range(trials):
        u = jax.random.uniform(jax.random.PRNGKey(1000 + i), (512,))
        acc = acc + quantize.qsgd_quantize_dequant(x, u, 255, 512)
    # rounding step = ||x||/s ~= 0.089 here; mean-of-200 std ~= 0.003
    np.testing.assert_allclose(acc / trials, x, atol=0.02)


def test_qsgd_error_shrinks_with_levels():
    x = _rand(jax.random.PRNGKey(5), (2048,))
    u = jax.random.uniform(jax.random.PRNGKey(6), (2048,))
    errs = []
    for s in [3, 15, 255]:
        q = quantize.qsgd_quantize_dequant(x, u, s, 512)
        errs.append(float(jnp.sum((q - x) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_qsgd_zero_vector_stays_zero():
    x = jnp.zeros(1024)
    u = jax.random.uniform(jax.random.PRNGKey(0), (1024,))
    q = quantize.qsgd_quantize_dequant(x, u, 255, 512)
    np.testing.assert_allclose(q, x)
