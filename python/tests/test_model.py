"""L2 invariants: flat-param plumbing, shapes, training signal, AOT surface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as zoo
from compile.model import MlpConfig, flatten, mlp_specs, param_count, unflatten

ALL_PRESETS = sorted(zoo.PRESETS)


def _batch(m, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    if m.kind == "class":
        x = jax.random.normal(kx, m.x_spec().shape)
        y = jax.random.randint(ky, m.y_spec().shape, 0, m.cfg.classes)
    else:
        x = jax.random.randint(kx, m.x_spec().shape, 0, m.cfg.vocab)
        y = jax.random.randint(ky, m.y_spec().shape, 0, m.cfg.vocab)
    return x, y


# ------------------------------------------------------------- flattening


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(2, 64),
    h=st.integers(2, 64),
    depth=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_flatten_unflatten_roundtrip(d, h, depth, seed):
    cfg = MlpConfig(input_dim=d, hidden=h, depth=depth)
    specs = mlp_specs(cfg)
    w = jax.random.normal(jax.random.PRNGKey(seed), (param_count(specs),))
    tree = unflatten(w, specs)
    w2 = flatten(tree, specs)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))


def test_param_count_matches_manual():
    cfg = MlpConfig(input_dim=10, hidden=4, depth=1, classes=3)
    # 10*4 + 4 + 4*3 + 3
    assert param_count(mlp_specs(cfg)) == 59


# ----------------------------------------------------------- per-preset


@pytest.mark.parametrize("name", ALL_PRESETS)
def test_init_shape_and_determinism(name):
    m = zoo.get(name)
    w0 = m.init(7)
    w1 = m.init(7)
    w2 = m.init(8)
    assert w0.shape == (m.n_params,)
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
    assert not np.allclose(np.asarray(w0), np.asarray(w2))
    assert np.all(np.isfinite(np.asarray(w0)))


@pytest.mark.parametrize("name", ALL_PRESETS)
def test_step_decreases_loss_on_fixed_batch(name):
    m = zoo.get(name)
    w = m.init(0)
    mom = jnp.zeros_like(w)
    x, y = _batch(m)
    step = jax.jit(m.step)
    w1, mom1, loss0 = step(w, mom, x, y, 0.05)
    loss_prev = loss0
    for _ in range(8):
        w1, mom1, loss_prev = step(w1, mom1, x, y, 0.05)
    assert float(loss_prev) < float(loss0)
    assert np.all(np.isfinite(np.asarray(w1)))


@pytest.mark.parametrize("name", ALL_PRESETS)
def test_step_equals_grad_plus_apply(name):
    """The fused `step` artifact must equal the two-phase grad+apply path
    (what the QSGD/FULLSGD coordinator modes use)."""
    m = zoo.get(name)
    w = m.init(3)
    mom = jax.random.normal(jax.random.PRNGKey(4), w.shape) * 0.01
    x, y = _batch(m, seed=5)
    w_s, m_s, loss_s = jax.jit(m.step)(w, mom, x, y, 0.1)
    g, loss_g = jax.jit(m.grad)(w, x, y)
    w_a, m_a = jax.jit(m.apply)(w, mom, g, 0.1)
    np.testing.assert_allclose(float(loss_s), float(loss_g), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_a), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(m_s), np.asarray(m_a), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("name", ALL_PRESETS)
def test_eval_matches_loss(name):
    m = zoo.get(name)
    w = m.init(1)
    x, y = _batch(m, seed=2)
    loss_e, acc = jax.jit(m.eval)(w, x, y)
    _, loss_g = jax.jit(m.grad)(w, x, y)
    np.testing.assert_allclose(float(loss_e), float(loss_g), rtol=1e-5)
    assert 0.0 <= float(acc) <= 1.0


def test_grad_matches_finite_difference():
    m = zoo.get("mlp_small")
    w = m.init(0) * 0.5
    x, y = _batch(m, seed=1)
    g, _ = jax.jit(m.grad)(w, x, y)
    # probe a few random coordinates
    rng = np.random.default_rng(0)
    idx = rng.integers(0, m.n_params, size=6)
    eps = 1e-3
    w_np = np.asarray(w, dtype=np.float64)
    for i in idx:
        wp, wm = w_np.copy(), w_np.copy()
        wp[i] += eps
        wm[i] -= eps
        lp = float(m.loss(jnp.asarray(wp, jnp.float32), x, y))
        lm = float(m.loss(jnp.asarray(wm, jnp.float32), x, y))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - float(g[i])) < 5e-2 * max(1.0, abs(fd)), (i, fd, float(g[i]))


def test_momentum_is_local_state():
    """Averaging w but keeping m local (the paper's scheme) must be
    expressible: apply with explicitly averaged w, untouched m."""
    m = zoo.get("mlp_small")
    w_a, w_b = m.init(0), m.init(1)
    mom = jnp.ones(m.n_params) * 0.1
    w_bar = (w_a + w_b) / 2
    g = jnp.zeros(m.n_params)
    w2, m2 = jax.jit(m.apply)(w_bar, mom, g, 0.1)
    # zero grad: w unchanged except momentum decay effect
    np.testing.assert_allclose(
        np.asarray(w2), np.asarray(w_bar - 0.1 * 0.9 * mom), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(m2), np.asarray(0.9 * mom), rtol=1e-6)


def test_sq_dev_surface():
    m = zoo.get("mlp_small")
    a = m.init(0)
    b = m.init(1)
    got = float(jax.jit(m.sq_dev)(a, b))
    want = float(jnp.sum((a - b) ** 2))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_txf_causality():
    """Future tokens must not influence past logits."""
    m = zoo.get("txf_tiny")
    w = m.init(0)
    x, _ = _batch(m, seed=3)
    p = zoo.unflatten(w, m.specs)
    logits = zoo.txf_logits(p, x, m.cfg)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % m.cfg.vocab)
    logits2 = zoo.txf_logits(p, x2, m.cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits[:, -1]), np.asarray(logits2[:, -1]))
