//! Campaign-scheduler benchmark: the strategy × collective quartet sweep
//! at quick scale, executed serially and with two concurrent runs.
//!
//! Emits a machine-readable summary line (`BENCH_CAMPAIGN_JSON {...}`)
//! *and* writes it to `BENCH_campaign.json`, so the scheduler's
//! throughput (runs/sec) and the sweep's total modeled communication
//! accumulate as a perf trajectory across commits.  The headline
//! numbers: runs/sec at each parallelism level and the parallel
//! speedup (bounded-parallel scheduling overlaps whole coordinator
//! clusters).

use adpsgd::collective::Algo;
use adpsgd::config::{ExperimentConfig, LrSchedule, StrategySpec};
use adpsgd::experiment::{Campaign, CampaignReport};
use adpsgd::period::Strategy;
use adpsgd::util::json::Json;

fn tiny_base(iters: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "bench_campaign".into();
    cfg.nodes = 4;
    cfg.iters = iters;
    cfg.batch_per_node = 16;
    cfg.eval_every = iters / 4;
    cfg.workload.input_dim = 48;
    cfg.workload.hidden = 24;
    cfg.workload.eval_batches = 4;
    cfg.optim.schedule = LrSchedule::Const;
    cfg.optim.lr0 = 0.05;
    cfg.sync.warmup_iters = 4;
    cfg.sync.p_init = 2;
    cfg.sync.period = 4;
    cfg
}

fn quartet(base: &ExperimentConfig, parallelism: usize) -> Campaign {
    Campaign::builder("bench", base.clone())
        .strategy("full", StrategySpec::Full)
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .strategy("qsgd", base.sync.spec_of(Strategy::Qsgd))
        .collectives(&[Algo::Ring, Algo::Flat])
        .parallelism(parallelism)
        .build()
        .expect("bench campaign builds")
}

fn report_line(tag: &str, r: &CampaignReport) {
    println!(
        "campaign/{tag:<24} {} runs in {:>8.2?} ({:.2} runs/sec)",
        r.runs.len(),
        std::time::Duration::from_secs_f64(r.wall_secs),
        r.runs_per_sec()
    );
}

fn main() {
    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    let iters = if fast { 80 } else { 240 };
    let base = tiny_base(iters);
    println!("\n== bench group: campaign scheduler (quartet × {{ring,flat}}, {iters} iters) ==");

    let serial = quartet(&base, 1).run().expect("serial campaign");
    report_line("serial_p1", &serial);

    let parallel = quartet(&base, 2).run().expect("parallel campaign");
    report_line("parallel_p2", &parallel);

    // determinism across scheduling levels is part of the contract
    for (a, b) in serial.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            a.report.final_train_loss, b.report.final_train_loss,
            "{}: parallel scheduling changed results",
            a.label
        );
    }

    let speedup = serial.wall_secs / parallel.wall_secs.max(1e-12);
    println!("    -> parallel speedup {speedup:.2}x; total modeled comm {:.3}s", serial.total_modeled_comm_secs());

    let summary = Json::obj(vec![
        ("bench", Json::str("campaign_scheduler")),
        ("iters", Json::num(iters as f64)),
        ("runs", Json::num(serial.runs.len() as f64)),
        ("wall_secs_p1", Json::num(serial.wall_secs)),
        ("wall_secs_p2", Json::num(parallel.wall_secs)),
        ("runs_per_sec_p1", Json::num(serial.runs_per_sec())),
        ("runs_per_sec_p2", Json::num(parallel.runs_per_sec())),
        ("parallel_speedup", Json::num(speedup)),
        ("total_modeled_comm_secs", Json::num(serial.total_modeled_comm_secs())),
        ("total_wire_bytes", Json::num(serial.total_wire_bytes() as f64)),
    ]);
    let line = summary.to_string_compact();
    println!("BENCH_CAMPAIGN_JSON {line}");
    if let Err(e) = std::fs::write("BENCH_campaign.json", &line) {
        eprintln!("warning: could not write BENCH_campaign.json: {e}");
    } else {
        println!("wrote BENCH_campaign.json");
    }
}
