//! Collective benchmarks: the in-process ring allreduce that implements
//! the paper's parameter averaging, across node counts and payload sizes
//! (paper geometry: 16 nodes, 6.8M-138M f32 parameters).

use adpsgd::collective::Comm;
use adpsgd::util::bench::Runner;
use adpsgd::util::rng::Rng;
use std::sync::Arc;

/// Run `rounds` allreduces over `n` worker threads, timing rank 0's view.
fn allreduce_secs(n: usize, len: usize, rounds: usize) -> f64 {
    let comm = Arc::new(Comm::new(n, len));
    let elapsed = Arc::new(std::sync::Mutex::new(0.0f64));
    std::thread::scope(|scope| {
        for rank in 0..n {
            let comm = Arc::clone(&comm);
            let elapsed = Arc::clone(&elapsed);
            scope.spawn(move || {
                let mut buf = vec![0.0f32; len];
                Rng::new(rank as u64, 7).fill_normal(&mut buf, 1.0);
                comm.barrier();
                let t = std::time::Instant::now();
                for _ in 0..rounds {
                    comm.allreduce_mean(rank, &mut buf);
                }
                if rank == 0 {
                    *elapsed.lock().unwrap() = t.elapsed().as_secs_f64();
                }
            });
        }
    });
    let v = *elapsed.lock().unwrap();
    v
}

fn main() {
    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    let rounds = if fast { 3 } else { 20 };
    println!("\n== bench group: collective (custom timing; {rounds} rounds each) ==");

    for &n in &[2usize, 4, 8, 16] {
        for &len in &[64 * 1024usize, 1 << 20, 6_800_000] {
            let secs = allreduce_secs(n, len, rounds);
            let per = secs / rounds as f64;
            let gbps = (len * 4 * n) as f64 / per / 1e9;
            println!(
                "collective/allreduce_mean/n{n}/{:>4}k   {:>9.3} ms/op   {:>7.2} GB/s aggregate",
                len >> 10,
                per * 1e3,
                gbps
            );
        }
    }

    // scalar allreduce (the S_k exchange) — latency-bound: fixed-round
    // all-rank timing (a Runner-style calibrated loop would deadlock the
    // barrier, so this uses the same scheme as the vector benches)
    let srounds = if fast { 200 } else { 5_000 };
    for &n in &[2usize, 8, 16] {
        let comm = Arc::new(Comm::new(n, 1));
        let elapsed = Arc::new(std::sync::Mutex::new(0.0f64));
        std::thread::scope(|scope| {
            for rank in 0..n {
                let comm = Arc::clone(&comm);
                let elapsed = Arc::clone(&elapsed);
                scope.spawn(move || {
                    comm.barrier();
                    let t = std::time::Instant::now();
                    for i in 0..srounds {
                        comm.allreduce_scalar_sum(rank, (rank + i) as f64);
                    }
                    if rank == 0 {
                        *elapsed.lock().unwrap() = t.elapsed().as_secs_f64();
                    }
                });
            }
        });
        let per = *elapsed.lock().unwrap() / srounds as f64;
        println!("collective/scalar_allreduce/n{n:<2}          {:>9.3} µs/op", per * 1e6);
    }

    // single-rank fast path through the Runner harness (no barriers)
    let mut r = Runner::from_env("collective");
    let solo = Comm::new(1, 1 << 20);
    let mut buf = vec![1.0f32; 1 << 20];
    r.bench("allreduce_mean/n1-noop", move || {
        solo.allreduce_mean(0, &mut buf);
        buf[0]
    });
    r.finish();
}
