//! Collective benchmarks: flat (leader-serialized) vs ring
//! (chunked-parallel) allreduce across node counts and payload sizes
//! (paper geometry: 16 nodes, 6.8M–138M f32 parameters).
//!
//! Emits a machine-readable JSON summary line (`BENCH_COLLECTIVE_JSON
//! {...}`) so the bench trajectory can be tracked across commits.  The
//! headline number is the measured ring-over-flat speedup: at large
//! `n_params` and node counts ring's per-rank chunk reduction
//! parallelizes the work flat serializes on the leader.

use adpsgd::collective::{build, Algo, Collective};
use adpsgd::util::bench::Runner;
use adpsgd::util::json::Json;
use adpsgd::util::rng::Rng;
use std::sync::Arc;

/// Run `rounds` allreduces over `n` worker threads, timing rank 0's view.
fn allreduce_secs(comm: &Arc<dyn Collective>, n: usize, len: usize, rounds: usize) -> f64 {
    let elapsed = Arc::new(std::sync::Mutex::new(0.0f64));
    std::thread::scope(|scope| {
        for rank in 0..n {
            let comm = Arc::clone(comm);
            let elapsed = Arc::clone(&elapsed);
            scope.spawn(move || {
                let mut buf = vec![0.0f32; len];
                Rng::new(rank as u64, 7).fill_normal(&mut buf, 1.0);
                let _ = comm.barrier();
                let t = std::time::Instant::now();
                for _ in 0..rounds {
                    let _ = comm.allreduce_mean(rank, &mut buf);
                }
                if rank == 0 {
                    *elapsed.lock().unwrap() = t.elapsed().as_secs_f64();
                }
            });
        }
    });
    let v = *elapsed.lock().unwrap();
    v
}

fn main() {
    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    println!("\n== bench group: collective (custom timing; flat vs ring) ==");

    let mut rows = Vec::new();
    for &n in &[2usize, 8, 16] {
        for &len in &[10_000usize, 1_000_000, 10_000_000] {
            if fast && len > 1_000_000 {
                continue; // CI smoke: skip the ~GB allocations
            }
            let rounds = match (fast, len) {
                (true, _) => 2,
                (false, 10_000_000) => 3,
                (false, _) => 10,
            };
            let mut per = std::collections::BTreeMap::new();
            for algo in [Algo::Flat, Algo::Ring] {
                let comm = build(algo, n, len);
                let secs = allreduce_secs(&comm, n, len, rounds) / rounds as f64;
                per.insert(algo.to_string(), secs);
            }
            let flat = per["flat"];
            let ring = per["ring"];
            let speedup = flat / ring;
            let gbps = (len * 4 * n) as f64 / ring / 1e9;
            println!(
                "collective/allreduce_mean/n{n:<2}/{:>8} params   flat {:>9.3} ms   ring {:>9.3} ms   ring speedup {:>5.2}x   {:>7.2} GB/s agg",
                len,
                flat * 1e3,
                ring * 1e3,
                speedup,
                gbps
            );
            rows.push(Json::obj(vec![
                ("nodes", Json::num(n as f64)),
                ("n_params", Json::num(len as f64)),
                ("flat_secs_per_op", Json::num(flat)),
                ("ring_secs_per_op", Json::num(ring)),
                ("ring_speedup", Json::num(speedup)),
                ("agg_gbps_ring", Json::num(gbps)),
            ]));
        }
    }

    // scalar allreduce (the S_k exchange) — latency-bound: fixed-round
    // all-rank timing (a Runner-style calibrated loop would deadlock the
    // barrier, so this uses the same scheme as the vector benches)
    let srounds = if fast { 200 } else { 5_000 };
    for &n in &[2usize, 8, 16] {
        let comm = build(Algo::Ring, n, 1);
        let elapsed = Arc::new(std::sync::Mutex::new(0.0f64));
        std::thread::scope(|scope| {
            for rank in 0..n {
                let comm = Arc::clone(&comm);
                let elapsed = Arc::clone(&elapsed);
                scope.spawn(move || {
                    let _ = comm.barrier();
                    let t = std::time::Instant::now();
                    for i in 0..srounds {
                        let _ = comm.allreduce_scalar_sum(rank, (rank + i) as f64);
                    }
                    if rank == 0 {
                        *elapsed.lock().unwrap() = t.elapsed().as_secs_f64();
                    }
                });
            }
        });
        let per = *elapsed.lock().unwrap() / srounds as f64;
        println!("collective/scalar_allreduce/n{n:<2}          {:>9.3} µs/op", per * 1e6);
        rows.push(Json::obj(vec![
            ("nodes", Json::num(n as f64)),
            ("n_params", Json::num(1.0)),
            ("scalar_secs_per_op", Json::num(per)),
        ]));
    }

    // single-rank fast path through the Runner harness (no barriers)
    let mut r = Runner::from_env("collective");
    let solo = build(Algo::Ring, 1, 1 << 20);
    let mut buf = vec![1.0f32; 1 << 20];
    r.bench("allreduce_mean/n1-noop", move || {
        let _ = solo.allreduce_mean(0, &mut buf);
        buf[0]
    });
    r.finish();

    let summary = Json::obj(vec![
        ("bench", Json::str("collective")),
        ("fast", Json::Bool(fast)),
        ("rows", Json::Arr(rows)),
    ]);
    println!("BENCH_COLLECTIVE_JSON {}", summary.to_string_compact());
}
