//! Dispatch-layer benchmark: scheduler throughput across job counts,
//! run-cache hit economics (including warm-probe throughput, now that
//! slots probe the cache in parallel), the subprocess transport
//! overhead, and pool reuse vs respawn-per-campaign.
//!
//! Emits a machine-readable summary line (`BENCH_DISPATCH_JSON {...}`)
//! *and* writes it to `BENCH_dispatch.json`, so the dispatcher's
//! trajectory accumulates across commits next to `BENCH_campaign.json`.
//! Headline numbers: runs/sec at jobs ∈ {1, 2, 4, 8} on an 8-run
//! campaign, the cache hit rate, cold/warm wall ratio and warm-probe
//! runs/sec, the per-run overhead of subprocess dispatch vs in-process
//! threads, the per-campaign overhead of respawning a worker pool
//! instead of reusing the shared one, the loopback `adpsgd agent`
//! columns (remote runs/sec and the per-run TCP-fabric overhead vs
//! local threads), and the fleet columns (announce-to-membership
//! latency against a loopback registry, and the blob bytes staged per
//! warm-start run — content addressing amortizes one snapshot across
//! every run that references it), plus the per-run cost of the event
//! journal and of the proto-v6 worker event stream (neither of which
//! may ever change the stable summary).

use adpsgd::collective::Algo;
use adpsgd::config::{ExperimentConfig, LrSchedule, StrategySpec};
use adpsgd::dispatch::{Agent, AgentConfig, DispatchOptions, Dispatcher, WorkerKind, WorkerPool};
use adpsgd::experiment::Campaign;
use adpsgd::period::Strategy;
use adpsgd::util::json::Json;
use std::sync::Arc;

fn tiny_base(iters: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "bench_dispatch".into();
    cfg.nodes = 2;
    cfg.iters = iters;
    cfg.batch_per_node = 16;
    cfg.eval_every = iters / 2;
    cfg.workload.input_dim = 48;
    cfg.workload.hidden = 24;
    cfg.workload.eval_batches = 4;
    cfg.optim.schedule = LrSchedule::Const;
    cfg.optim.lr0 = 0.05;
    cfg.sync.warmup_iters = 4;
    cfg.sync.p_init = 2;
    cfg.sync.period = 4;
    cfg
}

/// 8 runs: the paper's quartet × both collectives.
fn eight(base: &ExperimentConfig) -> Campaign {
    Campaign::builder("bench", base.clone())
        .strategy("full", StrategySpec::Full)
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .strategy("qsgd", base.sync.spec_of(Strategy::Qsgd))
        .collectives(&[Algo::Ring, Algo::Flat])
        .build()
        .expect("bench campaign builds")
}

fn opts(jobs: usize) -> DispatchOptions {
    DispatchOptions { jobs: Some(jobs), cache_dir: None, ..DispatchOptions::default() }
}

fn main() {
    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    let iters = if fast { 80 } else { 240 };
    let base = tiny_base(iters);
    println!("\n== bench group: dispatch (8-run campaign, {iters} iters/run) ==");

    // -- scheduler throughput across job counts ---------------------------
    let mut pairs: Vec<(&str, Json)> = vec![
        ("bench", Json::str("dispatch")),
        ("iters", Json::num(iters as f64)),
        ("runs", Json::num(8.0)),
    ];
    let mut wall_j1 = 0.0;
    for jobs in [1usize, 2, 4, 8] {
        let report = eight(&base).execute(&opts(jobs)).expect("bench campaign");
        if jobs == 1 {
            wall_j1 = report.wall_secs;
        }
        println!(
            "dispatch/jobs_{jobs:<2}            {} runs in {:>8.2?} ({:.2} runs/sec, speedup {:.2}x)",
            report.runs.len(),
            std::time::Duration::from_secs_f64(report.wall_secs),
            report.runs_per_sec(),
            wall_j1 / report.wall_secs.max(1e-12),
        );
        pairs.push((
            match jobs {
                1 => "runs_per_sec_j1",
                2 => "runs_per_sec_j2",
                4 => "runs_per_sec_j4",
                _ => "runs_per_sec_j8",
            },
            Json::num(report.runs_per_sec()),
        ));
    }

    // -- cache economics: cold fill vs warm hit ---------------------------
    let cache_dir = std::env::temp_dir()
        .join(format!("adpsgd_bench_dispatch_cache_{}", std::process::id()));
    std::fs::remove_dir_all(&cache_dir).ok();
    let cached = DispatchOptions {
        jobs: Some(4),
        cache_dir: Some(cache_dir.clone()),
        ..DispatchOptions::default()
    };
    let cold = eight(&base).execute(&cached).expect("cold campaign");
    let warm = eight(&base).execute(&cached).expect("warm campaign");
    let hit_rate = warm.cache_hits() as f64 / warm.runs.len() as f64;
    assert!(
        (hit_rate - 1.0).abs() < f64::EPSILON,
        "warm pass must be all hits, got {hit_rate}"
    );
    assert_eq!(
        cold.to_json_stable().to_string_compact(),
        warm.to_json_stable().to_string_compact(),
        "cold and warm stable summaries must be byte-identical"
    );
    println!(
        "dispatch/cache              cold {:>8.2?} -> warm {:>8.2?} ({:.0}% hits, {:.1}x, {:.1} probe runs/sec)",
        std::time::Duration::from_secs_f64(cold.wall_secs),
        std::time::Duration::from_secs_f64(warm.wall_secs),
        hit_rate * 100.0,
        cold.wall_secs / warm.wall_secs.max(1e-12),
        warm.runs_per_sec(),
    );
    std::fs::remove_dir_all(&cache_dir).ok();
    pairs.push(("cache_hit_rate", Json::num(hit_rate)));
    pairs.push(("cold_wall_secs", Json::num(cold.wall_secs)));
    pairs.push(("warm_wall_secs", Json::num(warm.wall_secs)));
    // warm-probe throughput: all 8 runs answered by parallel cache
    // probes on the slot threads (no training, no serial pre-pass)
    pairs.push(("warm_probe_runs_per_sec", Json::num(warm.runs_per_sec())));

    // -- proto v3 wire economics: JSON line vs binary payload -------------
    // the same finished report encoded both ways; the binary form is what
    // the TCP transport actually ships for run results since proto v3
    {
        use adpsgd::dispatch::net::transport;
        use adpsgd::dispatch::proto::Frame;
        let report = adpsgd::experiment::Experiment::from_config(tiny_base(iters))
            .and_then(adpsgd::experiment::Experiment::run)
            .expect("proto wire-size run");
        let frame = Frame::RunResult { id: 1, report };
        let json_bytes = frame.to_line().expect("json form").len();
        let bin_bytes = transport::encode_frame(&frame).expect("binary form").len();
        println!(
            "dispatch/proto_bytes        json {json_bytes}B vs binary {bin_bytes}B per run result ({:.2}x smaller)",
            json_bytes as f64 / bin_bytes.max(1) as f64,
        );
        pairs.push(("proto_json_bytes_per_run", Json::num(json_bytes as f64)));
        pairs.push(("proto_binary_bytes_per_run", Json::num(bin_bytes as f64)));
    }

    // -- journal overhead: the event journal is a pure observer ------------
    // the same 8-run campaign with and without a journal attached; the
    // per-run delta prices the JSONL lifecycle lines plus the full typed
    // event stream (thread workers attach the JournalObserver)
    {
        let jpath = std::env::temp_dir()
            .join(format!("adpsgd_bench_dispatch_journal_{}.jsonl", std::process::id()));
        std::fs::remove_file(&jpath).ok();
        let off = eight(&base).execute(&opts(4)).expect("journal-off campaign");
        let journal = adpsgd::obs::Journal::create(&jpath).expect("bench journal");
        let on = eight(&base)
            .execute(&DispatchOptions { journal: Some(journal), ..opts(4) })
            .expect("journal-on campaign");
        assert_eq!(
            off.to_json_stable().to_string_compact(),
            on.to_json_stable().to_string_compact(),
            "the journal must not change the stable summary"
        );
        let overhead = (on.wall_secs - off.wall_secs) / on.runs.len() as f64;
        println!(
            "dispatch/journal            off {:>8.2?} vs on {:>8.2?} ({overhead:+.3}s/run)",
            std::time::Duration::from_secs_f64(off.wall_secs),
            std::time::Duration::from_secs_f64(on.wall_secs),
        );
        pairs.push(("journal_overhead_secs_per_run", Json::num(overhead)));
        std::fs::remove_file(&jpath).ok();
    }

    // -- subprocess transport overhead ------------------------------------
    // cargo exports the binary path to benches; guard for stripped envs
    let worker_exe = option_env!("CARGO_BIN_EXE_adpsgd").map(std::path::PathBuf::from);
    match worker_exe {
        Some(exe) if exe.exists() => {
            let two = |opts: &DispatchOptions| {
                let mut b = tiny_base(iters);
                b.name = "bench_sub".into();
                let c = Campaign::builder("sub", b.clone())
                    .strategy("cpsgd", b.sync.spec_of(Strategy::Constant))
                    .strategy("full", StrategySpec::Full)
                    .build()
                    .expect("subprocess bench campaign");
                c.execute(opts).expect("subprocess bench campaign run")
            };
            let threads = two(&opts(2));
            let subs = two(&DispatchOptions {
                jobs: Some(2),
                workers: WorkerKind::Subprocess,
                worker_exe: Some(exe.clone()),
                cache_dir: None,
                ..DispatchOptions::default()
            });
            let overhead =
                (subs.wall_secs - threads.wall_secs) / subs.runs.len() as f64;
            println!(
                "dispatch/subprocess         thread {:>8.2?} vs subprocess {:>8.2?} ({:+.3}s/run)",
                std::time::Duration::from_secs_f64(threads.wall_secs),
                std::time::Duration::from_secs_f64(subs.wall_secs),
                overhead,
            );
            pairs.push(("subprocess_overhead_secs_per_run", Json::num(overhead)));

            // -- event-stream overhead: proto-v6 events frames -------------
            // the same 2-run subprocess campaign, journaled both times,
            // with the worker-child event stream off vs on; the delta
            // prices line rendering + batching + driver-side merging
            {
                let jdir = std::env::temp_dir()
                    .join(format!("adpsgd_bench_dispatch_stream_{}", std::process::id()));
                std::fs::remove_dir_all(&jdir).ok();
                std::fs::create_dir_all(&jdir).expect("bench stream dir");
                let journaled = |tag: &str, stream: bool| {
                    let journal =
                        adpsgd::obs::Journal::create(&jdir.join(format!("{tag}.jsonl")))
                            .expect("bench stream journal");
                    two(&DispatchOptions {
                        jobs: Some(2),
                        workers: WorkerKind::Subprocess,
                        worker_exe: Some(exe.clone()),
                        cache_dir: None,
                        journal: Some(journal),
                        stream_events: stream,
                        ..DispatchOptions::default()
                    })
                };
                let off = journaled("off", false);
                let on = journaled("on", true);
                assert_eq!(
                    off.to_json_stable().to_string_compact(),
                    on.to_json_stable().to_string_compact(),
                    "event streaming must not change the stable summary"
                );
                let overhead = (on.wall_secs - off.wall_secs) / on.runs.len() as f64;
                println!(
                    "dispatch/event_stream       off {:>8.2?} vs on {:>8.2?} ({overhead:+.3}s/run)",
                    std::time::Duration::from_secs_f64(off.wall_secs),
                    std::time::Duration::from_secs_f64(on.wall_secs),
                );
                pairs.push(("event_stream_overhead_secs_per_run", Json::num(overhead)));
                std::fs::remove_dir_all(&jdir).ok();
            }

            // -- pool reuse vs respawn across sequential campaigns ---------
            // the same 2-run campaign dispatched 3 times in a row: once
            // through the process-wide shared pool (children stay warm
            // between dispatches) and once with a fresh private pool per
            // dispatch (the historical respawn-per-campaign behavior)
            let mut b = tiny_base(iters);
            b.name = "bench_pool".into();
            let campaign = Campaign::builder("pool", b.clone())
                .strategy("cpsgd", b.sync.spec_of(Strategy::Constant))
                .strategy("full", StrategySpec::Full)
                .build()
                .expect("pool bench campaign");
            let sub_opts = DispatchOptions {
                jobs: Some(2),
                workers: WorkerKind::Subprocess,
                worker_exe: Some(exe.clone()),
                cache_dir: None,
                ..DispatchOptions::default()
            };
            const ROUNDS: usize = 3;
            let timed = |fresh_pool_per_dispatch: bool| {
                let t = std::time::Instant::now();
                for _ in 0..ROUNDS {
                    let d = if fresh_pool_per_dispatch {
                        Dispatcher::with_pool(sub_opts.clone(), Arc::new(WorkerPool::new()))
                    } else {
                        Dispatcher::new(sub_opts.clone())
                    };
                    d.execute(campaign.runs()).expect("pool bench dispatch");
                }
                t.elapsed().as_secs_f64()
            };
            let reuse = timed(false);
            let respawn = timed(true);
            let per_campaign = (respawn - reuse) / ROUNDS as f64;
            println!(
                "dispatch/pool_reuse         shared {:>8.2?} vs respawn {:>8.2?} over {ROUNDS} campaigns ({:+.3}s/campaign)",
                std::time::Duration::from_secs_f64(reuse),
                std::time::Duration::from_secs_f64(respawn),
                per_campaign,
            );
            pairs.push(("pool_reuse_wall_secs", Json::num(reuse)));
            pairs.push(("pool_respawn_wall_secs", Json::num(respawn)));
            pairs.push(("pool_respawn_overhead_secs_per_campaign", Json::num(per_campaign)));

            // -- remote loopback: the TCP agent fabric vs local threads ----
            // an in-process agent on 127.0.0.1 whose children run the
            // real binary: the overhead measured is handshake + JSON
            // frames over loopback + the agent's child supervision
            let agent_cfg = AgentConfig {
                listen: "127.0.0.1:0".into(),
                slots: 2,
                worker_exe: Some(exe.clone()),
                ..AgentConfig::default()
            };
            match Agent::spawn(agent_cfg, Arc::new(WorkerPool::new())) {
                Ok(addr) => {
                    let remote = two(&DispatchOptions {
                        workers: WorkerKind::Remote,
                        remote: vec![addr.to_string()],
                        cache_dir: None,
                        ..DispatchOptions::default()
                    });
                    assert_eq!(
                        threads.to_json_stable().to_string_compact(),
                        remote.to_json_stable().to_string_compact(),
                        "remote loopback must reproduce the local stable summary"
                    );
                    let overhead =
                        (remote.wall_secs - threads.wall_secs) / remote.runs.len() as f64;
                    println!(
                        "dispatch/remote_loopback    thread {:>8.2?} vs agent {:>8.2?} ({:.2} runs/sec, {:+.3}s/run)",
                        std::time::Duration::from_secs_f64(threads.wall_secs),
                        std::time::Duration::from_secs_f64(remote.wall_secs),
                        remote.runs_per_sec(),
                        overhead,
                    );
                    pairs.push((
                        "remote_loopback_runs_per_sec",
                        Json::num(remote.runs_per_sec()),
                    ));
                    pairs.push(("remote_overhead_secs_per_run", Json::num(overhead)));
                }
                Err(e) => {
                    println!("dispatch/remote_loopback    skipped (agent bind failed: {e:#})");
                    pairs.push(("remote_loopback_runs_per_sec", Json::Null));
                    pairs.push(("remote_overhead_secs_per_run", Json::Null));
                }
            }

            // -- fleet: announce-to-membership latency ---------------------
            // how long after an agent starts announcing does a registry
            // poll first list it (the floor on mid-campaign join latency)
            {
                use adpsgd::dispatch::fleet::registry;
                use adpsgd::dispatch::Registry;
                let joined = Registry::spawn("127.0.0.1:0").ok().and_then(|reg| {
                    let reg = reg.to_string();
                    let t = std::time::Instant::now();
                    let agent_cfg = AgentConfig {
                        listen: "127.0.0.1:0".into(),
                        slots: 2,
                        worker_exe: Some(exe.clone()),
                        fleet: Some(reg.clone()),
                        ..AgentConfig::default()
                    };
                    let addr = Agent::spawn(agent_cfg, Arc::new(WorkerPool::new()))
                        .ok()?
                        .to_string();
                    loop {
                        match registry::members(&reg) {
                            Ok(ms) if ms.iter().any(|m| m.addr == addr) => {
                                break Some(t.elapsed().as_secs_f64())
                            }
                            _ if t.elapsed() > std::time::Duration::from_secs(10) => {
                                break None
                            }
                            _ => std::thread::sleep(std::time::Duration::from_millis(2)),
                        }
                    }
                });
                match joined {
                    Some(secs) => {
                        println!("dispatch/fleet_join         agent visible in the registry after {secs:.3}s");
                        pairs.push(("fleet_join_secs", Json::num(secs)));
                    }
                    None => {
                        println!("dispatch/fleet_join         skipped (registry or agent unavailable)");
                        pairs.push(("fleet_join_secs", Json::Null));
                    }
                }
            }

            // -- blob staging: bytes shipped per warm-start run ------------
            // one snapshot, referenced by both runs of a remote campaign
            // against an agent with an empty blob store: content
            // addressing stages the artifact once, so bytes/run halves
            {
                let ckpt = std::env::temp_dir()
                    .join(format!("adpsgd_bench_blob_src_{}", std::process::id()));
                let store = std::env::temp_dir()
                    .join(format!("adpsgd_bench_blob_store_{}", std::process::id()));
                std::fs::remove_dir_all(&ckpt).ok();
                std::fs::remove_dir_all(&store).ok();
                let mut seed = tiny_base(iters);
                seed.name = "bench_blob_seed".into();
                seed.checkpoint_every = (iters / 2).max(1);
                seed.checkpoint_dir = ckpt.to_string_lossy().into_owned();
                adpsgd::experiment::Experiment::from_config(seed)
                    .and_then(adpsgd::experiment::Experiment::run)
                    .expect("blob bench seeding run");
                let mut b = tiny_base(iters);
                b.name = "bench_blob".into();
                b.init_from = ckpt.to_string_lossy().into_owned();
                let campaign = Campaign::builder("blob", b.clone())
                    .strategy("cpsgd", b.sync.spec_of(Strategy::Constant))
                    .strategy("full", StrategySpec::Full)
                    .build()
                    .expect("blob bench campaign");
                let agent_cfg = AgentConfig {
                    listen: "127.0.0.1:0".into(),
                    slots: 2,
                    worker_exe: Some(exe.clone()),
                    cache_dir: Some(store.clone()),
                    ..AgentConfig::default()
                };
                match Agent::spawn(agent_cfg, Arc::new(WorkerPool::new())) {
                    Ok(addr) => {
                        let report = campaign
                            .execute(&DispatchOptions {
                                workers: WorkerKind::Remote,
                                remote: vec![addr.to_string()],
                                cache_dir: None,
                                ..DispatchOptions::default()
                            })
                            .expect("blob bench campaign run");
                        let staged: u64 = std::fs::read_dir(store.join("blobs"))
                            .map(|rd| {
                                rd.filter_map(|e| e.ok())
                                    .filter_map(|e| e.metadata().ok())
                                    .map(|m| m.len())
                                    .sum()
                            })
                            .unwrap_or(0);
                        let per_run = staged as f64 / report.runs.len() as f64;
                        println!(
                            "dispatch/blob_staging       {staged}B staged once for {} warm-start runs ({per_run:.0}B/run)",
                            report.runs.len(),
                        );
                        pairs.push(("blob_staging_bytes_per_run", Json::num(per_run)));
                    }
                    Err(e) => {
                        println!("dispatch/blob_staging       skipped (agent bind failed: {e:#})");
                        pairs.push(("blob_staging_bytes_per_run", Json::Null));
                    }
                }
                std::fs::remove_dir_all(&ckpt).ok();
                std::fs::remove_dir_all(&store).ok();
            }
        }
        _ => {
            println!("dispatch/subprocess         skipped (worker binary unavailable)");
            // keep the JSON schema identical to the measured branch
            pairs.push(("subprocess_overhead_secs_per_run", Json::Null));
            pairs.push(("event_stream_overhead_secs_per_run", Json::Null));
            pairs.push(("pool_reuse_wall_secs", Json::Null));
            pairs.push(("pool_respawn_wall_secs", Json::Null));
            pairs.push(("pool_respawn_overhead_secs_per_campaign", Json::Null));
            pairs.push(("remote_loopback_runs_per_sec", Json::Null));
            pairs.push(("remote_overhead_secs_per_run", Json::Null));
            pairs.push(("fleet_join_secs", Json::Null));
            pairs.push(("blob_staging_bytes_per_run", Json::Null));
        }
    }

    let line = Json::obj(pairs).to_string_compact();
    println!("BENCH_DISPATCH_JSON {line}");
    if let Err(e) = std::fs::write("BENCH_dispatch.json", &line) {
        eprintln!("warning: could not write BENCH_dispatch.json: {e}");
    } else {
        println!("wrote BENCH_dispatch.json");
    }
}
