//! End-to-end figure benchmarks — one timed quick-scale regeneration per
//! paper table/figure, exercising the full coordinator stack (workers,
//! collectives, period control, ledger).  These are the "one bench per
//! paper table" harnesses; `cargo bench` prints each figure's
//! regeneration wall-time and its key reproduced numbers.

use adpsgd::figures::convergence::{convergence, time_split, Role};
use adpsgd::figures::{
    cifar_base, decreasing::decreasing_study, googlenet_role, speedup::fig6, table1::table1,
    variance::{fig1, fig2_fig3},
    vgg_role, Scale, Sink,
};
use std::time::Instant;

fn timed<T>(name: &str, f: impl FnOnce() -> anyhow::Result<T>) -> Option<T> {
    let t = Instant::now();
    match f() {
        Ok(v) => {
            println!("figures/{name:<28} regenerated in {:>8.2?}", t.elapsed());
            Some(v)
        }
        Err(e) => {
            println!("figures/{name:<28} FAILED: {e}");
            None
        }
    }
}

fn main() {
    let scale = Scale::Quick;
    let sink = Sink::new(None, true);
    println!("\n== bench group: figures (quick-scale end-to-end regeneration) ==");

    timed("fig1_cpsgd_variance", || fig1(scale, &sink)).map(|f| {
        println!("    -> {} periods, {} V_t points each", f.rows.len(), f.rows[0].v_t.points.len());
    });

    timed("fig2_fig3_adpsgd_variance", || fig2_fig3(scale, &sink)).map(|f| {
        println!(
            "    -> ADPSGD {} syncs (p̄ {:.2}) vs CPSGD-8 {} syncs",
            f.adpsgd.syncs, f.adpsgd.avg_period, f.cpsgd8.syncs
        );
    });

    for role in [Role::GoogLeNet, Role::Vgg16, Role::ResNet50, Role::AlexNet] {
        timed(&format!("{}_convergence", role.figure().replace(' ', "").to_lowercase()), || {
            let c = convergence(role, scale, &sink)?;
            let rows = time_split(&c, &sink);
            Ok((c, rows))
        })
        .map(|(c, rows)| {
            println!(
                "    -> ADPSGD acc {:.3} vs CPSGD {:.3}; comm@10G {:.2}s vs FULL {:.2}s",
                c.adpsgd().best_eval_acc,
                c.cpsgd().best_eval_acc,
                rows[2].comm_10g,
                rows[0].comm_10g
            );
        });
    }

    timed("fig6_speedup", || {
        let mut base = cifar_base(scale);
        vgg_role(&mut base, scale);
        base.iters = 320;
        fig6("vgg-role", &base, scale, &sink)
    })
    .map(|f| {
        let a = f.cell(adpsgd::period::Strategy::Adaptive, 16);
        println!("    -> ADPSGD@16: {:.2}x @100G / {:.2}x @10G", a.speedup_100g, a.speedup_10g);
    });

    timed("table1_accuracy_sweep", || {
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        base.iters = 240;
        base.eval_every = 40;
        table1(&base, scale, &sink)
    })
    .map(|t| {
        println!(
            "    -> ADPSGD {:.3} vs CPSGD-best {:.3} vs FULLSGD-best {:.3}",
            t.get("ADPSGD").best_acc,
            t.get("CPSGD").best_acc,
            t.get("FULLSGD").best_acc
        );
    });

    timed("sec5b_decreasing_period", || {
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        decreasing_study(&base, &sink)
    })
    .map(|s| {
        println!(
            "    -> decreasing loss {:.4} vs ADPSGD {:.4} at {} vs {} syncs",
            s.decreasing.final_train_loss,
            s.adpsgd.final_train_loss,
            s.decreasing.syncs,
            s.adpsgd.syncs
        );
    });

    println!("== figures done ==");
}
