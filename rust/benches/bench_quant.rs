//! QSGD quantizer benchmarks — the compression cost the paper's §VI
//! argues can defeat the saved bandwidth on fast links.  Reported per
//! gradient size so the netsim crossover analysis in EXPERIMENTS.md can
//! cite measured encode+decode cost vs modeled wire-time savings.

use adpsgd::quant::{decode, encode, quantize_inplace, QsgdConfig};
use adpsgd::util::bench::Runner;
use adpsgd::util::rng::Rng;

fn main() {
    let mut r = Runner::from_env("quant");
    let cfg = QsgdConfig::default();

    for &n in &[64 * 1024usize, 1 << 20, 6_800_000] {
        let tag = if n >= 1 << 20 { format!("{}M", n >> 20) } else { format!("{}k", n >> 10) };
        let mut g = vec![0.0f32; n];
        Rng::new(3, 0).fill_normal(&mut g, 0.01);
        let bytes = (n * 4) as u64;

        {
            let g = g.clone();
            let mut rng = Rng::new(11, 0);
            r.bench_bytes(&format!("encode/{tag}"), bytes, move || encode(&g, &cfg, &mut rng));
        }
        {
            let mut rng = Rng::new(11, 0);
            let enc = encode(&g, &cfg, &mut rng);
            let mut out = vec![0.0f32; n];
            r.bench_bytes(&format!("decode/{tag}"), bytes, move || {
                decode(&enc, &mut out);
                out[0]
            });
        }
        {
            let mut buf = g.clone();
            let mut rng = Rng::new(11, 0);
            r.bench_bytes(&format!("quantize_inplace/{tag}"), bytes, move || {
                quantize_inplace(&mut buf, &cfg, &mut rng)
            });
        }
    }

    // bucket-size sensitivity at 1M params
    let n = 1 << 20;
    let mut g = vec![0.0f32; n];
    Rng::new(5, 0).fill_normal(&mut g, 0.01);
    for bucket in [128usize, 512, 2048, 8192] {
        let qcfg = QsgdConfig { levels: 255, bucket };
        let mut buf = g.clone();
        let mut rng = Rng::new(13, 0);
        r.bench(&format!("quantize_inplace/bucket{bucket}"), move || {
            quantize_inplace(&mut buf, &qcfg, &mut rng)
        });
    }

    r.finish();
}
