//! QSGD quantizer benchmarks — the compression cost the paper's §VI
//! argues can defeat the saved bandwidth on fast links.  Reported per
//! gradient size so the netsim crossover analysis in EXPERIMENTS.md can
//! cite measured encode+decode cost vs modeled wire-time savings.
//!
//! `encode_into` and `quantize_inplace_with` reuse caller scratch
//! (no per-sync allocation); the serial/par pairs measure the parallel
//! bucket-norm pre-pass (the stochastic level walk stays sequential for
//! RNG-order determinism, so speedups here are smaller than tensor's).

use adpsgd::quant::{
    decode, encode, encode_into, quantize_inplace, quantize_inplace_with, Encoded, QsgdConfig,
    QsgdScratch,
};
use adpsgd::tensor::par;
use adpsgd::util::bench::{Measurement, Runner};
use adpsgd::util::rng::Rng;

/// Bench `f` serial then parallel and print the speedup column.
fn bench_pair<T>(r: &mut Runner, name: &str, bytes: u64, mut f: impl FnMut() -> T) {
    par::set_threads(1);
    let serial = r.bench(&format!("{name}/serial"), &mut f).map(Measurement::p50_ns);
    par::set_threads(0);
    let auto = r.bench(&format!("{name}/par"), &mut f).map(Measurement::p50_ns);
    if let (Some(s), Some(p)) = (serial, auto) {
        println!(
            "{:<44} {:>9.2}x speedup  ({:.2} GB/s parallel, {} threads)",
            format!("quant/{name}"),
            s / p,
            bytes as f64 / p,
            par::threads()
        );
    }
}

fn main() {
    let mut r = Runner::from_env("quant");
    let cfg = QsgdConfig::default();

    for &n in &[64 * 1024usize, 1 << 20, 6_800_000] {
        let tag = if n >= 1 << 20 { format!("{}M", n >> 20) } else { format!("{}k", n >> 10) };
        let mut g = vec![0.0f32; n];
        Rng::new(3, 0).fill_normal(&mut g, 0.01);
        let bytes = (n * 4) as u64;

        {
            let g = g.clone();
            let mut rng = Rng::new(11, 0);
            par::set_threads(1);
            r.bench_bytes(&format!("encode/{tag}"), bytes, move || encode(&g, &cfg, &mut rng));
        }
        {
            // scratch-reusing encode: the per-sync hot path after PR 6
            let g = g.clone();
            let mut rng = Rng::new(11, 0);
            let mut out = Encoded::default();
            bench_pair(&mut r, &format!("encode_into/{tag}"), bytes, move || {
                encode_into(&g, &cfg, &mut rng, &mut out);
                out.qs.first().copied()
            });
        }
        {
            let mut rng = Rng::new(11, 0);
            let enc = encode(&g, &cfg, &mut rng);
            let mut out = vec![0.0f32; n];
            r.bench_bytes(&format!("decode/{tag}"), bytes, move || {
                decode(&enc, &mut out);
                out[0]
            });
        }
        {
            let mut buf = g.clone();
            let mut rng = Rng::new(11, 0);
            let mut scratch = QsgdScratch::default();
            bench_pair(&mut r, &format!("quantize_inplace/{tag}"), bytes, move || {
                quantize_inplace_with(&mut buf, &cfg, &mut rng, &mut scratch)
            });
        }
    }

    // bucket-size sensitivity at 1M params
    let n = 1 << 20;
    let mut g = vec![0.0f32; n];
    Rng::new(5, 0).fill_normal(&mut g, 0.01);
    for bucket in [128usize, 512, 2048, 8192] {
        let qcfg = QsgdConfig { levels: 255, bucket };
        let mut buf = g.clone();
        let mut rng = Rng::new(13, 0);
        r.bench(&format!("quantize_inplace/bucket{bucket}"), move || {
            quantize_inplace(&mut buf, &qcfg, &mut rng)
        });
    }

    par::set_threads(0);
    r.finish();
}
