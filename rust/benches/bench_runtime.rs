//! PJRT runtime benchmarks: latency of the AOT HLO executables (init /
//! step / grad / eval) for every model in the artifact manifest — the
//! product-path compute cost on this host.
//!
//! Requires `make artifacts`.  Exits cleanly with a notice if artifacts
//! are absent (e.g., a fresh checkout before the python build step).

use adpsgd::data::{CharCorpus, DatasetHandle, NodeSource, SynthClass};
use adpsgd::runtime::{EngineFns, HloEngine, Manifest};
use adpsgd::util::bench::Runner;
use std::sync::Arc;

fn main() {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            println!("bench_runtime: skipping ({e}); run `make artifacts` first");
            return;
        }
    };

    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    let mut r = Runner::from_env("runtime");

    for (name, spec) in &manifest.models {
        // the big models dominate the window; skip them in fast mode
        if fast && spec.param_count > 300_000 {
            continue;
        }
        let engine = match HloEngine::load(&manifest, name, EngineFns::all()) {
            Ok(e) => e,
            Err(e) => {
                println!("runtime/{name}: load failed: {e}");
                continue;
            }
        };
        let n = engine.n_params();

        let dataset = if spec.kind == "lm" {
            DatasetHandle::Text(Arc::new(CharCorpus::generate(1, 1 << 14)))
        } else {
            let dim = *spec.x_shape.last().unwrap();
            DatasetHandle::Class(Arc::new(SynthClass::new(1, dim, spec.classes.max(2), 1.0, 0.0)))
        };
        let mut source = NodeSource::new(dataset, 1, 0, spec.batch, spec.seq);
        let batch = source.next_batch();

        let mut w = engine.init(42).unwrap();
        let mut m = vec![0.0f32; n];
        let mut g = vec![0.0f32; n];

        r.bench(&format!("{name}/step ({n}p)"), || {
            engine.step(&mut w, &mut m, &batch, 1e-4).unwrap()
        });
        r.bench(&format!("{name}/grad"), || engine.grad(&w, &batch, &mut g).unwrap());
        r.bench(&format!("{name}/apply"), || {
            engine.apply(&mut w, &mut m, &g, 1e-5).unwrap();
            w[0]
        });
        r.bench(&format!("{name}/eval"), || engine.eval(&w, &batch).unwrap());
        let w2 = w.clone();
        r.bench(&format!("{name}/sq_dev"), || engine.sq_dev(&w, &w2).unwrap());
    }

    r.finish();
}
