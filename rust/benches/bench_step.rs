//! Per-step engine benchmarks: the local SGD step (forward + backward +
//! fused momentum update) for every native workload, at both figure
//! geometries.  These are the compute numbers the Fig 4c/5c/6 time
//! models calibrate against.
//!
//! Every step/grad row is measured serial (`perf.threads = 1`) and
//! parallel (auto), with a speedup column — results are bit-identical
//! between the two (see `tensor::par`), so the column is pure
//! throughput.  The final `mlp_wide/d1024h1024` row is the 1e6+ param
//! geometry where kernel parallelism should pay for its dispatch.
//!
//! The closing section prices one whole tiny run under the cluster
//! model twice — a uniform cluster and a 4x straggler — and emits the
//! skewed-vs-uniform modeled-wall-clock column to `BENCH_step.json`
//! (`BENCH_STEP_JSON` on stdout): how much of the injected skew the BSP
//! barrier absorbs is a perf trajectory number like any other, and the
//! parameter trajectory is asserted identical between the two runs.

use adpsgd::config::{ExperimentConfig, LrSchedule, WorkloadConfig};
use adpsgd::coordinator::engine::{Engine, NativeEngine};
use adpsgd::data::SynthClass;
use adpsgd::experiment::Experiment;
use adpsgd::tensor::par;
use adpsgd::util::bench::{Measurement, Runner};
use adpsgd::util::json::Json;
use adpsgd::util::rng::Rng;
use adpsgd::workload::build;

/// Bench `f` serial then parallel and print the speedup column.
fn bench_pair<T>(r: &mut Runner, name: &str, mut f: impl FnMut() -> T) {
    par::set_threads(1);
    let serial = r.bench(&format!("{name}/serial"), &mut f).map(Measurement::p50_ns);
    par::set_threads(0);
    let auto = r.bench(&format!("{name}/par"), &mut f).map(Measurement::p50_ns);
    if let (Some(s), Some(p)) = (serial, auto) {
        println!(
            "{:<44} {:>9.2}x speedup  ({} threads)",
            format!("step/{name}"),
            s / p,
            par::threads()
        );
    }
}

fn main() {
    let mut r = Runner::from_env("step");

    for (name, dim, hidden, batch) in [
        ("mlp", 128usize, 64usize, 32usize),
        ("mlp", 256, 128, 128),
        ("mlp_deep", 256, 192, 128),
        ("mlp_wide", 256, 256, 128),
        ("logreg", 256, 0, 128),
        ("quadratic", 1024, 0, 128),
        // the 1e6+ param geometry: parallel kernels should clearly win here
        ("mlp_wide", 1024, 1024, 64),
    ] {
        let mut wcfg = WorkloadConfig::default();
        wcfg.input_dim = dim;
        wcfg.hidden = hidden.max(1);
        let wl = build(name, &wcfg).unwrap();
        let n_params = wl.n_params();
        let mut engine = NativeEngine::new(wl, 0.9);

        let ds = SynthClass::new(42, dim, 10, 1.0, 0.05);
        let mut rng = Rng::new(7, 0);
        let batch_data = ds.sample(&mut rng, batch);

        let mut w = engine.init(42).unwrap();
        let mut m = vec![0.0f32; n_params];
        let tag = format!("{name}/d{dim}h{hidden}b{batch} ({n_params}p)");
        bench_pair(&mut r, &format!("step/{tag}"), || {
            engine.step(&mut w, &mut m, &batch_data, 1e-4).unwrap()
        });

        let mut g = vec![0.0f32; n_params];
        bench_pair(&mut r, &format!("grad/{tag}"), || {
            engine.grad(&w, &batch_data, &mut g).unwrap()
        });

        par::set_threads(1);
        r.bench(&format!("eval/{tag}"), || engine.eval(&w, &batch_data).unwrap());
    }

    par::set_threads(0);
    r.finish();

    // ------------------------------------------ modeled wall clock
    // one tiny CPSGD run priced under a uniform cluster and under a 4x
    // straggler with seeded jitter: modeled_wall_secs is deterministic
    // (config-declared step_us, never measured time), so the slowdown
    // column is comparable across hosts and commits
    let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
    let iters = if fast { 80 } else { 240 };
    let run_modeled = |skewed: bool| {
        let mut cfg = ExperimentConfig::default();
        cfg.name = if skewed { "bench_step_skew".into() } else { "bench_step_uniform".into() };
        cfg.nodes = 4;
        cfg.iters = iters;
        cfg.batch_per_node = 16;
        cfg.eval_every = 0;
        cfg.variance_every = 0;
        cfg.workload.input_dim = 48;
        cfg.workload.hidden = 24;
        cfg.optim.schedule = LrSchedule::Const;
        cfg.sync.strategy = adpsgd::period::Strategy::Constant;
        cfg.sync.period = 4;
        if skewed {
            cfg.cluster.skew = "straggler:4.0".into();
            cfg.cluster.jitter = 0.1;
        }
        Experiment::from_config(cfg).expect("bench config").run().expect("bench run")
    };
    let uniform = run_modeled(false);
    let skewed = run_modeled(true);
    assert_eq!(
        uniform.final_train_loss, skewed.final_train_loss,
        "skew must move modeled clocks, never the trajectory"
    );
    let slowdown = skewed.modeled_wall_secs / uniform.modeled_wall_secs.max(1e-12);
    println!(
        "{:<44} uniform {:>8.3}s  skewed {:>8.3}s  ({:.2}x slowdown)",
        "step/modeled_wall (cpsgd, 4 nodes)", uniform.modeled_wall_secs, skewed.modeled_wall_secs,
        slowdown
    );

    let summary = Json::obj(vec![
        ("bench", Json::str("step")),
        ("iters", Json::num(iters as f64)),
        ("modeled_wall_secs_uniform", Json::num(uniform.modeled_wall_secs)),
        ("modeled_wall_secs_skewed", Json::num(skewed.modeled_wall_secs)),
        ("straggler_slowdown", Json::num(slowdown)),
    ]);
    let line = summary.to_string_compact();
    println!("BENCH_STEP_JSON {line}");
    if let Err(e) = std::fs::write("BENCH_step.json", &line) {
        eprintln!("warning: could not write BENCH_step.json: {e}");
    } else {
        println!("wrote BENCH_step.json");
    }
}
