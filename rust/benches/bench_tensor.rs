//! Tensor-algebra micro-benchmarks — the coordinator's parameter hot
//! path (momentum update, squared deviation, allreduce arithmetic) at
//! the paper's model sizes (GoogLeNet ≈ 6.8M params, VGG16 ≈ 138M is
//! benchmarked at 32M to keep the window short).
//!
//! Each kernel is measured twice — `perf.threads = 1` (the serial lane
//! kernels) and `perf.threads = 0` (auto parallelism) — and a speedup
//! column reports the ratio.  The two settings are bit-identical by
//! construction (see `tensor::par`), so the column is pure throughput,
//! not an accuracy trade.

use adpsgd::tensor::{self, par};
use adpsgd::util::bench::Runner;
use adpsgd::util::rng::Rng;

fn vec_of(n: usize, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    Rng::new(seed, 0).fill_normal(&mut v, 1.0);
    v
}

/// §Perf baseline: the pre-optimization serial-f64 reduction (kept here
/// so `cargo bench` shows the before/after delta of the chunked-lane
/// rewrite directly).
fn sq_deviation_naive(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

/// Bench `f` serial then parallel and print the speedup column.
fn bench_pair<T>(r: &mut Runner, name: &str, bytes: u64, mut f: impl FnMut() -> T) {
    par::set_threads(1);
    let serial = r.bench(&format!("{name}/serial"), &mut f).map(adpsgd::util::bench::Measurement::p50_ns);
    par::set_threads(0);
    let auto = r.bench(&format!("{name}/par"), &mut f).map(adpsgd::util::bench::Measurement::p50_ns);
    if let (Some(s), Some(p)) = (serial, auto) {
        println!(
            "{:<44} {:>9.2}x speedup  ({:.2} GB/s parallel, {} threads)",
            format!("tensor/{name}"),
            s / p,
            bytes as f64 / p,
            par::threads()
        );
    }
}

fn main() {
    let mut r = Runner::from_env("tensor");

    for &n in &[64 * 1024usize, 1 << 20, 6_800_000, 32 << 20] {
        let tag = if n >= 1 << 20 { format!("{}M", n >> 20) } else { format!("{}k", n >> 10) };
        let x = vec_of(n, 1);
        let y0 = vec_of(n, 2);
        let bytes = (n * 4) as u64;

        let mut y = y0.clone();
        bench_pair(&mut r, &format!("axpy/{tag}"), 2 * bytes, || {
            tensor::axpy(&mut y, 0.5, &x);
            y[0]
        });

        bench_pair(&mut r, &format!("sq_norm/{tag}"), bytes, || tensor::sq_norm(&x));

        bench_pair(&mut r, &format!("sq_deviation/{tag}"), 2 * bytes, || {
            tensor::sq_deviation(&x, &y0)
        });

        par::set_threads(1);
        r.bench_bytes(&format!("sq_deviation_naive/{tag}"), 2 * bytes, || {
            sq_deviation_naive(&x, &y0)
        });

        let mut w = y0.clone();
        let mut m = vec![0.0f32; n];
        let g = x.clone();
        bench_pair(&mut r, &format!("momentum_update/{tag}"), 4 * bytes, || {
            tensor::momentum_update(&mut w, &mut m, &g, 1e-6, 0.9);
            w[0]
        });

        bench_pair(&mut r, &format!("dot/{tag}"), 2 * bytes, || tensor::dot(&x, &y0));
    }

    // param_variance across 16 node rows — the Var[W_k] instrumentation
    let n = 1 << 18;
    let rows_data: Vec<Vec<f32>> = (0..16).map(|i| vec_of(n, 100 + i)).collect();
    let rows: Vec<&[f32]> = rows_data.iter().map(|v| v.as_slice()).collect();
    let mut scratch = vec![0.0f32; n];
    bench_pair(&mut r, "param_variance/16x256k", (16 * n * 4) as u64, || {
        tensor::param_variance(&rows, &mut scratch)
    });

    par::set_threads(0);
    r.finish();
}
