//! §IV-B robustness ablations: the paper's sensitivity claims for
//! Algorithm 2's hyper-parameters, plus the EASGD (related work [57])
//! comparison.
//!
//! Paper claims reproduced:
//! * "almost the same final test accuracy with p_init from 2 to 5";
//!   p_init = 8 degrades 0.5% ~ 1.0%.
//! * robust to K_s from 500 to 1500 (of 4000).
//! * the 0.7/1.3 thresholds need only be "slightly" off 1 — we sweep the
//!   band width as the design-choice ablation DESIGN.md §4 calls out.
//!
//! ```text
//! cargo run --release --example ablation_study -- [--quick] [--out results]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::ablation::ablation;
use adpsgd::figures::{cifar_base, googlenet_role, Scale, Sink};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    let mut base = cifar_base(scale);
    googlenet_role(&mut base, scale);
    let a = ablation(&base, scale, &sink)?;

    println!("shape checks:");
    let small: Vec<f64> =
        a.p_init.iter().filter(|r| !r.label.contains('8')).map(|r| r.best_acc).collect();
    let spread =
        small.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - small.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "  p_init 2..5 accuracies within a point:  spread {:.4} -> {}",
        spread,
        ok(spread < 0.02)
    );
    let ks_spread = a.k_s.iter().map(|r| r.best_acc).fold(f64::NEG_INFINITY, f64::max)
        - a.k_s.iter().map(|r| r.best_acc).fold(f64::INFINITY, f64::min);
    println!(
        "  K_s sweep accuracies within a point:    spread {:.4} -> {}",
        ks_spread,
        ok(ks_spread < 0.02)
    );
    let adp = a.easgd.last().unwrap();
    let best_easgd =
        a.easgd[..a.easgd.len() - 1].iter().map(|r| r.best_acc).fold(f64::NEG_INFINITY, f64::max);
    println!(
        "  ADPSGD >= best EASGD accuracy:          {:.4} vs {:.4} -> {}",
        adp.best_acc,
        best_easgd,
        ok(adp.best_acc >= best_easgd - 0.01)
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
