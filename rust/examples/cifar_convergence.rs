//! Figures 4, 5 + Table I: the CIFAR-geometry convergence comparison of
//! FULLSGD / CPSGD(p=8) / ADPSGD / QSGD on the compute-heavy
//! (GoogLeNet-role) and communication-heavy (VGG16-role) workloads,
//! plus the 4c/5c computation/communication split at both bandwidths.
//!
//! ```text
//! cargo run --release --example cifar_convergence -- [--quick] [--out results]
//! cargo run --release --example cifar_convergence -- --table1 [--quick]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::convergence::{convergence, time_split, Role};
use adpsgd::figures::{cifar_base, googlenet_role, table1::table1, Scale, Sink};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick", "table1"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    if args.flag("table1") {
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        let t = table1(&base, scale, &sink)?;
        let adp = t.get("ADPSGD");
        let cps = t.get("CPSGD");
        let small = t.get("SMALL_BATCH");
        println!("shape checks:");
        println!(
            "  ADPSGD >= CPSGD best-sweep acc:   {:.4} vs {:.4} -> {}",
            adp.best_acc,
            cps.best_acc,
            ok(adp.best_acc >= cps.best_acc - 0.01)
        );
        println!(
            "  SMALL_BATCH is the ceiling:       {:.4} -> {}",
            small.best_acc,
            ok(small.best_acc + 0.02 >= adp.best_acc)
        );
        return Ok(());
    }

    for role in [Role::GoogLeNet, Role::Vgg16] {
        let conv = convergence(role, scale, &sink)?;
        let rows = time_split(&conv, &sink);

        let full = conv.fullsgd();
        let adp = conv.adpsgd();
        let cps = conv.cpsgd();
        let qsgd = conv.qsgd();
        println!("shape checks ({}):", role.figure());
        println!(
            "  ADPSGD loss <= CPSGD loss:        {:.4} vs {:.4} -> {}",
            adp.final_train_loss,
            cps.final_train_loss,
            ok(adp.final_train_loss <= cps.final_train_loss * 1.1)
        );
        println!(
            "  ADPSGD acc >= CPSGD acc:          {:.4} vs {:.4} -> {}",
            adp.best_eval_acc,
            cps.best_eval_acc,
            ok(adp.best_eval_acc >= cps.best_eval_acc - 0.01)
        );
        println!(
            "  ADPSGD wire ~ 1/2 of QSGD:        {:.1} MB vs {:.1} MB -> {}",
            adp.ledger.total_wire_bytes() as f64 / 1e6,
            qsgd.ledger.total_wire_bytes() as f64 / 1e6,
            ok(adp.ledger.total_wire_bytes() < qsgd.ledger.total_wire_bytes())
        );
        let (a100, a10) = (rows[2].comm_100g, rows[2].comm_10g);
        let (f100, f10) = (rows[0].comm_100g, rows[0].comm_10g);
        println!(
            "  ADPSGD comm < FULLSGD comm:       @100G {:.2}s<{:.2}s, @10G {:.2}s<{:.2}s -> {}",
            a100,
            f100,
            a10,
            f10,
            ok(a100 < f100 && a10 < f10)
        );
        let _ = full;
        println!();
    }
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
