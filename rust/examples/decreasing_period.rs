//! §V-B: the decreasing-period strawman (Wang & Joshi-style: large
//! period first, small later) at the same communication budget as
//! CPSGD p=8 — the paper shows it converges an order of magnitude worse,
//! validating that early synchronization matters most.
//!
//! ```text
//! cargo run --release --example decreasing_period -- [--quick] [--out results]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::decreasing::decreasing_study;
use adpsgd::figures::{cifar_base, googlenet_role, vgg_role, Scale, Sink};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    // §III-A, analytically: the paper's four strategies evaluated with
    // the convergence bound (8) + (10) — the theory behind the figure
    println!("§III-A — analytic bound (8)+(10) per strategy:");
    let assumptions = adpsgd::analysis::Assumptions { l: 0.1, ..Default::default() };
    let mut t = adpsgd::metrics::Table::new(&["strategy", "variance term", "total bound", "syncs"]);
    for (label, bound, syncs) in adpsgd::analysis::section3a_strategies(&assumptions) {
        match bound {
            Some(b) => t.row(&[
                label,
                format!("{:.4e}", b.variance_term),
                format!("{:.4e}", b.total()),
                syncs.to_string(),
            ]),
            None => t.row(&[label, "n/a (improper p)".into(), "-".into(), "-".into()]),
        }
    }
    println!("{}", t.render());

    for (name, role_fn) in [
        ("googlenet-role", googlenet_role as fn(&mut _, Scale)),
        ("vgg-role", vgg_role as fn(&mut _, Scale)),
    ] {
        println!("=== {name} ===");
        let mut base = cifar_base(scale);
        role_fn(&mut base, scale);
        let s = decreasing_study(&base, &sink)?;

        println!("shape checks:");
        let budget_ratio = s.decreasing.syncs as f64 / s.cpsgd8.syncs as f64;
        println!(
            "  matched comm budget (20-then-5 vs p=8): {} vs {} syncs ({:.2}) -> {}",
            s.decreasing.syncs,
            s.cpsgd8.syncs,
            budget_ratio,
            ok((budget_ratio - 1.0).abs() < 0.05)
        );
        println!(
            "  decreasing-loss > adpsgd-loss:          {:.4} vs {:.4} -> {}",
            s.decreasing.final_train_loss,
            s.adpsgd.final_train_loss,
            ok(s.decreasing.final_train_loss > s.adpsgd.final_train_loss)
        );
        println!(
            "  decreasing-acc < adpsgd-acc:            {:.4} vs {:.4} -> {}",
            s.decreasing.best_eval_acc,
            s.adpsgd.best_eval_acc,
            ok(s.decreasing.best_eval_acc <= s.adpsgd.best_eval_acc + 0.005)
        );
        println!();
    }
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
