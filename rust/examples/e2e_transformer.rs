//! End-to-end driver: the FULL three-layer stack on a real workload.
//!
//! Trains a character-level transformer LM (L2 JAX model, L1 Pallas
//! kernels, AOT-lowered to HLO by `make artifacts`) on a synthetic tiny
//! corpus, executed from rust through PJRT (L3 coordinator + runtime) on
//! multiple simulated nodes, with ADPSGD vs FULLSGD — proving every
//! layer composes with python nowhere on the training path.
//!
//! ```text
//! make artifacts
//! cargo run --release --example e2e_transformer -- [--model txf_tiny]
//!     [--nodes 4] [--iters 300] [--out results]
//! ```
//!
//! The loss curve and the run summary are recorded in EXPERIMENTS.md §E2E.

use adpsgd::cli::Args;
use adpsgd::config::{Backend, ExperimentConfig, LrSchedule};
use adpsgd::experiment::Experiment;
use adpsgd::metrics::Table;
use adpsgd::period::Strategy;
use anyhow::{Context, Result};

fn main() -> Result<()> {
    let args = Args::parse_env(&[])?;
    let model = args.get_or("model", "txf_tiny").to_string();
    let nodes = args.get_usize("nodes", 4)?;
    let iters = args.get_usize("iters", 300)?;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    // verify artifacts exist up front with a friendly message
    let man = adpsgd::runtime::Manifest::load(&artifacts)
        .context("artifacts missing — run `make artifacts` first")?;
    let spec = man.get(&model)?;
    println!(
        "e2e: {model} ({} params, batch {}, seq {}, vocab {}) on {nodes} nodes x {iters} iters",
        spec.param_count, spec.batch, spec.seq, spec.vocab
    );

    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("e2e_{model}");
    cfg.nodes = nodes;
    cfg.iters = iters;
    cfg.eval_every = (iters / 10).max(1);
    cfg.workload.backend = Backend::Hlo(model.clone());
    cfg.workload.eval_batches = 4;
    cfg.artifacts_dir = artifacts;
    cfg.optim.lr0 = 0.05;
    cfg.optim.schedule = LrSchedule::StepDecay { boundaries: vec![3 * iters / 4], factor: 0.1 };
    cfg.sync.warmup_iters = iters / 20;
    cfg.sync.p_init = 2;
    cfg.sync.ks_frac = 0.2;

    let mut table =
        Table::new(&["strategy", "first loss", "final loss", "Δ", "eval loss", "syncs", "p̄"]);
    for strategy in [Strategy::Adaptive, Strategy::Full] {
        let mut c = cfg.clone();
        c.sync.strategy = strategy;
        let report = Experiment::from_config(c)?.run()?;

        let loss = report.recorder.get("train_loss").context("loss series missing")?;
        let first = loss.points.first().map(|p| p.1).unwrap_or(f64::NAN);
        let last = report.final_train_loss;
        println!("\n--- {strategy} loss curve (train, char-LM xent) ---");
        let mut named = loss.clone();
        named.name = format!("{strategy}");
        println!(
            "{}",
            adpsgd::metrics::plot::render(
                &[&named],
                &adpsgd::metrics::plot::PlotCfg {
                    title: format!("{strategy} train loss"),
                    height: 12,
                    ..Default::default()
                }
            )
        );
        table.row(&[
            strategy.to_string(),
            format!("{first:.4}"),
            format!("{last:.4}"),
            format!("{:+.4}", last - first),
            format!("{:.4}", report.final_eval_loss),
            report.syncs.to_string(),
            format!("{:.2}", report.avg_period),
        ]);
        if let Some(dir) = args.get("out") {
            report.recorder.write_csvs(std::path::Path::new(dir), &format!("e2e_{strategy}"))?;
        }

        anyhow::ensure!(
            last < first,
            "{strategy}: loss did not decrease ({first:.4} -> {last:.4})"
        );
    }
    println!("\n{}", table.render());
    println!("all layers composed: Pallas kernels -> JAX HLO -> PJRT -> rust coordinator  OK");
    Ok(())
}
