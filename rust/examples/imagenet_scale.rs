//! Figures 7 + 8: the ImageNet-geometry experiments — gradual-warmup LR
//! schedule (linear-scaling rule), periodic averaging engaged only after
//! the warmup epochs, K_s = 0.2K — on the ResNet50-role (compute-heavy)
//! and AlexNet-role (comm-heavy) workloads.
//!
//! ```text
//! cargo run --release --example imagenet_scale -- [--quick] [--out results]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::convergence::{convergence, time_split, Role};
use adpsgd::figures::{Scale, Sink};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    for role in [Role::ResNet50, Role::AlexNet] {
        let conv = convergence(role, scale, &sink)?;
        let rows = time_split(&conv, &sink);

        let adp = conv.adpsgd();
        let cps = conv.cpsgd();

        // paper headline: 1.27x (ResNet50) / up to 1.95x (10G) speedups
        let s100 = (rows[0].compute_secs + rows[0].comm_100g)
            / (rows[2].compute_secs + rows[2].comm_100g).max(1e-12);
        let s10 = (rows[0].compute_secs + rows[0].comm_10g)
            / (rows[2].compute_secs + rows[2].comm_10g).max(1e-12);
        println!("shape checks ({}):", role.figure());
        println!(
            "  ADPSGD speedup vs FULLSGD:        {:.2}x @100G, {:.2}x @10G -> {}",
            s100,
            s10,
            ok(s100 > 1.0 && s10 > s100)
        );
        println!(
            "  ADPSGD acc >= CPSGD acc:          {:.4} vs {:.4}          -> {}",
            adp.best_eval_acc,
            cps.best_eval_acc,
            ok(adp.best_eval_acc >= cps.best_eval_acc - 0.01)
        );
        println!(
            "  warmup keeps p̄ moderate:          p̄ = {:.2}               -> {}",
            adp.avg_period,
            ok(adp.avg_period > 1.0)
        );
        println!();
    }
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
