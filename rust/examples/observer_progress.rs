//! Session API tour: a custom [`RunObserver`] rendering live progress
//! from the coordinator's typed event stream, plus a custom
//! [`PeriodController`] injected past the registry.
//!
//! ```text
//! cargo run --release --example observer_progress -- [--nodes 8] [--iters 600]
//! cargo run --release --example observer_progress -- --controller cosine
//! ```

use adpsgd::cli::Args;
use adpsgd::config::{LrSchedule, StrategySpec};
use adpsgd::experiment::{Experiment, RunEvent, RunObserver};
use adpsgd::period::PeriodController;
use anyhow::Result;

/// Prints one status line per loss-agreement window, straight off the
/// event stream — no polling, no recorder post-processing.
struct Progress {
    iters: usize,
    syncs: usize,
    last_period: usize,
}

impl RunObserver for Progress {
    fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        match ev {
            RunEvent::RunStart { cfg, n_params, resume_iter } => {
                println!(
                    "run {} | {} nodes × {} iters | {} params | resume@{}",
                    cfg.name, cfg.nodes, cfg.iters, n_params, resume_iter
                );
            }
            RunEvent::SyncDone { period, .. } => {
                self.syncs += 1;
                self.last_period = *period;
            }
            RunEvent::IterEnd { k, lr, loss: Some(loss) } => {
                println!(
                    "  k={k:>5}/{} loss={loss:.4} lr={lr:.4} syncs={} p={}",
                    self.iters, self.syncs, self.last_period
                );
            }
            RunEvent::EvalDone { k, loss, acc } => {
                println!("  k={k:>5} eval: loss={loss:.4} acc={acc:.4}");
            }
            RunEvent::RunEnd { .. } => println!("done: {} syncs total", self.syncs),
            _ => {}
        }
        Ok(())
    }
}

/// A schedule the registry does not know: period follows a slow cosine
/// between 2 and 10 — demonstrating that *any* `PeriodController` can
/// drive the pipeline without touching the coordinator.
struct CosinePeriod {
    total: usize,
    cnt: usize,
    p: usize,
}

impl CosinePeriod {
    fn new(total: usize) -> Self {
        CosinePeriod { total, cnt: 0, p: 2 }
    }
}

impl PeriodController for CosinePeriod {
    fn should_sync(&mut self, k: usize) -> bool {
        let phase = (k as f64 / self.total.max(1) as f64) * std::f64::consts::PI;
        self.p = (6.0 - 4.0 * phase.cos()).round() as usize; // 2 -> 10
        self.cnt += 1;
        if self.cnt >= self.p.max(1) {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, _k: usize, _s_k: f64, _lr: f32) {}

    fn current_period(&self) -> usize {
        self.p
    }

    fn name(&self) -> &'static str {
        "cosine"
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env(&[])?;
    let nodes = args.get_usize("nodes", 8)?;
    let iters = args.get_usize("iters", 600)?;
    let use_cosine = args.get("controller") == Some("cosine");

    let mut builder = Experiment::builder()
        .name("observer_demo")
        .nodes(nodes)
        .iters(iters)
        .batch_per_node(16)
        .eval_every(iters / 4)
        .strategy(StrategySpec::Adaptive {
            p_init: 4,
            warmup_iters: iters / 50,
            ks_frac: 0.25,
            low: 0.7,
            high: 1.3,
        })
        .configure(|c| {
            c.workload.input_dim = 64;
            c.workload.hidden = 32;
            c.optim.schedule = LrSchedule::Const;
        })
        .observer(Box::new(Progress { iters, syncs: 0, last_period: 0 }));
    if use_cosine {
        println!("using the injected cosine period controller\n");
        builder = builder.period_controller(move || Box::new(CosinePeriod::new(iters)));
    }

    let report = builder.build()?.run()?;
    println!(
        "\nfinal: loss={:.4} acc={:.4} syncs={} p̄={:.2}",
        report.final_train_loss, report.best_eval_acc, report.syncs, report.avg_period
    );
    Ok(())
}
