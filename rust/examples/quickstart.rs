//! Quickstart: train a small model on 8 simulated nodes with each of the
//! paper's four strategies and print the convergence/communication
//! comparison — the 60-second tour of the public API.
//!
//! ```text
//! cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --nodes 16 --iters 2000
//! cargo run --release --example quickstart -- --collective flat
//! ```

use adpsgd::cli::Args;
use adpsgd::collective::Algo;
use adpsgd::config::{Backend, ExperimentConfig, LrSchedule, NetConfig};
use adpsgd::metrics::Table;
use adpsgd::netsim::NetModel;
use adpsgd::period::Strategy;
use adpsgd::Trainer;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?; // --quick accepted (already quick)
    let nodes = args.get_usize("nodes", 8)?;
    let iters = args.get_usize("iters", if args.flag("quick") { 400 } else { 800 })?;
    let collective: Algo = args.get_or("collective", "ring").parse()?;

    // 1. Describe the experiment. Everything is plain data — the same
    //    struct a TOML file or the `adpsgd run` launcher produces.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.nodes = nodes;
    cfg.iters = iters;
    cfg.batch_per_node = 32;
    cfg.eval_every = iters / 10;
    cfg.workload.backend = Backend::Native("mlp".into());
    cfg.workload.input_dim = 128;
    cfg.workload.hidden = 64;
    cfg.optim.schedule =
        LrSchedule::StepDecay { boundaries: vec![iters / 2, 3 * iters / 4], factor: 0.1 };
    cfg.sync.warmup_iters = iters / 100;
    cfg.sync.collective = collective;

    println!(
        "quickstart: {} nodes x {} iters, total batch {}, {} params, {} collective\n",
        nodes,
        iters,
        cfg.total_batch(),
        "mlp(128-64-10)",
        collective
    );

    // 2. Run each strategy through the coordinator.
    let fast = NetModel::new(&NetConfig::infiniband_100g());
    let slow = NetModel::new(&NetConfig::ethernet_10g());
    let mut table = Table::new(&[
        "strategy",
        "final loss",
        "best acc",
        "syncs",
        "p̄",
        "wire MB",
        "modeled total @100G",
        "@10G",
    ]);
    // Per-iteration local compute is the same for every strategy (the
    // paper's Fig 4c shows near-equal computation bars), so model the
    // totals from one common compute baseline instead of per-run thread-
    // contention noise on this host.
    let mut common_compute: Option<f64> = None;
    let mut full_totals: Option<(f64, f64)> = None;
    for strategy in [Strategy::Full, Strategy::Constant, Strategy::Adaptive, Strategy::Qsgd] {
        let mut c = cfg.clone();
        c.sync.strategy = strategy;
        let report = Trainer::new(c)?.run()?;
        let compute = *common_compute.get_or_insert(report.compute_secs);
        let t100 = compute + report.ledger.modeled_secs(&fast);
        let t10 = compute + report.ledger.modeled_secs(&slow);
        if strategy == Strategy::Full {
            full_totals = Some((t100, t10));
        }
        let (f100, f10) = full_totals.unwrap();
        table.row(&[
            strategy.to_string(),
            format!("{:.4}", report.final_train_loss),
            format!("{:.4}", report.best_eval_acc),
            report.syncs.to_string(),
            format!("{:.2}", report.avg_period),
            format!("{:.2}", report.ledger.total_wire_bytes() as f64 / 1e6),
            format!("{} ({:.2}x)", adpsgd::util::fmt::secs(t100), f100 / t100),
            format!("{} ({:.2}x)", adpsgd::util::fmt::secs(t10), f10 / t10),
        ]);
    }
    println!("{}", table.render());
    println!("speedups are modeled on the paper's testbed (16xP100-style, α-β network model);");
    println!("ADPSGD should match/beat CPSGD accuracy with fewer syncs and beat FULLSGD time.");
    Ok(())
}
