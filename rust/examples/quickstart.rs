//! Quickstart: train a small model on 8 simulated nodes with each of the
//! paper's four strategies and print the convergence/communication
//! comparison — the 60-second tour of the public API.
//!
//! The four-strategy sweep is one declarative [`Campaign`]: a strategy
//! axis over typed specs, executed through the session API.
//!
//! ```text
//! cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --nodes 16 --iters 2000
//! cargo run --release --example quickstart -- --collective flat
//! ```

use adpsgd::cli::Args;
use adpsgd::collective::Algo;
use adpsgd::config::{Backend, ExperimentConfig, LrSchedule, NetConfig, StrategySpec};
use adpsgd::experiment::Campaign;
use adpsgd::metrics::Table;
use adpsgd::netsim::NetModel;
use adpsgd::period::Strategy;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?; // --quick accepted (already quick)
    let nodes = args.get_usize("nodes", 8)?;
    let iters = args.get_usize("iters", if args.flag("quick") { 400 } else { 800 })?;
    let collective: Algo = args.get_or("collective", "ring").parse()?;

    // 1. Describe the experiment. Everything is plain data — the same
    //    struct a TOML file or the `adpsgd run` launcher produces.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.nodes = nodes;
    cfg.iters = iters;
    cfg.batch_per_node = 32;
    cfg.eval_every = iters / 10;
    cfg.workload.backend = Backend::Native("mlp".into());
    cfg.workload.input_dim = 128;
    cfg.workload.hidden = 64;
    cfg.optim.schedule =
        LrSchedule::StepDecay { boundaries: vec![iters / 2, 3 * iters / 4], factor: 0.1 };
    cfg.sync.warmup_iters = iters / 100;
    cfg.sync.collective = collective;

    println!(
        "quickstart: {} nodes x {} iters, total batch {}, {} params, {} collective\n",
        nodes,
        iters,
        cfg.total_batch(),
        "mlp(128-64-10)",
        collective
    );

    // 2. Declare the four-strategy sweep as a campaign.  Each strategy
    //    carries exactly its own typed knobs, projected from the base.
    let report = Campaign::builder("quickstart", cfg.clone())
        .strategy("FULLSGD", StrategySpec::Full)
        .strategy("CPSGD", cfg.sync.spec_of(Strategy::Constant))
        .strategy("ADPSGD", cfg.sync.spec_of(Strategy::Adaptive))
        .strategy("QSGD", cfg.sync.spec_of(Strategy::Qsgd))
        .build()?
        .run()?;

    // 3. Re-price each run's comm ledger under both bandwidth presets.
    let fast = NetModel::new(&NetConfig::infiniband_100g());
    let slow = NetModel::new(&NetConfig::ethernet_10g());
    let mut table = Table::new(&[
        "strategy",
        "final loss",
        "best acc",
        "syncs",
        "p̄",
        "wire MB",
        "modeled total @100G",
        "@10G",
    ]);
    // Per-iteration local compute is the same for every strategy (the
    // paper's Fig 4c shows near-equal computation bars), so model the
    // totals from one common compute baseline instead of per-run thread-
    // contention noise on this host.
    let compute = report.get("FULLSGD").compute_secs;
    let mut full_totals: Option<(f64, f64)> = None;
    for run in &report.runs {
        let r = &run.report;
        let t100 = compute + r.ledger.modeled_secs(&fast);
        let t10 = compute + r.ledger.modeled_secs(&slow);
        let (f100, f10) = *full_totals.get_or_insert((t100, t10));
        table.row(&[
            run.label.clone(),
            format!("{:.4}", r.final_train_loss),
            format!("{:.4}", r.best_eval_acc),
            r.syncs.to_string(),
            format!("{:.2}", r.avg_period),
            format!("{:.2}", r.ledger.total_wire_bytes() as f64 / 1e6),
            format!("{} ({:.2}x)", adpsgd::util::fmt::secs(t100), f100 / t100),
            format!("{} ({:.2}x)", adpsgd::util::fmt::secs(t10), f10 / t10),
        ]);
    }
    println!("{}", table.render());
    println!("speedups are modeled on the paper's testbed (16xP100-style, α-β network model);");
    println!("ADPSGD should match/beat CPSGD accuracy with fewer syncs and beat FULLSGD time.");
    Ok(())
}
