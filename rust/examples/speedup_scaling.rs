//! Figure 6: speedups of distributed FULLSGD / ADPSGD over single-node
//! vanilla SGD for n ∈ {2,4,8,16} nodes at 100Gbps and 10Gbps, for both
//! model roles (compute-heavy GoogLeNet-role, comm-heavy VGG-role).
//!
//! ```text
//! cargo run --release --example speedup_scaling -- [--quick] [--out results]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::speedup::{fig6, straggler_panel};
use adpsgd::figures::{cifar_base, googlenet_role, vgg_role, Scale, Sink};
use adpsgd::period::Strategy;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    let mut g = cifar_base(scale);
    googlenet_role(&mut g, scale);
    let fg = fig6("googlenet-role", &g, scale, &sink)?;

    let mut v = cifar_base(scale);
    vgg_role(&mut v, scale);
    let fv = fig6("vgg-role", &v, scale, &sink)?;

    // heterogeneity ablation (not in the paper's homogeneous testbed):
    // periodic averaging also amortizes straggler waiting by ~sqrt(p)
    straggler_panel(fv.per_step_secs, v.iters, 0.2, &sink);

    println!("shape checks:");
    // paper Fig 6b: FULLSGD on the comm-heavy model collapses at 10Gbps
    // (12.77x -> 6.12x) while ADPSGD stays near-linear.
    let full16 = fv.cell(Strategy::Full, 16);
    let adp16 = fv.cell(Strategy::Adaptive, 16);
    println!(
        "  [vgg] FULLSGD@16 degrades when throttled: {:.2}x -> {:.2}x  -> {}",
        full16.speedup_100g,
        full16.speedup_10g,
        ok(full16.speedup_10g < full16.speedup_100g)
    );
    println!(
        "  [vgg] ADPSGD@16 beats FULLSGD@16 at 10G: {:.2}x vs {:.2}x  -> {}",
        adp16.speedup_10g,
        full16.speedup_10g,
        ok(adp16.speedup_10g > full16.speedup_10g)
    );
    println!(
        "  [vgg] ADPSGD near-linear at 16 nodes:    {:.2}x / 16       -> {}",
        adp16.speedup_100g,
        ok(adp16.speedup_100g > 12.0)
    );
    // compute-heavy model: FULLSGD is acceptable, ADPSGD still >= FULLSGD
    let gfull16 = fg.cell(Strategy::Full, 16);
    let gadp16 = fg.cell(Strategy::Adaptive, 16);
    println!(
        "  [googlenet] ADPSGD >= FULLSGD @100G:     {:.2}x vs {:.2}x  -> {}",
        gadp16.speedup_100g,
        gfull16.speedup_100g,
        ok(gadp16.speedup_100g >= gfull16.speedup_100g * 0.99)
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
