//! Figures 1–3: the variance statistics that motivate adaptive periods.
//!
//! * Fig 1 — `V_t` of CPSGD for p ∈ {2,4,5,8}: large initial variance,
//!   ∝ γ², drops at each LR decay.
//! * Fig 2 — `V_t` of ADPSGD vs CPSGD p=8: flat early (∝ γ), slower decay.
//! * Fig 3 — ADPSGD's period trajectory (paper: 4 → 6 → 29 → 43, 498
//!   syncs ≈ effective p 8.03).
//!
//! ```text
//! cargo run --release --example variance_study -- [--quick] [--out results]
//! ```

use adpsgd::cli::Args;
use adpsgd::figures::variance::{fig1, fig2_fig3, window_mean};
use adpsgd::figures::{Scale, Sink};
use adpsgd::metrics::plot::{render, PlotCfg};
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env(&["quick"])?;
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), false);

    let f1 = fig1(scale, &sink)?;
    let f23 = fig2_fig3(scale, &sink)?;

    // terminal renderings of the actual paper panels
    {
        let mut named: Vec<adpsgd::metrics::Series> = Vec::new();
        for r in &f1.rows {
            let mut s = r.v_t.clone();
            s.name = format!("p={}", r.p);
            named.push(s);
        }
        let refs: Vec<&adpsgd::metrics::Series> = named.iter().collect();
        println!(
            "{}",
            render(&refs, &PlotCfg { log_y: true, title: "Fig 1: V_t (log)".into(), ..Default::default() })
        );
    }
    {
        let mut a = f23.adpsgd_vt.clone();
        a.name = "ADPSGD".into();
        let mut c = f23.cpsgd_vt.clone();
        c.name = "CPSGD p=8".into();
        println!(
            "{}",
            render(&[&a, &c], &PlotCfg { log_y: true, title: "Fig 2: V_t (log)".into(), ..Default::default() })
        );
        let mut p = f23.period_traj.clone();
        p.name = "period".into();
        println!(
            "{}",
            render(&[&p], &PlotCfg { title: "Fig 3: averaging period".into(), ..Default::default() })
        );
    }

    // Paper-shape checks, printed so a human reading the log sees the
    // qualitative reproduction at a glance.
    println!("shape checks:");
    let v2 = window_mean(&f1.rows[0].v_t, f1.iters, 0.05, 0.5);
    let v8 = window_mean(&f1.rows[3].v_t, f1.iters, 0.05, 0.5);
    println!("  [fig1] V_t grows with p:              p=2 {v2:.3e}  <  p=8 {v8:.3e}  -> {}",
        ok(v8 > v2));
    let early = window_mean(&f1.rows[3].v_t, f1.iters, 0.05, 0.5);
    let late = window_mean(&f1.rows[3].v_t, f1.iters, 0.75, 1.0);
    println!("  [fig1] V_t drops after LR decay:      {early:.3e} -> {late:.3e}          -> {}",
        ok(late < early));
    let a_early = window_mean(&f23.adpsgd_vt, f23.iters, 0.02, 0.5);
    let c_early = window_mean(&f23.cpsgd_vt, f23.iters, 0.02, 0.5);
    println!("  [fig2] ADPSGD early V_t < CPSGD p=8:  {a_early:.3e} < {c_early:.3e}      -> {}",
        ok(a_early < c_early));
    let p_first = f23.period_traj.points.first().map(|p| p.1).unwrap_or(f64::NAN);
    let p_last = f23.period_traj.last_y().unwrap_or(f64::NAN);
    println!("  [fig3] period grows ({p_first:.0} -> {p_last:.0}), {} syncs, p̄={:.2}      -> {}",
        f23.adpsgd.syncs, f23.adpsgd.avg_period, ok(p_last >= p_first));
    println!("  [fig3] ADPSGD comm <= CPSGD p=8 comm: {} vs {} syncs             -> {}",
        f23.adpsgd.syncs, f23.cpsgd8.syncs,
        ok(f23.adpsgd.syncs as f64 <= 1.15 * f23.cpsgd8.syncs as f64));
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
