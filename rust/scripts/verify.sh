#!/usr/bin/env bash
# Tier-1 verification: build + full test suite + one quickstart smoke run
# under each collective algorithm.  Referenced from ROADMAP.md; CI and
# pre-merge checks should run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== verify: cargo build --release =="
cargo build --release

echo "== verify: cargo test -q =="
cargo test -q

for algo in flat ring; do
    echo "== verify: quickstart smoke run (collective = ${algo}) =="
    cargo run --release --example quickstart -- --quick --iters 200 --nodes 4 --collective "${algo}"
done

echo "== verify: OK =="
