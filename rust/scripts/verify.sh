#!/usr/bin/env bash
# Tier-1 verification: build + full test suite + examples build + one
# quickstart smoke run under each collective algorithm + a campaign
# smoke sweep (strategy × collective) + the campaign-scheduler bench
# (emits BENCH_campaign.json for the perf trajectory).  Referenced from
# ROADMAP.md; CI and pre-merge checks should run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== verify: cargo build --release =="
cargo build --release

echo "== verify: cargo build --release --examples =="
cargo build --release --examples

echo "== verify: cargo test -q =="
cargo test -q

for algo in flat ring; do
    echo "== verify: quickstart smoke run (collective = ${algo}) =="
    cargo run --release --example quickstart -- --quick --iters 200 --nodes 4 --collective "${algo}"
done

echo "== verify: campaign smoke sweep (strategy x collective) =="
cargo run --release -- campaign --quick --name verify_campaign --parallel 2 --out /tmp/adpsgd_verify

echo "== verify: campaign scheduler bench (fast) =="
ADPSGD_BENCH_FAST=1 cargo bench --bench bench_campaign

echo "== verify: OK =="
