#!/usr/bin/env bash
# Tier-1 verification: build + full test suite + examples build + one
# quickstart smoke run under each collective algorithm + a campaign
# smoke sweep (strategy × collective) + a cold-vs-warm run-cache smoke
# (the second invocation must be answered from the cache and write a
# byte-identical summary) + a cache-gc smoke (size-bound eviction must
# shrink the warm cache, previewed by --dry-run) + a hang smoke (a
# SIGSTOPped subprocess worker must be recovered under the heartbeat
# deadline) + a remote-agent loopback smoke (a campaign dispatched to a
# local `adpsgd agent` must write a byte-identical stable summary, and
# a warm agent must answer the re-run from its own cache) + a
# kernel-parallelism smoke (the same campaign under perf.threads=1 and
# perf.threads=4 must write byte-identical stable summaries — the
# tensor::par reductions are bit-identical at any thread count) + a
# fleet smoke (a registry plus two loopback agents resolved via
# --fleet, one restarted mid-campaign, must write a byte-identical
# stable summary, and a wrong shared-secret token must be rejected) +
# an obs smoke (a journaled loopback-fleet campaign must write a
# schema-valid event journal whose trace ids reach the agent's own log,
# `adpsgd status` must report the advertised slots, and a --no-journal
# rerun must write a byte-identical stable summary) + a trace smoke
# (the agent's streamed observer events must land in the journal tagged
# with their origin, `adpsgd trace` must name every run of the campaign
# with a per-node attribution, and its --emit-cluster block must drive
# a real run as a config overlay) + a robustness
# smoke (the 5-strategy heterogeneity sweep — skew, faults, both
# network presets — must write a byte-identical stable summary across
# --jobs levels and cold/warm cache) +
# the campaign/dispatch benches (emit BENCH_campaign.json /
# BENCH_dispatch.json for the perf trajectory).  Referenced from
# ROADMAP.md; CI and pre-merge checks should run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== verify: cargo build --release =="
cargo build --release

echo "== verify: cargo build --release --examples =="
cargo build --release --examples

echo "== verify: cargo test -q =="
cargo test -q

for algo in flat ring; do
    echo "== verify: quickstart smoke run (collective = ${algo}) =="
    cargo run --release --example quickstart -- --quick --iters 200 --nodes 4 --collective "${algo}"
done

echo "== verify: campaign smoke sweep (strategy x collective) =="
cargo run --release -- campaign --quick --name verify_campaign --jobs 2 --out /tmp/adpsgd_verify

echo "== verify: run-cache cold/warm smoke =="
CACHE_DIR=/tmp/adpsgd_verify_cache
rm -rf "${CACHE_DIR}" /tmp/adpsgd_verify_cold /tmp/adpsgd_verify_warm
cargo run --release -- campaign --quick --name cache_smoke --jobs 4 \
    --cache-dir "${CACHE_DIR}" --out /tmp/adpsgd_verify_cold | tee /tmp/adpsgd_verify_cold.log
cargo run --release -- campaign --quick --name cache_smoke --jobs 4 \
    --cache-dir "${CACHE_DIR}" --out /tmp/adpsgd_verify_warm | tee /tmp/adpsgd_verify_warm.log
# the warm pass must be answered entirely from the cache (the quick
# sweep is 4 strategies x 2 collectives = 8 runs) ...
grep -q "8 cache hits" /tmp/adpsgd_verify_warm.log \
    || { echo "verify: FAIL — warm campaign did not hit the cache on all 8 runs"; exit 1; }
# ... and produce a byte-identical summary
cmp /tmp/adpsgd_verify_cold/cache_smoke.campaign.json /tmp/adpsgd_verify_warm/cache_smoke.campaign.json \
    || { echo "verify: FAIL — cold/warm campaign summaries differ"; exit 1; }
echo "   cache smoke OK (8/8 hits, byte-identical summary)"

echo "== verify: cache-gc smoke (dry-run preview, then real) =="
# the warm cache above holds 8 entries; a 1-byte bound must evict them all
entries_before=$(find "${CACHE_DIR}" -name '*.run.json' | wc -l)
[ "${entries_before}" -eq 8 ] \
    || { echo "verify: FAIL — expected 8 cache entries before gc, found ${entries_before}"; exit 1; }
cargo run --release -- cache-gc --cache-dir "${CACHE_DIR}" --max-bytes 1 --dry-run \
    | tee /tmp/adpsgd_verify_gc_dry.log
grep -q "8 would be evicted" /tmp/adpsgd_verify_gc_dry.log \
    || { echo "verify: FAIL — dry run did not plan all 8 evictions"; exit 1; }
entries_dry=$(find "${CACHE_DIR}" -name '*.run.json' | wc -l)
[ "${entries_dry}" -eq 8 ] \
    || { echo "verify: FAIL — --dry-run deleted entries (${entries_dry} left)"; exit 1; }
cargo run --release -- cache-gc --cache-dir "${CACHE_DIR}" --max-bytes 1
entries_after=$(find "${CACHE_DIR}" -name '*.run.json' | wc -l)
[ "${entries_after}" -eq 0 ] \
    || { echo "verify: FAIL — cache-gc left ${entries_after} entries above the size bound"; exit 1; }
echo "   cache-gc smoke OK (${entries_before} -> ${entries_after} entries, dry-run previewed)"

echo "== verify: kernel-parallelism smoke (perf.threads 1 vs 4) =="
# --no-cache so both passes really execute: the comparison must witness
# the parallel kernels reproducing the serial results bit-for-bit, not a
# cache answering the second pass
rm -rf /tmp/adpsgd_verify_t1 /tmp/adpsgd_verify_t4
cargo run --release -- campaign --quick --name threads_smoke --jobs 2 --no-cache \
    --perf.threads 1 --out /tmp/adpsgd_verify_t1
cargo run --release -- campaign --quick --name threads_smoke --jobs 2 --no-cache \
    --perf.threads 4 --out /tmp/adpsgd_verify_t4
cmp /tmp/adpsgd_verify_t1/threads_smoke.campaign.json \
    /tmp/adpsgd_verify_t4/threads_smoke.campaign.json \
    || { echo "verify: FAIL — perf.threads changed results (reductions must be bit-identical)"; exit 1; }
echo "   threads smoke OK (perf.threads 1 and 4 summaries byte-identical)"

echo "== verify: subprocess-worker smoke (tight hang deadline) =="
cargo run --release -- campaign --quick --name worker_smoke --jobs 2 --workers subprocess \
    --hang-timeout 30 \
    --strategies cpsgd,adpsgd --collectives ring --out /tmp/adpsgd_verify

echo "== verify: hang smoke (stopped worker recovered under deadline) =="
cargo test --release --test integration_dispatch stopped_worker_is_declared_hung_and_run_retried

echo "== verify: remote-agent loopback smoke =="
AGENT_CACHE=/tmp/adpsgd_verify_agent_cache
AGENT_LOG=/tmp/adpsgd_verify_agent.log
rm -rf "${AGENT_CACHE}" "${AGENT_LOG}" \
    /tmp/adpsgd_verify_remote_local /tmp/adpsgd_verify_remote /tmp/adpsgd_verify_remote2
./target/release/adpsgd agent --listen 127.0.0.1:0 --slots 2 --token verify-secret \
    --cache-dir "${AGENT_CACHE}" > "${AGENT_LOG}" 2>&1 &
AGENT_PID=$!
trap 'kill "${AGENT_PID}" 2>/dev/null || true' EXIT
for _ in $(seq 50); do
    grep -q "agent: listening on" "${AGENT_LOG}" && break
    sleep 0.2
done
AGENT_ADDR=$(sed -n 's/^agent: listening on \([^ ]*\).*/\1/p' "${AGENT_LOG}" | head -n1)
[ -n "${AGENT_ADDR}" ] \
    || { echo "verify: FAIL — agent did not announce its address"; cat "${AGENT_LOG}"; exit 1; }
# the same 8-run quick campaign, locally and through the loopback agent:
# the stable summaries must be byte-identical
cargo run --release -- campaign --quick --name remote_smoke --jobs 4 \
    --no-cache --out /tmp/adpsgd_verify_remote_local
cargo run --release -- campaign --quick --name remote_smoke --workers remote \
    --remote "${AGENT_ADDR}" --remote-token verify-secret \
    --no-cache --out /tmp/adpsgd_verify_remote
cmp /tmp/adpsgd_verify_remote_local/remote_smoke.campaign.json \
    /tmp/adpsgd_verify_remote/remote_smoke.campaign.json \
    || { echo "verify: FAIL — remote and local stable summaries differ"; exit 1; }
# a warm agent answers the re-run from its own cache (8/8 hits in its log)
cargo run --release -- campaign --quick --name remote_smoke --workers remote \
    --remote "${AGENT_ADDR}" --remote-token verify-secret \
    --no-cache --out /tmp/adpsgd_verify_remote2
agent_hits=$(grep -c "answered from cache" "${AGENT_LOG}" || true)
[ "${agent_hits}" -ge 8 ] \
    || { echo "verify: FAIL — warm agent served ${agent_hits}/8 runs from its cache"; cat "${AGENT_LOG}"; exit 1; }
cmp /tmp/adpsgd_verify_remote/remote_smoke.campaign.json \
    /tmp/adpsgd_verify_remote2/remote_smoke.campaign.json \
    || { echo "verify: FAIL — warm-agent re-run summary differs"; exit 1; }
kill "${AGENT_PID}" 2>/dev/null || true
trap - EXIT
echo "   remote-agent smoke OK (byte-identical summary, ${agent_hits}/8 agent cache hits)"

echo "== verify: fleet smoke (registry discovery, mid-run agent restart) =="
FLEET_DIR=/tmp/adpsgd_verify_fleet
rm -rf "${FLEET_DIR}"
mkdir -p "${FLEET_DIR}"
./target/release/adpsgd registry --listen 127.0.0.1:0 > "${FLEET_DIR}/registry.log" 2>&1 &
REGISTRY_PID=$!
trap 'kill "${REGISTRY_PID}" "${FLEET_A_PID:-}" "${FLEET_B_PID:-}" 2>/dev/null || true' EXIT
for _ in $(seq 50); do
    grep -q "registry: listening on" "${FLEET_DIR}/registry.log" && break
    sleep 0.2
done
REG_ADDR=$(sed -n 's/^registry: listening on \([^ ]*\).*/\1/p' "${FLEET_DIR}/registry.log" | head -n1)
[ -n "${REG_ADDR}" ] \
    || { echo "verify: FAIL — registry did not announce its address"; cat "${FLEET_DIR}/registry.log"; exit 1; }
start_fleet_agent() { # $1 = listen addr, $2 = log file (appended: restarts share it)
    ./target/release/adpsgd agent --listen "$1" --slots 2 --token fleet-secret \
        --fleet "${REG_ADDR}" >> "$2" 2>&1 &
}
start_fleet_agent 127.0.0.1:0 "${FLEET_DIR}/agent_a.log"
FLEET_A_PID=$!
start_fleet_agent 127.0.0.1:0 "${FLEET_DIR}/agent_b.log"
FLEET_B_PID=$!
for _ in $(seq 50); do
    grep -q "agent: listening on" "${FLEET_DIR}/agent_b.log" && break
    sleep 0.2
done
FLEET_B_ADDR=$(sed -n 's/^agent: listening on \([^ ]*\).*/\1/p' "${FLEET_DIR}/agent_b.log" | head -n1)
[ -n "${FLEET_B_ADDR}" ] \
    || { echo "verify: FAIL — fleet agent B did not announce its address"; cat "${FLEET_DIR}/agent_b.log"; exit 1; }
# the same quick campaign locally and with membership resolved through
# the registry alone (no --remote list): summaries must be byte-identical
cargo run --release -- campaign --quick --name fleet_smoke --jobs 2 \
    --no-cache --out "${FLEET_DIR}/local"
cargo run --release -- campaign --quick --name fleet_smoke --workers remote \
    --fleet "${REG_ADDR}" --remote-token fleet-secret \
    --no-cache --out "${FLEET_DIR}/fleet" &
CAMPAIGN_PID=$!
# restart agent B as soon as it starts executing: redial-with-backoff
# must let the campaign finish on capacity that died and came back
for _ in $(seq 200); do
    grep -q "agent: run .* started" "${FLEET_DIR}/agent_b.log" && break
    kill -0 "${CAMPAIGN_PID}" 2>/dev/null || break
    sleep 0.05
done
if grep -q "agent: run .* started" "${FLEET_DIR}/agent_b.log"; then
    kill "${FLEET_B_PID}" 2>/dev/null || true
    start_fleet_agent "${FLEET_B_ADDR}" "${FLEET_DIR}/agent_b.log"
    FLEET_B_PID=$!
    RESTARTED="restarted mid-run"
else
    RESTARTED="no restart (campaign finished first)"
fi
wait "${CAMPAIGN_PID}" \
    || { echo "verify: FAIL — fleet campaign did not survive the restart"; cat "${FLEET_DIR}/agent_b.log"; exit 1; }
cmp "${FLEET_DIR}/local/fleet_smoke.campaign.json" "${FLEET_DIR}/fleet/fleet_smoke.campaign.json" \
    || { echo "verify: FAIL — fleet and local stable summaries differ"; exit 1; }
# wrong shared secret against a token-requiring agent: the campaign must
# be rejected loudly (static --remote fails fast at the handshake)
if AUTH_OUT=$(cargo run --release -- campaign --quick --name auth_smoke --workers remote \
    --remote "${FLEET_B_ADDR}" --remote-token wrong-secret --no-cache \
    --out "${FLEET_DIR}/auth" 2>&1); then
    echo "verify: FAIL — a wrong --remote-token must be rejected"; exit 1
fi
echo "${AUTH_OUT}" | grep -qi "token" \
    || { echo "verify: FAIL — the auth rejection must name the token"; echo "${AUTH_OUT}"; exit 1; }
kill "${REGISTRY_PID}" "${FLEET_A_PID}" "${FLEET_B_PID}" 2>/dev/null || true
trap - EXIT
echo "   fleet smoke OK (registry-resolved summary byte-identical; agent B ${RESTARTED}; bad token rejected)"

echo "== verify: obs smoke (event journal, trace propagation, status) =="
OBS_DIR=/tmp/adpsgd_verify_obs
rm -rf "${OBS_DIR}"
mkdir -p "${OBS_DIR}"
./target/release/adpsgd registry --listen 127.0.0.1:0 > "${OBS_DIR}/registry.log" 2>&1 &
OBS_REG_PID=$!
trap 'kill "${OBS_REG_PID}" "${OBS_AGENT_PID:-}" 2>/dev/null || true' EXIT
for _ in $(seq 50); do
    grep -q "registry: listening on" "${OBS_DIR}/registry.log" && break
    sleep 0.2
done
OBS_REG=$(sed -n 's/^registry: listening on \([^ ]*\).*/\1/p' "${OBS_DIR}/registry.log" | head -n1)
[ -n "${OBS_REG}" ] \
    || { echo "verify: FAIL — obs registry did not announce its address"; cat "${OBS_DIR}/registry.log"; exit 1; }
./target/release/adpsgd agent --listen 127.0.0.1:0 --slots 2 --fleet "${OBS_REG}" \
    > "${OBS_DIR}/agent.log" 2>&1 &
OBS_AGENT_PID=$!
for _ in $(seq 50); do
    grep -q "agent: listening on" "${OBS_DIR}/agent.log" && break
    sleep 0.2
done
# a journaled loopback-fleet campaign: membership via the registry, runs
# on the loopback agent, the event journal written next to the summary
cargo run --release -- campaign --quick --name obs_smoke --workers remote \
    --fleet "${OBS_REG}" --no-cache --out "${OBS_DIR}/on"
JOURNAL="${OBS_DIR}/on/obs_smoke.campaign.jsonl"
[ -f "${JOURNAL}" ] \
    || { echo "verify: FAIL — the campaign did not write its event journal"; exit 1; }
journal_lines=$(wc -l < "${JOURNAL}")
schema_lines=$(grep -c '"schema":1' "${JOURNAL}" || true)
[ "${journal_lines}" -gt 0 ] && [ "${schema_lines}" -eq "${journal_lines}" ] \
    || { echo "verify: FAIL — journal schema marker on ${schema_lines}/${journal_lines} lines"; exit 1; }
# one run's trace id must appear on BOTH ends of the TCP hop: in the
# driver's journal and in the agent's own run-start log line
OBS_TRACE=$(sed -n 's/.*"event":"run.start".*"trace":"\([0-9a-f]*\)".*/\1/p' "${JOURNAL}" | head -n1)
[ -n "${OBS_TRACE}" ] \
    || { echo "verify: FAIL — no journaled run.start carries a trace id"; exit 1; }
grep -q "trace ${OBS_TRACE}" "${OBS_DIR}/agent.log" \
    || { echo "verify: FAIL — trace ${OBS_TRACE} never reached the agent"; cat "${OBS_DIR}/agent.log"; exit 1; }
# the status view renders fleet membership and the advertised capacity
STATUS_OUT=$(cargo run --release -- status --fleet "${OBS_REG}")
echo "${STATUS_OUT}" | grep -q "slots 2" \
    || { echo "verify: FAIL — status did not report the advertised slots"; echo "${STATUS_OUT}"; exit 1; }
# journaling is a pure observer: a --no-journal rerun writes no journal
# and a byte-identical stable summary
cargo run --release -- campaign --quick --name obs_smoke --workers remote \
    --fleet "${OBS_REG}" --no-cache --no-journal --out "${OBS_DIR}/off"
[ ! -f "${OBS_DIR}/off/obs_smoke.campaign.jsonl" ] \
    || { echo "verify: FAIL — --no-journal still wrote a journal"; exit 1; }
cmp "${OBS_DIR}/on/obs_smoke.campaign.json" "${OBS_DIR}/off/obs_smoke.campaign.json" \
    || { echo "verify: FAIL — stable summaries differ with journaling on/off"; exit 1; }
kill "${OBS_REG_PID}" "${OBS_AGENT_PID}" 2>/dev/null || true
trap - EXIT
echo "   obs smoke OK (journal schema'd, trace ${OBS_TRACE} on both ends, status sees the slots)"

echo "== verify: trace smoke (timeline analyzer over the obs journal) =="
# the campaign above streamed the agent's observer events (proto v6):
# they must sit in the merged journal tagged with their agent origin
grep -q '"origin":"agent:' "${JOURNAL}" \
    || { echo "verify: FAIL — no agent-streamed events in the journal"; exit 1; }
TRACE_OUT="${OBS_DIR}/trace.txt"
cargo run --release -- trace "${JOURNAL}" > "${TRACE_OUT}" \
    || { echo "verify: FAIL — adpsgd trace rejected the campaign journal"; exit 1; }
# every run label in the stable summary must appear in the timeline,
# and the streamed events must have produced per-node attributions
for label in $(grep -o '"label":"[^"]*"' "${OBS_DIR}/on/obs_smoke.campaign.json" \
                   | cut -d'"' -f4 | sort -u); do
    grep -qF "\"${label}\"" "${TRACE_OUT}" \
        || { echo "verify: FAIL — trace timeline is missing run ${label}"; cat "${TRACE_OUT}"; exit 1; }
done
grep -q "critical path" "${TRACE_OUT}" \
    || { echo "verify: FAIL — no run was attributed (agent events not streamed?)"; cat "${TRACE_OUT}"; exit 1; }
# --emit-cluster harvests the observed skew as a config overlay that the
# parser must accept unchanged: drive a real (tiny) run with it
CLUSTER_TOML="${OBS_DIR}/cluster.toml"
cargo run --release -- trace "${JOURNAL}" --emit-cluster > "${CLUSTER_TOML}"
grep -q '^\[cluster\]' "${CLUSTER_TOML}" && grep -q '^factors = \[' "${CLUSTER_TOML}" \
    || { echo "verify: FAIL — --emit-cluster did not print a [cluster] factors block"; cat "${CLUSTER_TOML}"; exit 1; }
N_FACTORS=$(($(tr -cd ',' < "${CLUSTER_TOML}" | wc -c) + 1))
cargo run --release -- run --config "${CLUSTER_TOML}" --nodes "${N_FACTORS}" \
    --iters 20 --batch_per_node 8 --eval_every 20 > /dev/null \
    || { echo "verify: FAIL — the emitted [cluster] block was rejected as a config overlay"; exit 1; }
echo "   trace smoke OK (origin-tagged events, all runs attributed, [cluster] factors round-trip)"

echo "== verify: robustness smoke (strategy zoo under a straggler cluster) =="
# the heterogeneity sweep: 5 strategies (adpsgd/cpsgd/adacomm/prsgd/
# dasgd) x 2 networks x 3 scenarios (uniform / skew / faults).  Run it
# cold at --jobs 4, then warm at --jobs 1: modeled clocks are
# config-declared and all [cluster] randomness is seeded, so the stable
# summary must be byte-identical across job counts and cache states.
ROBUST_DIR=/tmp/adpsgd_verify_robust
ROBUST_CACHE="${ROBUST_DIR}/cache"
rm -rf "${ROBUST_DIR}"
mkdir -p "${ROBUST_DIR}/a" "${ROBUST_DIR}/b"
cargo run --release -- figures --only robustness --quick --jobs 4 \
    --cache-dir "${ROBUST_CACHE}" --out "${ROBUST_DIR}/a"
cargo run --release -- figures --only robustness --quick --jobs 1 \
    --cache-dir "${ROBUST_CACHE}" --out "${ROBUST_DIR}/b"
cmp "${ROBUST_DIR}/a/robustness.campaign.json" "${ROBUST_DIR}/b/robustness.campaign.json" \
    || { echo "verify: FAIL — robustness summaries differ across jobs/cache states"; exit 1; }
grep -q '"label":"dasgd_eth10_faulty"' "${ROBUST_DIR}/a/robustness.campaign.json" \
    || { echo "verify: FAIL — the robustness sweep is missing its faulty DaSGD cell"; exit 1; }
echo "   robustness smoke OK (cold jobs=4 == warm jobs=1, byte-identical)"

echo "== verify: campaign scheduler bench (fast) =="
ADPSGD_BENCH_FAST=1 cargo bench --bench bench_campaign

echo "== verify: dispatch bench (fast) =="
ADPSGD_BENCH_FAST=1 cargo bench --bench bench_dispatch

echo "== verify: OK =="
