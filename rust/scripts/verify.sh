#!/usr/bin/env bash
# Tier-1 verification: build + full test suite + examples build + one
# quickstart smoke run under each collective algorithm + a campaign
# smoke sweep (strategy × collective) + a cold-vs-warm run-cache smoke
# (the second invocation must be answered from the cache and write a
# byte-identical summary) + a cache-gc smoke (size-bound eviction must
# shrink the warm cache) + a hang smoke (a SIGSTOPped subprocess
# worker must be recovered under the heartbeat deadline) + the
# campaign/dispatch benches (emit BENCH_campaign.json /
# BENCH_dispatch.json for the perf trajectory).  Referenced from
# ROADMAP.md; CI and pre-merge checks should run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== verify: cargo build --release =="
cargo build --release

echo "== verify: cargo build --release --examples =="
cargo build --release --examples

echo "== verify: cargo test -q =="
cargo test -q

for algo in flat ring; do
    echo "== verify: quickstart smoke run (collective = ${algo}) =="
    cargo run --release --example quickstart -- --quick --iters 200 --nodes 4 --collective "${algo}"
done

echo "== verify: campaign smoke sweep (strategy x collective) =="
cargo run --release -- campaign --quick --name verify_campaign --jobs 2 --out /tmp/adpsgd_verify

echo "== verify: run-cache cold/warm smoke =="
CACHE_DIR=/tmp/adpsgd_verify_cache
rm -rf "${CACHE_DIR}" /tmp/adpsgd_verify_cold /tmp/adpsgd_verify_warm
cargo run --release -- campaign --quick --name cache_smoke --jobs 4 \
    --cache-dir "${CACHE_DIR}" --out /tmp/adpsgd_verify_cold | tee /tmp/adpsgd_verify_cold.log
cargo run --release -- campaign --quick --name cache_smoke --jobs 4 \
    --cache-dir "${CACHE_DIR}" --out /tmp/adpsgd_verify_warm | tee /tmp/adpsgd_verify_warm.log
# the warm pass must be answered entirely from the cache (the quick
# sweep is 4 strategies x 2 collectives = 8 runs) ...
grep -q "8 cache hits" /tmp/adpsgd_verify_warm.log \
    || { echo "verify: FAIL — warm campaign did not hit the cache on all 8 runs"; exit 1; }
# ... and produce a byte-identical summary
cmp /tmp/adpsgd_verify_cold/cache_smoke.campaign.json /tmp/adpsgd_verify_warm/cache_smoke.campaign.json \
    || { echo "verify: FAIL — cold/warm campaign summaries differ"; exit 1; }
echo "   cache smoke OK (8/8 hits, byte-identical summary)"

echo "== verify: cache-gc smoke =="
# the warm cache above holds 8 entries; a 1-byte bound must evict them all
entries_before=$(find "${CACHE_DIR}" -name '*.run.json' | wc -l)
[ "${entries_before}" -eq 8 ] \
    || { echo "verify: FAIL — expected 8 cache entries before gc, found ${entries_before}"; exit 1; }
cargo run --release -- cache-gc --cache-dir "${CACHE_DIR}" --max-bytes 1
entries_after=$(find "${CACHE_DIR}" -name '*.run.json' | wc -l)
[ "${entries_after}" -eq 0 ] \
    || { echo "verify: FAIL — cache-gc left ${entries_after} entries above the size bound"; exit 1; }
echo "   cache-gc smoke OK (${entries_before} -> ${entries_after} entries)"

echo "== verify: subprocess-worker smoke (tight hang deadline) =="
cargo run --release -- campaign --quick --name worker_smoke --jobs 2 --workers subprocess \
    --hang-timeout 30 \
    --strategies cpsgd,adpsgd --collectives ring --out /tmp/adpsgd_verify

echo "== verify: hang smoke (stopped worker recovered under deadline) =="
cargo test --release --test integration_dispatch stopped_worker_is_declared_hung_and_run_retried

echo "== verify: campaign scheduler bench (fast) =="
ADPSGD_BENCH_FAST=1 cargo bench --bench bench_campaign

echo "== verify: dispatch bench (fast) =="
ADPSGD_BENCH_FAST=1 cargo bench --bench bench_dispatch

echo "== verify: OK =="
