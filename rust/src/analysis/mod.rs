//! The paper's convergence theory as executable math.
//!
//! §II-B/§III derive, for periodic parameter averaging SGD on an
//! L-smooth objective with gradient-variance bound σ², the convergence
//! bound (equation 8):
//!
//! ```text
//!  E[ Σ γₖ/Σγⱼ ‖∇f(w̄ₖ)‖² ]  ≤  2(f(w₀)−f*)/Σγₖ                 (opt term)
//!                             + L² · Σ γₖ·Var[Wₖ]/Σγⱼ            (variance term)
//!                             + (Σγₖ²/Σγₖ) · Lσ²/M               (noise term)
//! ```
//!
//! with the variance term bounded per (10) for a constant period p:
//!
//! ```text
//!  Σ γₖVar[Wₖ]/Σγⱼ  ≤  γ²np·C₁/(1−3γ²np²L²)
//!                     + 3γ²np²/(1−3γ²np²L²) · avg‖∇f‖²
//! ```
//!
//! This module evaluates those bounds for arbitrary piecewise
//! (γ, p) schedules — the calculator behind the paper's §III-A argument
//! that strategy-1 (small p early) dominates strategy-2 (small p late)
//! at identical communication cost, and behind ADPSGD's (13)–(15)
//! condition `Var[Wₖ] ≤ γₖC₂/M` that preserves the O(1/√(MK)) rate.

use crate::config::LrSchedule;
use crate::optim::lr_at;

/// Problem-level constants the paper's analysis assumes.
#[derive(Debug, Clone, Copy)]
pub struct Assumptions {
    /// Lipschitz-smoothness constant L
    pub l: f64,
    /// per-sample stochastic-gradient variance bound σ²
    pub sigma2: f64,
    /// total mini-batch size M = n·B
    pub m: usize,
    /// node count n
    pub n: usize,
    /// initial optimality gap f(w₀) − f(w*)
    pub f0_gap: f64,
    /// stand-in for the running average of ‖∇f‖² in (10) — decays over
    /// training; we evaluate it per segment via `grad_decay`
    pub grad_sq0: f64,
    /// multiplicative decay of `grad_sq0` per segment of the schedule
    pub grad_decay: f64,
}

impl Default for Assumptions {
    fn default() -> Self {
        Assumptions {
            l: 1.0,
            sigma2: 1.0,
            m: 512,
            n: 16,
            f0_gap: 1.0,
            grad_sq0: 1.0,
            grad_decay: 0.2,
        }
    }
}

/// One segment of a piecewise training schedule: `len` iterations at
/// learning rate `gamma` with averaging period `p`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub len: usize,
    pub gamma: f64,
    pub p: usize,
}

/// Build segments from an `LrSchedule` and a piecewise period schedule
/// ("(start, p)" pairs) over `k_total` iterations, splitting at every
/// boundary of either schedule.
pub fn segments(
    lr: &LrSchedule,
    lr0: f32,
    periods: &[(usize, usize)],
    k_total: usize,
) -> Vec<Segment> {
    assert!(!periods.is_empty() && periods[0].0 == 0);
    let mut cuts: Vec<usize> = vec![0, k_total];
    if let LrSchedule::StepDecay { boundaries, .. } | LrSchedule::Warmup { boundaries, .. } = lr {
        cuts.extend(boundaries.iter().copied().filter(|&b| b < k_total));
    }
    cuts.extend(periods.iter().map(|s| s.0).filter(|&b| b < k_total));
    cuts.sort_unstable();
    cuts.dedup();

    let period_at = |k: usize| -> usize {
        let mut p = periods[0].1;
        for &(start, pp) in periods {
            if k >= start {
                p = pp;
            }
        }
        p
    };

    cuts.windows(2)
        .map(|w| Segment {
            len: w[1] - w[0],
            gamma: lr_at(lr, lr0, w[0]) as f64,
            p: period_at(w[0]),
        })
        .collect()
}

/// Equation (10)'s bound on the γ-weighted average parameter variance
/// for one constant-(γ, p) segment.  Returns `None` when the bound's
/// denominator is non-positive (the analysis requires 3γ²np²L² < 1 —
/// the "proper averaging period" condition of [23]).
pub fn variance_bound_segment(a: &Assumptions, s: &Segment, grad_sq: f64) -> Option<f64> {
    if s.p <= 1 {
        return Some(0.0); // full communication: Var[W_k] = 0
    }
    let g2 = s.gamma * s.gamma;
    let np = a.n as f64 * s.p as f64;
    let np2 = a.n as f64 * (s.p as f64) * (s.p as f64);
    let denom = 1.0 - 3.0 * g2 * np2 * a.l * a.l;
    if denom <= 0.0 {
        return None;
    }
    // C₁ is "a constant that depends on the variance of stochastic
    // gradients" — σ²/M per local step is the natural scale.
    let c1 = a.sigma2 / a.m as f64;
    Some((g2 * np * c1) / denom + (3.0 * g2 * np2 / denom) * grad_sq)
}

/// The three terms of equation (8) for a piecewise schedule, plus the
/// communication cost (number of synchronizations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bound {
    pub opt_term: f64,
    pub variance_term: f64,
    pub noise_term: f64,
    pub syncs: usize,
}

impl Bound {
    pub fn total(&self) -> f64 {
        self.opt_term + self.variance_term + self.noise_term
    }
}

/// Evaluate (8) with per-segment variance bounds (10).  `None` if any
/// segment violates the proper-period condition.
pub fn convergence_bound(a: &Assumptions, segs: &[Segment]) -> Option<Bound> {
    let sum_gamma: f64 = segs.iter().map(|s| s.gamma * s.len as f64).sum();
    let sum_gamma2: f64 = segs.iter().map(|s| s.gamma * s.gamma * s.len as f64).sum();
    assert!(sum_gamma > 0.0);

    let mut variance_term = 0.0;
    let mut syncs = 0usize;
    let mut grad_sq = a.grad_sq0;
    for s in segs {
        let weight = s.gamma * s.len as f64 / sum_gamma;
        let vbound = variance_bound_segment(a, s, grad_sq)?;
        variance_term += a.l * a.l * weight * vbound;
        syncs += s.len / s.p.max(1);
        grad_sq *= a.grad_decay;
    }

    Some(Bound {
        opt_term: 2.0 * a.f0_gap / sum_gamma,
        variance_term,
        noise_term: (sum_gamma2 / sum_gamma) * a.l * a.sigma2 / a.m as f64,
        syncs,
    })
}

/// ADPSGD's variance term under condition (13), `Var[Wₖ] ≤ γₖ·C₂/M`:
/// equation (14)'s `(Σγₖ²/Σγₖ)·L²C₂/M` — same asymptotic order as the
/// noise term, i.e. O(1/√(MK)) under γ ∝ √(M/K).
pub fn adaptive_variance_term(a: &Assumptions, segs: &[Segment], c2: f64) -> f64 {
    let sum_gamma: f64 = segs.iter().map(|s| s.gamma * s.len as f64).sum();
    let sum_gamma2: f64 = segs.iter().map(|s| s.gamma * s.gamma * s.len as f64).sum();
    (sum_gamma2 / sum_gamma) * a.l * a.l * c2 / a.m as f64
}

/// The paper's §III-A worked example: four period strategies on the
/// CIFAR schedule (lr 0.1, ×0.1 at k=2000,3000 of 4000).  Returns
/// (label, bound, syncs) rows.
pub fn section3a_strategies(a: &Assumptions) -> Vec<(String, Option<Bound>, usize)> {
    let lr = LrSchedule::StepDecay { boundaries: vec![2000, 3000], factor: 0.1 };
    let k = 4000;
    let cases: Vec<(&str, Vec<(usize, usize)>)> = vec![
        ("strategy-1 (4 then 8)", vec![(0, 4), (2000, 8)]),
        ("strategy-2 (8 then 4)", vec![(0, 8), (2000, 4)]),
        ("strategy-3 (8 const)", vec![(0, 8)]),
        ("strategy-4 (5 const)", vec![(0, 5)]),
    ];
    cases
        .into_iter()
        .map(|(label, periods)| {
            let segs = segments(&lr, 0.1, &periods, k);
            let bound = convergence_bound(a, &segs);
            let syncs = bound.map(|b| b.syncs).unwrap_or(0);
            (label.to_string(), bound, syncs)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assumptions() -> Assumptions {
        // L small enough that the proper-period condition 3γ²np²L² < 1
        // holds for the paper's (γ=0.1, n=16, p≤8) geometry
        Assumptions { l: 0.1, ..Default::default() }
    }

    #[test]
    fn segments_split_at_all_boundaries() {
        let lr = LrSchedule::StepDecay { boundaries: vec![2000, 3000], factor: 0.1 };
        let segs = segments(&lr, 0.1, &[(0, 4), (2500, 8)], 4000);
        let lens: Vec<usize> = segs.iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![2000, 500, 500, 1000]);
        assert_eq!(segs[0].p, 4);
        assert_eq!(segs[1].p, 4);
        assert_eq!(segs[2].p, 8);
        assert!((segs[1].gamma - 0.01).abs() < 1e-6); // f32 lr slack
        assert_eq!(segs.iter().map(|s| s.len).sum::<usize>(), 4000);
    }

    #[test]
    fn variance_bound_monotone_in_p() {
        let a = assumptions();
        let mk = |p| Segment { len: 1000, gamma: 0.01, p };
        let mut prev = 0.0;
        for p in [1usize, 2, 4, 8, 16] {
            let v = variance_bound_segment(&a, &mk(p), 1.0).unwrap();
            assert!(v >= prev, "bound must grow with p: {v} at p={p}");
            prev = v;
        }
    }

    #[test]
    fn improper_period_rejected() {
        // 3γ²np²L² ≥ 1 ⇒ the analysis breaks down ⇒ None
        let a = Assumptions { l: 10.0, ..assumptions() };
        let s = Segment { len: 100, gamma: 0.1, p: 64 };
        assert!(variance_bound_segment(&a, &s, 1.0).is_none());
    }

    #[test]
    fn paper_section3a_ordering() {
        // the paper's argument: at equal communication, strategy-1
        // (small p early) beats strategy-2 (small p late); and
        // strategy-1 beats strategy-4 with *less* communication
        let rows = section3a_strategies(&assumptions());
        let get = |label: &str| {
            rows.iter()
                .find(|(l, _, _)| l.starts_with(label))
                .map(|(_, b, s)| (b.unwrap(), *s))
                .unwrap()
        };
        let (s1, c1) = get("strategy-1");
        let (s2, c2) = get("strategy-2");
        let (s3, c3) = get("strategy-3");
        let (s4, c4) = get("strategy-4");
        assert_eq!(c1, 750, "paper: 2000/4 + 2000/8");
        assert_eq!(c2, 750);
        assert_eq!(c3, 500);
        assert_eq!(c4, 800);
        assert!(
            s1.variance_term < s2.variance_term,
            "strategy-1 {} must beat strategy-2 {}",
            s1.variance_term,
            s2.variance_term
        );
        assert!(s1.variance_term < s3.variance_term);
        assert!(
            s1.variance_term < s4.variance_term && c1 < c4,
            "strategy-1 beats strategy-4 with less communication"
        );
        // opt and noise terms identical across strategies (same γ path)
        assert!((s1.opt_term - s2.opt_term).abs() < 1e-15);
        assert!((s1.noise_term - s2.noise_term).abs() < 1e-15);
    }

    #[test]
    fn adaptive_term_is_noise_order() {
        // (14): with Var ≤ γC₂/M the variance term has the same γ²-sum
        // structure as the noise term — the O(1/√(MK)) preservation
        let a = assumptions();
        let lr = LrSchedule::StepDecay { boundaries: vec![2000, 3000], factor: 0.1 };
        let segs = segments(&lr, 0.1, &[(0, 4)], 4000);
        let v = adaptive_variance_term(&a, &segs, 1.0);
        let b = convergence_bound(&a, &segs).unwrap();
        // same structural factor Σγ²/Σγ:
        let ratio = v / b.noise_term;
        let expect = a.l * 1.0 / a.sigma2; // L²C₂/M ÷ Lσ²/M = L·C₂/σ²
        assert!((ratio - expect).abs() < 1e-12, "{ratio} vs {expect}");
    }

    #[test]
    fn noise_term_scales_inverse_m() {
        let mut a = assumptions();
        let lr = LrSchedule::Const;
        let segs = segments(&lr, 0.05, &[(0, 4)], 1000);
        let b1 = convergence_bound(&a, &segs).unwrap();
        a.m *= 4;
        let b2 = convergence_bound(&a, &segs).unwrap();
        assert!((b1.noise_term / b2.noise_term - 4.0).abs() < 1e-9);
    }

    #[test]
    fn full_communication_has_zero_variance_term() {
        let a = assumptions();
        let segs = segments(&LrSchedule::Const, 0.05, &[(0, 1)], 1000);
        let b = convergence_bound(&a, &segs).unwrap();
        assert_eq!(b.variance_term, 0.0);
        assert_eq!(b.syncs, 1000);
    }
}
