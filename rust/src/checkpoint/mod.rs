//! Checkpointing: binary snapshots of the (averaged) model parameters.
//!
//! The coordinator writes a snapshot of the post-synchronization mean
//! parameters every `checkpoint_every` iterations (leader only — after a
//! sync all nodes hold the same w), and any run can warm-start from a
//! snapshot via `init_from`.  Momentum is deliberately *not* restored:
//! it is node-local state (the paper averages only parameters), and a
//! warm start is a new trajectory.
//!
//! Format (little-endian): magic `ADPK`, version u32, iter u64,
//! n_params u64, loss f64, a controller-state section (version ≥ 2: a
//! presence byte, then period/cnt u64, C₂ f64, C₂-sample-count u64 —
//! see [`CtrlState`]), then n f32 parameters, then a u64 xor checksum
//! of the payload words (parameters and controller state).  Version-1
//! snapshots (no controller section) still load, with `ctrl = None` —
//! those warm starts re-seed C₂ from the first post-resume sync.

use crate::period::CtrlState;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"ADPK";
const VERSION: u32 = 2;

/// One parameter snapshot, plus (version ≥ 2) the period controller's
/// adaptive state so Algorithm 2 resumes exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub iter: u64,
    pub loss: f64,
    pub w: Vec<f32>,
    /// the leader's period-controller state at snapshot time (all
    /// replicas hold identical controllers); `None` for stateless
    /// strategies and version-1 snapshots
    pub ctrl: Option<CtrlState>,
}

fn checksum(w: &[f32], ctrl: &Option<CtrlState>) -> u64 {
    let mut acc = 0xD1B54A32D192ED03u64;
    let mut mix = |word: u64, i: usize| {
        acc ^= word.rotate_left((i % 63) as u32);
        acc = acc.wrapping_mul(0x9E3779B97F4A7C15);
    };
    for (i, v) in w.iter().enumerate() {
        mix(v.to_bits() as u64, i);
    }
    if let Some(c) = ctrl {
        for (i, word) in
            [c.period, c.cnt, c.c2.to_bits(), c.c2_samples].into_iter().enumerate()
        {
            mix(word, w.len() + i);
        }
    }
    acc
}

impl Checkpoint {
    pub fn new(iter: u64, loss: f64, w: Vec<f32>) -> Self {
        Checkpoint { iter, loss, w, ctrl: None }
    }

    /// A snapshot carrying the period controller's state.
    pub fn with_ctrl(iter: u64, loss: f64, w: Vec<f32>, ctrl: Option<CtrlState>) -> Self {
        Checkpoint { iter, loss, w, ctrl }
    }

    /// Canonical file name for iteration `iter` under `dir`.
    pub fn path_for(dir: &Path, iter: u64) -> PathBuf {
        dir.join(format!("ckpt_{iter:010}.adpk"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        // write to a temp file then rename: a crash never leaves a
        // half-written "latest" checkpoint
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {}", tmp.display()))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&self.iter.to_le_bytes())?;
            f.write_all(&(self.w.len() as u64).to_le_bytes())?;
            f.write_all(&self.loss.to_le_bytes())?;
            match &self.ctrl {
                None => f.write_all(&[0u8])?,
                Some(c) => {
                    f.write_all(&[1u8])?;
                    f.write_all(&c.period.to_le_bytes())?;
                    f.write_all(&c.cnt.to_le_bytes())?;
                    f.write_all(&c.c2.to_le_bytes())?;
                    f.write_all(&c.c2_samples.to_le_bytes())?;
                }
            }
            for v in &self.w {
                f.write_all(&v.to_le_bytes())?;
            }
            f.write_all(&checksum(&self.w, &self.ctrl).to_le_bytes())?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an adpsgd checkpoint (bad magic)", path.display());
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        if !(1..=VERSION).contains(&version) {
            bail!("{}: unsupported checkpoint version {version}", path.display());
        }
        f.read_exact(&mut b8)?;
        let iter = u64::from_le_bytes(b8);
        f.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        if n > (1usize << 33) {
            bail!("{}: implausible parameter count {n}", path.display());
        }
        f.read_exact(&mut b8)?;
        let loss = f64::from_le_bytes(b8);
        let ctrl = if version >= 2 {
            let mut flag = [0u8; 1];
            f.read_exact(&mut flag)?;
            match flag[0] {
                0 => None,
                1 => {
                    let mut word = || -> Result<u64> {
                        f.read_exact(&mut b8)?;
                        Ok(u64::from_le_bytes(b8))
                    };
                    let period = word()?;
                    let cnt = word()?;
                    let c2 = f64::from_bits(word()?);
                    let c2_samples = word()?;
                    Some(CtrlState { period, cnt, c2, c2_samples })
                }
                other => bail!(
                    "{}: corrupt controller-state flag {other}",
                    path.display()
                ),
            }
        } else {
            None
        };
        let mut w = vec![0.0f32; n];
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            w[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        f.read_exact(&mut b8)?;
        let want = u64::from_le_bytes(b8);
        let got = if version >= 2 { checksum(&w, &ctrl) } else { checksum(&w, &None) };
        if want != got {
            bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
        }
        Ok(Checkpoint { iter, loss, w, ctrl })
    }

    /// Latest checkpoint (by iteration) in a directory, if any.
    pub fn latest(dir: &Path) -> Result<Option<PathBuf>> {
        if !dir.exists() {
            return Ok(None);
        }
        let mut best: Option<(u64, PathBuf)> = None;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let Some(iter_str) = name.strip_prefix("ckpt_").and_then(|s| s.strip_suffix(".adpk"))
            else {
                continue;
            };
            if let Ok(iter) = iter_str.parse::<u64>() {
                if best.as_ref().map(|(b, _)| iter > *b).unwrap_or(true) {
                    best = Some((iter, path));
                }
            }
        }
        Ok(best.map(|(_, p)| p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("adpsgd_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact() {
        let dir = tmpdir("rt");
        let w: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let ck = Checkpoint::new(42, 0.123, w);
        let path = Checkpoint::path_for(&dir, ck.iter);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_with_controller_state() {
        let dir = tmpdir("ctrl");
        let ctrl = CtrlState { period: 7, cnt: 3, c2: 2.625, c2_samples: 19 };
        let ck = Checkpoint::with_ctrl(88, 0.5, vec![1.5; 32], Some(ctrl));
        let path = Checkpoint::path_for(&dir, ck.iter);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.ctrl, Some(ctrl));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn version1_snapshots_still_load_without_ctrl() {
        let dir = tmpdir("v1");
        let w = vec![0.25f32; 16];
        let path = dir.join("ckpt_0000000042.adpk");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&42u64.to_le_bytes());
        bytes.extend_from_slice(&(w.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&0.75f64.to_le_bytes());
        for v in &w {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&checksum(&w, &None).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.iter, 42);
        assert_eq!(ck.w, w);
        assert_eq!(ck.ctrl, None, "v1 snapshots carry no controller state");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ctrl_state_corruption_detected() {
        let dir = tmpdir("ctrlcorrupt");
        let ck = Checkpoint::with_ctrl(
            1,
            0.0,
            vec![1.0; 64],
            Some(CtrlState { period: 4, cnt: 1, c2: 1.0, c2_samples: 2 }),
        );
        let path = Checkpoint::path_for(&dir, 1);
        ck.save(&path).unwrap();
        // flip a byte inside the controller-state section (right after
        // the presence flag at offset 4+4+8+8+8)
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4 + 4 + 8 + 8 + 8 + 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("corrupt");
        let ck = Checkpoint::new(1, 0.0, vec![1.0; 64]);
        let path = Checkpoint::path_for(&dir, 1);
        ck.save(&path).unwrap();
        // flip one byte mid-payload
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = tmpdir("magic");
        let path = dir.join("ckpt_0000000001.adpk");
        std::fs::write(&path, b"NOPE-not-a-checkpoint").unwrap();
        assert!(Checkpoint::load(&path).unwrap_err().to_string().contains("magic"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_picks_highest_iter() {
        let dir = tmpdir("latest");
        for iter in [5u64, 900, 37] {
            Checkpoint::new(iter, 0.0, vec![0.5; 8])
                .save(&Checkpoint::path_for(&dir, iter))
                .unwrap();
        }
        let latest = Checkpoint::latest(&dir).unwrap().unwrap();
        assert!(latest.to_str().unwrap().contains("0000000900"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_empty_dir_is_none() {
        let dir = tmpdir("empty");
        assert!(Checkpoint::latest(&dir).unwrap().is_none());
        assert!(Checkpoint::latest(Path::new("/no/such/dir")).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
