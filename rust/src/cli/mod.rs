//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar used by the launcher and every example:
//!
//! ```text
//! prog [subcommand] [--flag] [--key value] [--key=value] [positional...]
//! ```

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// declared flag names (so `--flag value` is not misparsed)
    #[allow(dead_code)]
    bool_flags: Vec<&'static str>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).  `bool_flags` lists
    /// options that take no value.
    pub fn parse_env(bool_flags: &[&'static str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1).collect(), bool_flags)
    }

    pub fn parse(argv: Vec<String>, bool_flags: &[&'static str]) -> Result<Args> {
        let mut out = Args { bool_flags: bool_flags.to_vec(), ..Default::default() };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("option --{body} expects a value");
                    };
                    out.options.insert(body.to_string(), v.clone());
                    i += 1;
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() && out.options.is_empty()
            {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// All `--set key=value` style config overrides: collects every
    /// option whose key contains a '.' (dotted config path).
    ///
    /// Application is strict: when these overrides are applied
    /// (`ExperimentConfig::from_file` / `from_overrides` /
    /// `apply_overrides`), keys that are unknown, or that name a
    /// strategy knob not belonging to the configured `sync.strategy`
    /// (e.g. `--sync.qsgd_levels` under `strategy=adpsgd`), are rejected
    /// with the list of valid keys — never silently ignored.
    pub fn config_overrides(&self) -> Vec<(String, String)> {
        self.options
            .iter()
            .filter(|(k, _)| k.contains('.'))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            argv(&["train", "--config", "c.toml", "--verbose", "--nodes=8", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("c.toml"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("nodes"), Some("8"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv(&["--config"]), &[]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv(&["--n=4", "--f", "2.5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 4);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
        assert!(a.get_usize("f", 0).is_err());
    }

    #[test]
    fn dotted_overrides() {
        let a = Args::parse(argv(&["--sync.period=8", "--net.bandwidth_gbps", "10"]), &[]).unwrap();
        let ov = a.config_overrides();
        assert_eq!(ov.len(), 2);
        assert!(ov.contains(&("sync.period".into(), "8".into())));
    }
}
