//! In-process collectives across worker threads, behind the pluggable
//! [`Collective`] trait.
//!
//! The simulated cluster's "nodes" are OS threads in one address space,
//! so collectives move real data between real threads.  Two algorithms
//! implement the same contract:
//!
//! * [`FlatComm`] — the reference: after every rank publishes, the
//!   leader (rank 0) reduces the **whole** buffer serially, then every
//!   rank copies the result back.  Simple, and the baseline the
//!   per-algorithm cost model prices as a serialized gather+broadcast.
//! * [`RingComm`] — chunked reduce-scatter + all-gather, the
//!   shared-memory analogue of NCCL's ring allreduce: rank `r` reduces
//!   chunk `r`, so the reduction parallelizes across all ranks and the
//!   measured `compute_secs`/`wall_secs` drop roughly by the node count
//!   for large parameter vectors.
//!
//! Both reduce each element in **fixed rank order** (sum ranks 0..n,
//! then multiply by 1/n), so the two algorithms produce bit-identical
//! results and runs are deterministic regardless of thread scheduling —
//! the property the coordinator's `deterministic_across_runs` test and
//! the flat/ring equivalence property test pin down.
//!
//! Phases are separated by barriers; phase-2 chunk writes are disjoint
//! by construction, which is what makes the single shared result buffer
//! sound (see `SharedVec`).
//!
//! **Failure handling**: a worker that hits an error mid-run calls
//! [`Collective::poison`]; every rank blocked in (or arriving at) a
//! collective then returns [`Poisoned`] instead of deadlocking — the
//! in-process analogue of NCCL's communicator abort.  The barrier is a
//! custom Mutex+Condvar generation barrier because `std::sync::Barrier`
//! cannot be interrupted.  Poison semantics are identical across
//! algorithms.
//!
//! Wall-clock *modeling* of the same exchanges on a real network lives
//! in [`crate::netsim`] (which prices flat and ring differently); this
//! module is the data plane.  Selection is `cfg.sync.collective`
//! ([`Algo`]), plumbed through [`build`].

use std::cell::UnsafeCell;
use std::sync::{Arc, Condvar, Mutex};

/// A collective failed because some rank aborted the communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

impl std::fmt::Display for Poisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("communicator poisoned: a peer rank failed")
    }
}

impl std::error::Error for Poisoned {}

/// Which allreduce algorithm a communicator (and the cost model) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Algo {
    /// Leader-serialized reduce + broadcast ([`FlatComm`]).
    Flat,
    /// Chunked reduce-scatter + all-gather ([`RingComm`]).
    #[default]
    Ring,
}

impl std::str::FromStr for Algo {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "flat" => Algo::Flat,
            "ring" => Algo::Ring,
            other => anyhow::bail!("unknown collective {other:?} (flat|ring)"),
        })
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Algo::Flat => "flat",
            Algo::Ring => "ring",
        })
    }
}

/// The collective contract every communicator implements.  All methods
/// are callable concurrently from `n` rank threads; every rank must
/// participate in every collective call (BSP).
pub trait Collective: Send + Sync {
    fn n_ranks(&self) -> usize;

    /// Which algorithm this communicator runs (for the cost model).
    fn algo(&self) -> Algo;

    /// Abort the communicator: every rank blocked in (or arriving at) a
    /// collective returns `Err(Poisoned)`.  Idempotent and sticky.
    fn poison(&self);

    fn is_poisoned(&self) -> bool;

    /// Block until all ranks arrive (or the communicator is poisoned).
    fn barrier(&self) -> Result<(), Poisoned>;

    /// Average `buf` elementwise across all ranks (every rank must call
    /// with an equal-length buffer; all receive the mean).
    ///
    /// Deterministic: the reduction order per element is rank order, so
    /// results are bit-identical across runs, thread schedules, and
    /// algorithms.
    fn allreduce_mean(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned>;

    /// Sum a scalar across ranks (used for the S_k statistic and loss
    /// aggregation).  Deterministic (rank-ordered sum).
    fn allreduce_scalar_sum(&self, rank: usize, v: f64) -> Result<f64, Poisoned>;

    /// Rank 0's value wins; everyone receives it (parameter broadcast at
    /// init so all nodes start from the same w₀, as the paper requires).
    fn broadcast(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned>;
}

/// Build the communicator selected by `algo`.
pub fn build(algo: Algo, n: usize, len: usize) -> Arc<dyn Collective> {
    match algo {
        Algo::Flat => Arc::new(FlatComm::new(n, len)),
        Algo::Ring => Arc::new(RingComm::new(n, len)),
    }
}

// ------------------------------------------------------------ substrate

/// Interruptible generation barrier.
struct AbortableBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

#[derive(Debug)]
struct BarrierState {
    count: usize,
    generation: u64,
    poisoned: bool,
}

impl AbortableBarrier {
    fn new(n: usize) -> Self {
        AbortableBarrier {
            n,
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) -> Result<(), Poisoned> {
        let mut s = self.state.lock().unwrap();
        if s.poisoned {
            return Err(Poisoned);
        }
        s.count += 1;
        if s.count == self.n {
            s.count = 0;
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen && !s.poisoned {
            s = self.cv.wait(s).unwrap();
        }
        if s.poisoned {
            Err(Poisoned)
        } else {
            Ok(())
        }
    }

    fn poison(&self) {
        let mut s = self.state.lock().unwrap();
        s.poisoned = true;
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned
    }
}

/// Shared f32 buffer written in disjoint ranges between barriers.
///
/// Safety contract: phase-2 writers each own a disjoint index range
/// (rank-derived for ring, the leader's whole range for flat), and
/// barriers order every write before any phase-3 read.  No two threads
/// ever touch the same element between barriers.
struct SharedVec(UnsafeCell<Vec<f32>>);

// SAFETY: see the contract above — disjoint writes + barrier ordering.
unsafe impl Sync for SharedVec {}

impl SharedVec {
    fn new(n: usize) -> Self {
        SharedVec(UnsafeCell::new(vec![0.0; n]))
    }

    /// SAFETY: caller must hold a disjoint range per thread (phase 2).
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [f32] {
        let v: *mut Vec<f32> = self.0.get();
        &mut (unsafe { &mut *v })[lo..hi]
    }

    /// SAFETY: caller must be in a read-only phase (after the write
    /// barrier, before the reuse barrier).
    unsafe fn slice(&self) -> &[f32] {
        let v: *const Vec<f32> = self.0.get();
        unsafe { &*v }
    }
}

/// State + phase plumbing shared by both algorithms: publish slots, the
/// shared result buffer, scalar slots, and the abortable barrier.  The
/// algorithms differ only in who reduces which range in phase 2.
struct Core {
    n: usize,
    len: usize,
    slots: Vec<Mutex<Vec<f32>>>,
    result: SharedVec,
    scalars: Vec<Mutex<f64>>,
    barrier: AbortableBarrier,
}

impl Core {
    fn new(n: usize, len: usize) -> Self {
        assert!(n >= 1);
        Core {
            n,
            len,
            slots: (0..n).map(|_| Mutex::new(vec![0.0; len])).collect(),
            result: SharedVec::new(len),
            scalars: (0..n).map(|_| Mutex::new(0.0)).collect(),
            barrier: AbortableBarrier::new(n),
        }
    }

    fn barrier(&self) -> Result<(), Poisoned> {
        if self.n > 1 {
            self.barrier.wait()
        } else if self.barrier.is_poisoned() {
            Err(Poisoned)
        } else {
            Ok(())
        }
    }

    /// Reduce `[lo, hi)` of the result buffer from all slots in rank
    /// order, then scale by 1/n.  Caller owns the range (phase 2).
    ///
    /// The per-element arithmetic (rank-ordered add, then scale) goes
    /// through the [`crate::tensor`] kernels, which split large ranges
    /// across the `tensor::par` pool — elementwise ops, so the result
    /// is bit-identical at any thread count.  Slots stay locked one at
    /// a time: under the ring algorithm every rank reduces its own
    /// range concurrently, and holding all slot locks here would
    /// serialize them.
    fn reduce_range(&self, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        // SAFETY: [lo, hi) is owned by this thread; barriers order phases.
        let out = unsafe { self.result.slice_mut(lo, hi) };
        let inv = 1.0 / self.n as f32;
        let first = self.slots[0].lock().unwrap();
        out.copy_from_slice(&first[lo..hi]);
        drop(first);
        for r in 1..self.n {
            let slot = self.slots[r].lock().unwrap();
            crate::tensor::add_assign(out, &slot[lo..hi]);
        }
        crate::tensor::scale(out, inv);
    }

    /// Full allreduce with the phase-2 reduction range given by
    /// `range_for(rank)`.  Publish → reduce → gather, three barriers.
    fn allreduce_mean(
        &self,
        rank: usize,
        buf: &mut [f32],
        range_for: impl Fn(usize) -> (usize, usize),
    ) -> Result<(), Poisoned> {
        assert_eq!(buf.len(), self.len);
        assert!(rank < self.n);
        if self.n == 1 {
            // no peers to exchange with, but poison stays sticky even in
            // the degenerate case (the trait contract: a poisoned
            // communicator rejects every new collective)
            return self.barrier();
        }
        // phase 1: publish
        self.slots[rank].lock().unwrap().copy_from_slice(buf);
        self.barrier()?;
        // phase 2: reduce this rank's range (deterministic rank order)
        let (lo, hi) = range_for(rank);
        self.reduce_range(lo, hi);
        self.barrier()?;
        // phase 3: allgather
        // SAFETY: writes finished at the barrier above; next mutation
        // happens only after the final barrier below.
        buf.copy_from_slice(unsafe { self.result.slice() });
        self.barrier()?;
        Ok(())
    }

    fn allreduce_scalar_sum(&self, rank: usize, v: f64) -> Result<f64, Poisoned> {
        if self.n == 1 {
            self.barrier()?;
            return Ok(v);
        }
        *self.scalars[rank].lock().unwrap() = v;
        self.barrier()?;
        let mut acc = 0.0;
        for s in &self.scalars {
            acc += *s.lock().unwrap();
        }
        self.barrier()?;
        Ok(acc)
    }

    fn broadcast(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        assert_eq!(buf.len(), self.len);
        if self.n == 1 {
            return self.barrier();
        }
        if rank == 0 {
            self.slots[0].lock().unwrap().copy_from_slice(buf);
        }
        self.barrier()?;
        if rank != 0 {
            buf.copy_from_slice(&self.slots[0].lock().unwrap());
        }
        self.barrier()?;
        Ok(())
    }
}

// ------------------------------------------------------------ FlatComm

/// Reference communicator: the leader reduces the whole buffer serially.
pub struct FlatComm {
    core: Core,
}

impl FlatComm {
    pub fn new(n: usize, len: usize) -> Self {
        FlatComm { core: Core::new(n, len) }
    }
}

impl Collective for FlatComm {
    fn n_ranks(&self) -> usize {
        self.core.n
    }

    fn algo(&self) -> Algo {
        Algo::Flat
    }

    fn poison(&self) {
        self.core.barrier.poison();
    }

    fn is_poisoned(&self) -> bool {
        self.core.barrier.is_poisoned()
    }

    fn barrier(&self) -> Result<(), Poisoned> {
        self.core.barrier()
    }

    fn allreduce_mean(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        let len = self.core.len;
        // rank 0 owns everything; other ranks reduce nothing
        self.core
            .allreduce_mean(rank, buf, |r| if r == 0 { (0, len) } else { (0, 0) })
    }

    fn allreduce_scalar_sum(&self, rank: usize, v: f64) -> Result<f64, Poisoned> {
        self.core.allreduce_scalar_sum(rank, v)
    }

    fn broadcast(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        self.core.broadcast(rank, buf)
    }
}

// ------------------------------------------------------------ RingComm

/// Chunked communicator: rank `r` reduces chunk `r`, in parallel.
pub struct RingComm {
    core: Core,
}

impl RingComm {
    pub fn new(n: usize, len: usize) -> Self {
        RingComm { core: Core::new(n, len) }
    }

    fn chunk(&self, rank: usize) -> (usize, usize) {
        let lo = rank * self.core.len / self.core.n;
        let hi = (rank + 1) * self.core.len / self.core.n;
        (lo, hi)
    }
}

impl Collective for RingComm {
    fn n_ranks(&self) -> usize {
        self.core.n
    }

    fn algo(&self) -> Algo {
        Algo::Ring
    }

    fn poison(&self) {
        self.core.barrier.poison();
    }

    fn is_poisoned(&self) -> bool {
        self.core.barrier.is_poisoned()
    }

    fn barrier(&self) -> Result<(), Poisoned> {
        self.core.barrier()
    }

    fn allreduce_mean(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        self.core.allreduce_mean(rank, buf, |r| self.chunk(r))
    }

    fn allreduce_scalar_sum(&self, rank: usize, v: f64) -> Result<f64, Poisoned> {
        self.core.allreduce_scalar_sum(rank, v)
    }

    fn broadcast(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        self.core.broadcast(rank, buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn both(n: usize, len: usize) -> Vec<Arc<dyn Collective>> {
        vec![
            Arc::new(FlatComm::new(n, len)) as Arc<dyn Collective>,
            Arc::new(RingComm::new(n, len)),
        ]
    }

    fn run_ranks<F>(n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn allreduce_mean_correct_both_algos() {
        let n = 4;
        let len = 1000;
        for comm in both(n, len) {
            let outputs: Arc<Vec<Mutex<Vec<f32>>>> =
                Arc::new((0..n).map(|_| Mutex::new(vec![])).collect());
            {
                let comm = Arc::clone(&comm);
                let outputs = Arc::clone(&outputs);
                run_ranks(n, move |rank| {
                    let mut buf: Vec<f32> =
                        (0..len).map(|i| (rank * len + i) as f32).collect();
                    comm.allreduce_mean(rank, &mut buf).unwrap();
                    *outputs[rank].lock().unwrap() = buf;
                });
            }
            // expected mean of rank*len + i over ranks = i + len*(n-1)/2
            let expect: Vec<f32> =
                (0..len).map(|i| i as f32 + len as f32 * 1.5).collect();
            for r in 0..n {
                let got = outputs[r].lock().unwrap();
                assert_eq!(&*got, &expect, "rank {r}");
            }
        }
    }

    #[test]
    fn repeated_allreduce_deterministic() {
        let n = 8;
        let len = 4097; // non-divisible chunks
        let run = |algo: Algo| {
            let comm = build(algo, n, len);
            let out: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(vec![]));
            let out2 = Arc::clone(&out);
            let comm2 = Arc::clone(&comm);
            run_ranks(n, move |rank| {
                let mut rng = Rng::new(123, rank as u64);
                let mut buf = vec![0.0f32; len];
                rng.fill_normal(&mut buf, 1.0);
                for _ in 0..3 {
                    comm2.allreduce_mean(rank, &mut buf).unwrap();
                }
                if rank == 0 {
                    *out2.lock().unwrap() = buf;
                }
            });
            let v = out.lock().unwrap().clone();
            v
        };
        let r1 = run(Algo::Ring);
        let r2 = run(Algo::Ring);
        assert_eq!(r1, r2, "allreduce must be bit-deterministic");
        // and flat reduces in the same rank order -> bit-identical too
        let f1 = run(Algo::Flat);
        assert_eq!(r1, f1, "flat and ring must agree bitwise");
    }

    #[test]
    fn allreduce_bit_identical_across_thread_counts() {
        // the reduce inner loops route through tensor::par — the mean
        // must not depend on the kernel thread count for either algo
        let _guard = crate::tensor::par::test_serial();
        let n = 4;
        let len = 40_000; // above the parallel threshold
        let run = |algo: Algo| {
            let comm = build(algo, n, len);
            let out: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(vec![]));
            let out2 = Arc::clone(&out);
            let comm2 = Arc::clone(&comm);
            run_ranks(n, move |rank| {
                let mut rng = Rng::new(77, rank as u64);
                let mut buf = vec![0.0f32; len];
                rng.fill_normal(&mut buf, 1.0);
                comm2.allreduce_mean(rank, &mut buf).unwrap();
                if rank == 0 {
                    *out2.lock().unwrap() = buf;
                }
            });
            let v = out.lock().unwrap().clone();
            v
        };
        for algo in [Algo::Flat, Algo::Ring] {
            crate::tensor::par::set_threads(1);
            let reference = run(algo);
            for t in [2usize, 7] {
                crate::tensor::par::set_threads(t);
                assert_eq!(run(algo), reference, "algo {algo:?} threads={t}");
            }
        }
        crate::tensor::par::set_threads(0);
    }

    #[test]
    fn all_ranks_agree_after_allreduce() {
        let n = 5;
        let len = 333;
        for comm in both(n, len) {
            let outputs: Arc<Vec<Mutex<Vec<f32>>>> =
                Arc::new((0..n).map(|_| Mutex::new(vec![])).collect());
            {
                let comm = Arc::clone(&comm);
                let outputs = Arc::clone(&outputs);
                run_ranks(n, move |rank| {
                    let mut rng = Rng::new(7, rank as u64);
                    let mut buf = vec![0.0f32; len];
                    rng.fill_normal(&mut buf, 2.0);
                    comm.allreduce_mean(rank, &mut buf).unwrap();
                    *outputs[rank].lock().unwrap() = buf;
                });
            }
            let first = outputs[0].lock().unwrap().clone();
            for r in 1..n {
                assert_eq!(*outputs[r].lock().unwrap(), first);
            }
        }
    }

    #[test]
    fn scalar_sum_and_broadcast() {
        let n = 6;
        for comm in both(n, 8) {
            let sums: Arc<Vec<Mutex<f64>>> =
                Arc::new((0..n).map(|_| Mutex::new(0.0)).collect());
            {
                let comm = Arc::clone(&comm);
                let sums = Arc::clone(&sums);
                run_ranks(n, move |rank| {
                    let s = comm.allreduce_scalar_sum(rank, (rank + 1) as f64).unwrap();
                    *sums[rank].lock().unwrap() = s;
                    let mut buf = vec![rank as f32; 8];
                    comm.broadcast(rank, &mut buf).unwrap();
                    assert!(buf.iter().all(|&v| v == 0.0), "rank {rank} got {buf:?}");
                });
            }
            for r in 0..n {
                assert_eq!(*sums[r].lock().unwrap(), 21.0);
            }
        }
    }

    #[test]
    fn single_rank_is_noop() {
        for comm in both(1, 4) {
            let mut buf = vec![1.0, 2.0, 3.0, 4.0];
            comm.allreduce_mean(0, &mut buf).unwrap();
            assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
            assert_eq!(comm.allreduce_scalar_sum(0, 5.0).unwrap(), 5.0);
        }
    }

    #[test]
    fn sequential_scalar_rounds_do_not_interfere() {
        let n = 3;
        for comm in both(n, 1) {
            let ok = Arc::new(Mutex::new(true));
            {
                let comm = Arc::clone(&comm);
                let ok = Arc::clone(&ok);
                run_ranks(n, move |rank| {
                    for round in 0..50u64 {
                        let s = comm
                            .allreduce_scalar_sum(rank, (round + rank as u64) as f64)
                            .unwrap();
                        let expect = (3 * round + 3) as f64; // sum over ranks 0..3 of round+rank
                        if (s - expect).abs() > 1e-12 {
                            *ok.lock().unwrap() = false;
                        }
                    }
                });
            }
            assert!(*ok.lock().unwrap());
        }
    }

    #[test]
    fn poison_unblocks_waiting_ranks() {
        // rank 1 never joins the collective; rank 2 poisons after a
        // delay; rank 0 must return Err instead of hanging forever.
        let n = 3;
        for comm in both(n, 64) {
            let results: Arc<Vec<Mutex<Option<Result<(), Poisoned>>>>> =
                Arc::new((0..n).map(|_| Mutex::new(None)).collect());
            {
                let comm = Arc::clone(&comm);
                let results = Arc::clone(&results);
                run_ranks(n, move |rank| {
                    match rank {
                        0 => {
                            let mut buf = vec![1.0f32; 64];
                            let r = comm.allreduce_mean(0, &mut buf);
                            *results[0].lock().unwrap() = Some(r);
                        }
                        1 => { /* failed node: never participates */ }
                        _ => {
                            std::thread::sleep(std::time::Duration::from_millis(50));
                            comm.poison();
                            *results[2].lock().unwrap() = Some(Err(Poisoned));
                        }
                    }
                });
            }
            assert_eq!(*results[0].lock().unwrap(), Some(Err(Poisoned)));
            assert!(comm.is_poisoned());
        }
    }

    #[test]
    fn poisoned_comm_rejects_new_collectives() {
        for comm in both(2, 4) {
            comm.poison();
            let mut buf = vec![0.0f32; 4];
            assert_eq!(comm.allreduce_mean(0, &mut buf), Err(Poisoned));
            assert_eq!(comm.allreduce_scalar_sum(1, 1.0), Err(Poisoned));
            assert_eq!(comm.broadcast(0, &mut buf), Err(Poisoned));
        }
        // poison stays sticky even in the degenerate single-rank case
        for comm in both(1, 4) {
            comm.poison();
            let mut buf = vec![0.0f32; 4];
            assert_eq!(comm.allreduce_mean(0, &mut buf), Err(Poisoned));
            assert_eq!(comm.allreduce_scalar_sum(0, 1.0), Err(Poisoned));
            assert_eq!(comm.broadcast(0, &mut buf), Err(Poisoned));
        }
    }

    #[test]
    fn poison_is_idempotent_and_sticky() {
        for comm in both(2, 1) {
            comm.poison();
            comm.poison();
            assert!(comm.is_poisoned());
            assert_eq!(comm.barrier(), Err(Poisoned));
        }
    }

    #[test]
    fn algo_parse_roundtrip() {
        assert_eq!("flat".parse::<Algo>().unwrap(), Algo::Flat);
        assert_eq!("ring".parse::<Algo>().unwrap(), Algo::Ring);
        assert!("mesh".parse::<Algo>().is_err());
        assert_eq!(Algo::Flat.to_string(), "flat");
        assert_eq!(Algo::default(), Algo::Ring);
        assert_eq!(build(Algo::Flat, 2, 4).algo(), Algo::Flat);
        assert_eq!(build(Algo::Ring, 2, 4).algo(), Algo::Ring);
    }
}
