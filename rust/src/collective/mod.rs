//! In-process collectives across worker threads.
//!
//! The simulated cluster's "nodes" are OS threads in one address space,
//! so collectives move real data between real threads — the shared-
//! memory analogue of NCCL's ring allreduce:
//!
//! 1. **publish** — every rank copies its vector into its slot
//! 2. **reduce-scatter** — rank r averages chunk r across all slots
//!    (fixed rank order, so float summation is deterministic regardless
//!    of thread scheduling)
//! 3. **allgather** — every rank copies the full averaged vector back
//!
//! Three barriers separate the phases; chunk writes in phase 2 are
//! disjoint by construction, which is what makes the single shared
//! result buffer sound (see `SharedVec`).
//!
//! **Failure handling**: a worker that hits an error mid-run calls
//! [`Comm::poison`]; every rank blocked in (or arriving at) a collective
//! then returns [`CommError::Poisoned`] instead of deadlocking — the
//! in-process analogue of NCCL's communicator abort.  The barrier is a
//! custom Mutex+Condvar generation barrier because `std::sync::Barrier`
//! cannot be interrupted.
//!
//! Wall-clock *modeling* of the same exchange on a real network lives in
//! [`crate::netsim`]; this module is the data plane.

use std::cell::UnsafeCell;
use std::sync::{Condvar, Mutex};

/// A collective failed because some rank aborted the communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

impl std::fmt::Display for Poisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("communicator poisoned: a peer rank failed")
    }
}

impl std::error::Error for Poisoned {}

/// Interruptible generation barrier.
struct AbortableBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

#[derive(Debug)]
struct BarrierState {
    count: usize,
    generation: u64,
    poisoned: bool,
}

impl AbortableBarrier {
    fn new(n: usize) -> Self {
        AbortableBarrier {
            n,
            state: Mutex::new(BarrierState { count: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) -> Result<(), Poisoned> {
        let mut s = self.state.lock().unwrap();
        if s.poisoned {
            return Err(Poisoned);
        }
        s.count += 1;
        if s.count == self.n {
            s.count = 0;
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen && !s.poisoned {
            s = self.cv.wait(s).unwrap();
        }
        if s.poisoned {
            Err(Poisoned)
        } else {
            Ok(())
        }
    }

    fn poison(&self) {
        let mut s = self.state.lock().unwrap();
        s.poisoned = true;
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.state.lock().unwrap().poisoned
    }
}

/// Shared f32 buffer written in disjoint chunks between barriers.
///
/// Safety contract: phase-2 writers each own a disjoint index range
/// (rank-derived), and barriers order every write before any phase-3
/// read.  No two threads ever touch the same element between barriers.
struct SharedVec(UnsafeCell<Vec<f32>>);

// SAFETY: see the contract above — disjoint writes + barrier ordering.
unsafe impl Sync for SharedVec {}

impl SharedVec {
    fn new(n: usize) -> Self {
        SharedVec(UnsafeCell::new(vec![0.0; n]))
    }

    /// SAFETY: caller must hold a disjoint range per thread (phase 2).
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [f32] {
        let v: *mut Vec<f32> = self.0.get();
        &mut (unsafe { &mut *v })[lo..hi]
    }

    /// SAFETY: caller must be in a read-only phase (after the write
    /// barrier, before the reuse barrier).
    unsafe fn slice(&self) -> &[f32] {
        let v: *const Vec<f32> = self.0.get();
        unsafe { &*v }
    }
}

/// A communicator for `n` ranks over vectors of length `len`.
pub struct Comm {
    n: usize,
    len: usize,
    slots: Vec<Mutex<Vec<f32>>>,
    result: SharedVec,
    scalars: Vec<Mutex<f64>>,
    barrier: AbortableBarrier,
}

impl Comm {
    pub fn new(n: usize, len: usize) -> Self {
        assert!(n >= 1);
        Comm {
            n,
            len,
            slots: (0..n).map(|_| Mutex::new(vec![0.0; len])).collect(),
            result: SharedVec::new(len),
            scalars: (0..n).map(|_| Mutex::new(0.0)).collect(),
            barrier: AbortableBarrier::new(n),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    /// Abort the communicator: every rank blocked in (or arriving at) a
    /// collective returns `Err(Poisoned)`.  Idempotent.
    pub fn poison(&self) {
        self.barrier.poison();
    }

    pub fn is_poisoned(&self) -> bool {
        self.barrier.is_poisoned()
    }

    /// Block until all ranks arrive (or the communicator is poisoned).
    pub fn barrier(&self) -> Result<(), Poisoned> {
        if self.n > 1 {
            self.barrier.wait()
        } else {
            Ok(())
        }
    }

    fn chunk(&self, rank: usize) -> (usize, usize) {
        let lo = rank * self.len / self.n;
        let hi = (rank + 1) * self.len / self.n;
        (lo, hi)
    }

    /// Average `buf` elementwise across all ranks (every rank must call
    /// with an equal-length buffer; all receive the mean).
    ///
    /// Deterministic: the reduction order per element is rank order, so
    /// results are bit-identical across runs and thread schedules.
    pub fn allreduce_mean(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        assert_eq!(buf.len(), self.len);
        assert!(rank < self.n);
        if self.n == 1 {
            return Ok(());
        }
        // phase 1: publish
        self.slots[rank].lock().unwrap().copy_from_slice(buf);
        self.barrier()?;
        // phase 2: reduce-scatter my chunk (deterministic rank order)
        let (lo, hi) = self.chunk(rank);
        if lo < hi {
            // SAFETY: [lo, hi) is disjoint per rank; barriers order phases.
            let out = unsafe { self.result.slice_mut(lo, hi) };
            let inv = 1.0 / self.n as f32;
            let first = self.slots[0].lock().unwrap();
            out.copy_from_slice(&first[lo..hi]);
            drop(first);
            for r in 1..self.n {
                let slot = self.slots[r].lock().unwrap();
                for (o, v) in out.iter_mut().zip(&slot[lo..hi]) {
                    *o += *v;
                }
            }
            for o in out.iter_mut() {
                *o *= inv;
            }
        }
        self.barrier()?;
        // phase 3: allgather
        // SAFETY: writes finished at the barrier above; next mutation
        // happens only after the final barrier below.
        buf.copy_from_slice(unsafe { self.result.slice() });
        self.barrier()?;
        Ok(())
    }

    /// Sum a scalar across ranks (used for the S_k statistic and loss
    /// aggregation).  Deterministic (rank-ordered sum).
    pub fn allreduce_scalar_sum(&self, rank: usize, v: f64) -> Result<f64, Poisoned> {
        if self.n == 1 {
            return Ok(v);
        }
        *self.scalars[rank].lock().unwrap() = v;
        self.barrier()?;
        let mut acc = 0.0;
        for s in &self.scalars {
            acc += *s.lock().unwrap();
        }
        self.barrier()?;
        Ok(acc)
    }

    /// Rank 0's value wins; everyone receives it (parameter broadcast at
    /// init so all nodes start from the same w₀, as the paper requires).
    pub fn broadcast(&self, rank: usize, buf: &mut [f32]) -> Result<(), Poisoned> {
        assert_eq!(buf.len(), self.len);
        if self.n == 1 {
            return Ok(());
        }
        if rank == 0 {
            self.slots[0].lock().unwrap().copy_from_slice(buf);
        }
        self.barrier()?;
        if rank != 0 {
            buf.copy_from_slice(&self.slots[0].lock().unwrap());
        }
        self.barrier()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn run_ranks<F>(n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn allreduce_mean_correct() {
        let n = 4;
        let len = 1000;
        let comm = Arc::new(Comm::new(n, len));
        let outputs: Arc<Vec<Mutex<Vec<f32>>>> =
            Arc::new((0..n).map(|_| Mutex::new(vec![])).collect());
        {
            let comm = Arc::clone(&comm);
            let outputs = Arc::clone(&outputs);
            run_ranks(n, move |rank| {
                let mut buf: Vec<f32> = (0..len).map(|i| (rank * len + i) as f32).collect();
                comm.allreduce_mean(rank, &mut buf).unwrap();
                *outputs[rank].lock().unwrap() = buf;
            });
        }
        // expected mean of rank*len + i over ranks = i + len*(n-1)/2
        let expect: Vec<f32> = (0..len).map(|i| i as f32 + len as f32 * 1.5).collect();
        for r in 0..n {
            let got = outputs[r].lock().unwrap();
            assert_eq!(&*got, &expect, "rank {r}");
        }
    }

    #[test]
    fn repeated_allreduce_deterministic() {
        let n = 8;
        let len = 4097; // non-divisible chunks
        let run = || {
            let comm = Arc::new(Comm::new(n, len));
            let out: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(vec![]));
            let out2 = Arc::clone(&out);
            let comm2 = Arc::clone(&comm);
            run_ranks(n, move |rank| {
                let mut rng = Rng::new(123, rank as u64);
                let mut buf = vec![0.0f32; len];
                rng.fill_normal(&mut buf, 1.0);
                for _ in 0..3 {
                    comm2.allreduce_mean(rank, &mut buf).unwrap();
                }
                if rank == 0 {
                    *out2.lock().unwrap() = buf;
                }
            });
            let v = out.lock().unwrap().clone();
            v
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "allreduce must be bit-deterministic");
    }

    #[test]
    fn all_ranks_agree_after_allreduce() {
        let n = 5;
        let len = 333;
        let comm = Arc::new(Comm::new(n, len));
        let outputs: Arc<Vec<Mutex<Vec<f32>>>> =
            Arc::new((0..n).map(|_| Mutex::new(vec![])).collect());
        {
            let comm = Arc::clone(&comm);
            let outputs = Arc::clone(&outputs);
            run_ranks(n, move |rank| {
                let mut rng = Rng::new(7, rank as u64);
                let mut buf = vec![0.0f32; len];
                rng.fill_normal(&mut buf, 2.0);
                comm.allreduce_mean(rank, &mut buf).unwrap();
                *outputs[rank].lock().unwrap() = buf;
            });
        }
        let first = outputs[0].lock().unwrap().clone();
        for r in 1..n {
            assert_eq!(*outputs[r].lock().unwrap(), first);
        }
    }

    #[test]
    fn scalar_sum_and_broadcast() {
        let n = 6;
        let comm = Arc::new(Comm::new(n, 8));
        let sums: Arc<Vec<Mutex<f64>>> = Arc::new((0..n).map(|_| Mutex::new(0.0)).collect());
        {
            let comm = Arc::clone(&comm);
            let sums = Arc::clone(&sums);
            run_ranks(n, move |rank| {
                let s = comm.allreduce_scalar_sum(rank, (rank + 1) as f64).unwrap();
                *sums[rank].lock().unwrap() = s;
                let mut buf = vec![rank as f32; 8];
                comm.broadcast(rank, &mut buf).unwrap();
                assert!(buf.iter().all(|&v| v == 0.0), "rank {rank} got {buf:?}");
            });
        }
        for r in 0..n {
            assert_eq!(*sums[r].lock().unwrap(), 21.0);
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let comm = Comm::new(1, 4);
        let mut buf = vec![1.0, 2.0, 3.0, 4.0];
        comm.allreduce_mean(0, &mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(comm.allreduce_scalar_sum(0, 5.0).unwrap(), 5.0);
    }

    #[test]
    fn sequential_scalar_rounds_do_not_interfere() {
        let n = 3;
        let comm = Arc::new(Comm::new(n, 1));
        let ok = Arc::new(Mutex::new(true));
        {
            let comm = Arc::clone(&comm);
            let ok = Arc::clone(&ok);
            run_ranks(n, move |rank| {
                for round in 0..50u64 {
                    let s = comm.allreduce_scalar_sum(rank, (round + rank as u64) as f64).unwrap();
                    let expect = (3 * round + 3) as f64; // sum over ranks 0..3 of round+rank
                    if (s - expect).abs() > 1e-12 {
                        *ok.lock().unwrap() = false;
                    }
                }
            });
        }
        assert!(*ok.lock().unwrap());
    }

    #[test]
    fn poison_unblocks_waiting_ranks() {
        // rank 1 never joins the collective; rank 2 poisons after a
        // delay; rank 0 must return Err instead of hanging forever.
        let n = 3;
        let comm = Arc::new(Comm::new(n, 64));
        let results: Arc<Vec<Mutex<Option<Result<(), Poisoned>>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        {
            let comm = Arc::clone(&comm);
            let results = Arc::clone(&results);
            run_ranks(n, move |rank| {
                match rank {
                    0 => {
                        let mut buf = vec![1.0f32; 64];
                        let r = comm.allreduce_mean(0, &mut buf);
                        *results[0].lock().unwrap() = Some(r);
                    }
                    1 => { /* failed node: never participates */ }
                    _ => {
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        comm.poison();
                        *results[2].lock().unwrap() = Some(Err(Poisoned));
                    }
                }
            });
        }
        assert_eq!(*results[0].lock().unwrap(), Some(Err(Poisoned)));
        assert!(comm.is_poisoned());
    }

    #[test]
    fn poisoned_comm_rejects_new_collectives() {
        let comm = Comm::new(2, 4);
        comm.poison();
        let mut buf = vec![0.0f32; 4];
        assert_eq!(comm.allreduce_mean(0, &mut buf), Err(Poisoned));
        assert_eq!(comm.allreduce_scalar_sum(1, 1.0), Err(Poisoned));
        assert_eq!(comm.broadcast(0, &mut buf), Err(Poisoned));
    }

    #[test]
    fn poison_is_idempotent_and_sticky() {
        let comm = Comm::new(2, 1);
        comm.poison();
        comm.poison();
        assert!(comm.is_poisoned());
        assert_eq!(comm.barrier(), Err(Poisoned));
    }
}
