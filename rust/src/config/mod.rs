//! Typed experiment configuration + TOML loading/validation.
//!
//! Every run of the system — examples, benches, the `adpsgd` launcher —
//! is described by an [`ExperimentConfig`].  Configs can be built in
//! code, loaded from a TOML file, or patched by `--key=value` CLI
//! overrides (see [`crate::cli`]).
//!
//! Strategy knobs have two forms:
//!
//! * **typed / nested (canonical)** — `[sync.<strategy>]` tables whose
//!   keys are exactly the knobs that strategy consumes (see
//!   [`spec::StrategySpec`]); the same keys work as dotted CLI
//!   overrides (`--sync.adaptive.p_init=4`).
//! * **legacy flat** — the historical `[sync]` keys (`sync.p_init`,
//!   `sync.qsgd_levels`, …).  They keep loading through a compat layer
//!   (with a one-time deprecation note on stderr), and nested keys win
//!   when both are present.
//!
//! CLI overrides are checked against the *chosen* strategy: a knob that
//! belongs to a different strategy (`--sync.qsgd_levels` under
//! `sync.strategy = adaptive`) is an error that lists the valid keys,
//! instead of being silently absorbed into an unused field.

pub mod spec;
pub mod toml;

pub use spec::StrategySpec;

use crate::collective::Algo as CollectiveAlgo;
use crate::period::Strategy;
use anyhow::{anyhow, bail, Context, Result};
use toml::{TomlDoc, TomlValue};

/// Which compute backend executes the local SGD step.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Pure-rust workload (fast; used for the statistics figures).
    Native(String),
    /// AOT-compiled HLO executed via PJRT (the product path).
    Hlo(String),
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Native("mlp".into())
    }
}

/// Learning-rate schedule (paper §IV: step decay for CIFAR, gradual
/// warmup + step decay for ImageNet).
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    Const,
    /// lr0 scaled by `factor` at each boundary iteration.
    StepDecay { boundaries: Vec<usize>, factor: f32 },
    /// Linear ramp from lr0/warmup_factor to lr0 over `warmup_iters`,
    /// then step decay.
    Warmup { warmup_iters: usize, warmup_factor: f32, boundaries: Vec<usize>, factor: f32 },
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule::StepDecay { boundaries: vec![2000, 3000], factor: 0.1 }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct OptimConfig {
    pub lr0: f32,
    pub momentum: f32,
    pub schedule: LrSchedule,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig { lr0: 0.1, momentum: 0.9, schedule: LrSchedule::default() }
    }
}

/// Synchronization strategy configuration (the paper's knobs).
#[derive(Debug, Clone, PartialEq)]
pub struct SyncConfig {
    pub strategy: Strategy,
    /// CPSGD period (also the fallback/logging initial period).
    pub period: usize,
    /// ADPSGD: p_init after the warmup epoch (paper: 4).
    pub p_init: usize,
    /// ADPSGD: iterations with p=1 before Algorithm 2 engages (paper:
    /// "averaging period of 1 for the first epoch").
    pub warmup_iters: usize,
    /// ADPSGD: C2-sampling horizon K_s, as a fraction of total iters
    /// (paper: K_s = 0.25K CIFAR, 0.2K ImageNet).
    pub ks_frac: f64,
    /// ADPSGD thresholds (paper: 0.7 / 1.3).
    pub low: f64,
    pub high: f64,
    /// Decreasing-period strawman (§V-B): period before/after the switch.
    pub dec_first: usize,
    pub dec_second: usize,
    /// QSGD: quantization levels (paper: 8 bits -> 255) and bucket size.
    pub qsgd_levels: u32,
    pub qsgd_bucket: usize,
    /// Piecewise schedule spec ("0:4,2000:8") for [`Strategy::Piecewise`].
    pub piecewise: String,
    /// EASGD elastic coefficient α (fraction each node moves toward the
    /// mean at a sync; 1.0 degenerates to CPSGD).
    pub easgd_alpha: f64,
    /// Per-strategy period storage: CPSGD and EASGD both *consume* a
    /// period, but each strategy's `[sync.<strategy>]` table stores its
    /// value here independently (`None` = fall back to the shared legacy
    /// `period` field), so one base config can configure both without
    /// last-writer-wins between the tables.
    pub constant_period: Option<usize>,
    pub easgd_period: Option<usize>,
    /// Top-k sparsification: fraction of gradient components kept.
    pub topk_frac: f64,
    /// AdaComm: initial (and maximum) averaging period τ0.
    pub adacomm_tau0: usize,
    /// PR-SGD / DaSGD periods use the same per-strategy slot discipline
    /// as `constant_period` / `easgd_period` (None = legacy `period`).
    pub prsgd_period: Option<usize>,
    pub dasgd_period: Option<usize>,
    /// DaSGD: local steps the averaging result lags behind its launch.
    pub dasgd_delay: usize,
    /// Which collective algorithm executes (and prices) the exchanges:
    /// `ring` (chunked reduce-scatter + all-gather, the default) or
    /// `flat` (leader-serialized reference).  Both produce bit-identical
    /// reductions; they differ in measured and modeled wall-clock.
    pub collective: CollectiveAlgo,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            strategy: Strategy::Adaptive,
            period: 8,
            p_init: 4,
            warmup_iters: 0,
            ks_frac: 0.25,
            low: 0.7,
            high: 1.3,
            dec_first: 20,
            dec_second: 5,
            qsgd_levels: 255,
            qsgd_bucket: 512,
            piecewise: "0:4,2000:8".into(),
            easgd_alpha: 0.5,
            constant_period: None,
            easgd_period: None,
            topk_frac: 0.03125,
            adacomm_tau0: 16,
            prsgd_period: None,
            dasgd_period: None,
            dasgd_delay: 2,
            collective: CollectiveAlgo::Ring,
        }
    }
}

/// Network cost-model configuration (see [`crate::netsim`]).
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { bandwidth_gbps: 100.0, latency_us: 2.0 }
    }
}

impl NetConfig {
    /// Preset names accepted by the `net.preset` config key.
    pub const PRESETS: [&'static str; 2] = ["infiniband_100g", "ethernet_10g"];

    pub fn infiniband_100g() -> Self {
        NetConfig { bandwidth_gbps: 100.0, latency_us: 2.0 }
    }
    /// Paper's throttled-cloud setting (trickle to 5Gbps up/down).
    pub fn ethernet_10g() -> Self {
        NetConfig { bandwidth_gbps: 10.0, latency_us: 25.0 }
    }

    /// Look up a named preset; unknown names error listing the valid
    /// set (the parse-time contract of the `net.preset` key).
    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "infiniband_100g" => Ok(Self::infiniband_100g()),
            "ethernet_10g" => Ok(Self::ethernet_10g()),
            other => bail!(
                "net.preset: unknown preset {other:?} (valid presets: {})",
                Self::PRESETS.join(", ")
            ),
        }
    }
}

/// Seeded fault-injection schedule declared per run: *how many* node
/// pauses and packet-delay spikes to place; concrete placement is
/// derived deterministically by
/// [`crate::netsim::cluster::FaultSchedule::generate`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// fault-placement seed; 0 = derive from the experiment seed
    pub seed: u64,
    /// number of node pauses to inject across the run
    pub pauses: usize,
    /// duration of each pause, seconds of modeled time
    pub pause_secs: f64,
    /// number of packet-delay spikes to inject
    pub spikes: usize,
    /// extra per-message latency while a spike is active, seconds
    pub spike_secs: f64,
    /// spike duration, iterations
    pub spike_len: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            pauses: 0,
            pause_secs: 0.5,
            spikes: 0,
            spike_secs: 1e-3,
            spike_len: 8,
        }
    }
}

/// Heterogeneous-cluster model configuration (the `[cluster]` table).
/// All knobs here shape *modeled* clocks and comm pricing only — they
/// never touch parameter math, so results stay bit-identical across
/// every cluster setting of the same seed.  They are still
/// result-affecting for the run report (modeled wall-clock), so every
/// key enters the run-cache digest.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// per-node compute skew spec: "none", "linear:<spread>" (factors
    /// ramp 1.0 → 1.0+spread across ranks), or "straggler:<factor>"
    /// (last rank is factor× slower)
    pub skew: String,
    /// explicit per-node compute factors (length = nodes); wins over
    /// `skew` when non-empty
    pub factors: Vec<f64>,
    /// nominal modeled per-step compute time, microseconds
    pub step_us: f64,
    /// seeded per-step jitter as a fraction of the node's step time
    pub jitter: f64,
    /// per-node uplink bandwidth overrides, Gbps (length = nodes, or
    /// empty for the uniform `[net]` link)
    pub link_bw_gbps: Vec<f64>,
    /// per-node uplink latency overrides, microseconds
    pub link_latency_us: Vec<f64>,
    pub faults: FaultConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            skew: "none".into(),
            factors: Vec::new(),
            step_us: 1000.0,
            jitter: 0.0,
            link_bw_gbps: Vec::new(),
            link_latency_us: Vec::new(),
            faults: FaultConfig::default(),
        }
    }
}

/// Workload/data configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub backend: Backend,
    /// synthetic classification: input dim / classes / difficulty
    pub input_dim: usize,
    pub classes: usize,
    pub hidden: usize,
    pub noise: f32,
    pub label_noise: f32,
    /// held-out evaluation batches
    pub eval_batches: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            backend: Backend::default(),
            input_dim: 256,
            classes: 10,
            hidden: 128,
            noise: 1.0,
            label_noise: 0.05,
            eval_batches: 16,
        }
    }
}

/// Execution-performance knobs.  Incidental by construction: they
/// change wall-clock, never results (the kernels are bit-identical at
/// any thread count), so the run-cache digest excludes them the same
/// way it excludes `name` and the scheduler's `jobs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfConfig {
    /// worker threads for the parallel tensor kernels
    /// (`tensor::par`): 0 = auto (one per core), 1 = serial
    pub threads: usize,
}

impl Default for PerfConfig {
    fn default() -> Self {
        PerfConfig { threads: 0 }
    }
}

/// Top-level experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// number of simulated nodes (paper: up to 16)
    pub nodes: usize,
    /// total iterations K
    pub iters: usize,
    /// per-node mini-batch size (paper: 128)
    pub batch_per_node: usize,
    pub eval_every: usize,
    /// record Var[W_k] every this many iterations (0 = off). This is
    /// measurement instrumentation (not charged to the comm ledger).
    pub variance_every: usize,
    pub threads: usize,
    pub perf: PerfConfig,
    pub workload: WorkloadConfig,
    pub optim: OptimConfig,
    pub sync: SyncConfig,
    pub net: NetConfig,
    pub cluster: ClusterConfig,
    /// directory with AOT artifacts (HLO backend)
    pub artifacts_dir: String,
    /// write a parameter snapshot every this many iterations (0 = off)
    pub checkpoint_every: usize,
    /// where snapshots go (created on demand)
    pub checkpoint_dir: String,
    /// warm-start parameters from this checkpoint file (or a directory,
    /// in which case the latest snapshot is used)
    pub init_from: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            seed: 42,
            nodes: 16,
            iters: 4000,
            batch_per_node: 32,
            eval_every: 200,
            variance_every: 0,
            threads: 0,
            perf: PerfConfig::default(),
            workload: WorkloadConfig::default(),
            optim: OptimConfig::default(),
            sync: SyncConfig::default(),
            net: NetConfig::default(),
            cluster: ClusterConfig::default(),
            artifacts_dir: "artifacts".into(),
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            init_from: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// Total mini-batch M = nodes * batch_per_node (paper: 16*128 = 2048).
    pub fn total_batch(&self) -> usize {
        self.nodes * self.batch_per_node
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            bail!("nodes must be >= 1");
        }
        if self.iters == 0 {
            bail!("iters must be >= 1");
        }
        if self.batch_per_node == 0 {
            bail!("batch_per_node must be >= 1");
        }
        if !(self.optim.lr0 > 0.0) {
            bail!("lr0 must be positive");
        }
        if !(0.0..1.0).contains(&self.optim.momentum) {
            bail!("momentum must be in [0, 1)");
        }
        let s = &self.sync;
        if s.period == 0 || s.p_init == 0 {
            bail!("periods must be >= 1");
        }
        if !(s.low < 1.0 && s.high > 1.0) {
            bail!("adaptive thresholds must straddle 1.0 (low < 1 < high)");
        }
        if !(0.0..=1.0).contains(&s.ks_frac) {
            bail!("ks_frac must be in [0, 1]");
        }
        if s.qsgd_levels == 0 || s.qsgd_bucket == 0 {
            bail!("qsgd parameters must be >= 1");
        }
        if s.strategy == Strategy::Piecewise {
            crate::period::Piecewise::parse(&s.piecewise)
                .map_err(|e| anyhow!("sync.piecewise: {e}"))?;
        }
        if !(0.0 < s.easgd_alpha && s.easgd_alpha <= 1.0) {
            bail!("easgd_alpha must be in (0, 1]");
        }
        if !(0.0 < s.topk_frac && s.topk_frac <= 1.0) {
            bail!("topk_frac must be in (0, 1]");
        }
        if self.net.bandwidth_gbps <= 0.0 || self.net.latency_us < 0.0 {
            bail!("network parameters must be positive");
        }
        let cl = &self.cluster;
        if !(cl.step_us > 0.0) || !cl.step_us.is_finite() {
            bail!("cluster.step_us must be a positive finite number");
        }
        if !(0.0..1.0).contains(&cl.jitter) {
            bail!("cluster.jitter must be in [0, 1)");
        }
        let f = &cl.faults;
        if !(f.pause_secs >= 0.0 && f.pause_secs.is_finite())
            || !(f.spike_secs >= 0.0 && f.spike_secs.is_finite())
        {
            bail!("cluster.faults durations must be non-negative finite numbers");
        }
        // skew grammar, factor/link array shapes, and value ranges are
        // the cluster model's own build-time checks
        crate::netsim::cluster::ClusterModel::from_config(
            cl, &self.net, self.nodes, self.iters, self.seed,
        )?;
        // per-strategy half: the typed spec validates its own knobs
        self.sync.spec().validate()?;
        Ok(())
    }

    /// Parse an override value the way TOML would, falling back to a
    /// bare string (CLI users don't quote strategy names).
    pub(crate) fn parse_override_value(v: &str) -> TomlValue {
        toml::TomlDoc::parse(&format!("x = {v}"))
            .ok()
            .and_then(|d| d.get("x").cloned())
            .unwrap_or_else(|| TomlValue::Str(v.to_string()))
    }

    /// Load from a TOML file, then apply `overrides` ("key=value" pairs,
    /// dotted keys matching the TOML schema).  Override keys are
    /// strictly checked against the chosen strategy's knob set.
    pub fn from_file(path: &str, overrides: &[(String, String)]) -> Result<Self> {
        let cfg = Self::from_file_lenient(path, overrides)?;
        Self::check_override_keys(&[cfg.sync.strategy], overrides)?;
        Ok(cfg)
    }

    /// [`Self::from_file`] without the per-strategy override check — for
    /// callers that sweep several strategies (`adpsgd campaign`) and
    /// validate overrides against the whole swept set themselves via
    /// [`Self::check_override_keys`].
    pub fn from_file_lenient(path: &str, overrides: &[(String, String)]) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        for (k, v) in overrides {
            doc.entries.insert(k.clone(), Self::parse_override_value(v));
        }
        Self::from_doc(&doc)
    }

    /// Build a config from dotted overrides alone (no file) — what
    /// `adpsgd run` does when `--config` is absent.
    pub fn from_overrides(overrides: &[(String, String)]) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_overrides(overrides)?;
        Ok(cfg)
    }

    /// Apply dotted overrides on top of this config (strictly checked
    /// against the chosen strategy), then re-validate.
    pub fn apply_overrides(&mut self, overrides: &[(String, String)]) -> Result<()> {
        self.apply_overrides_lenient(overrides)?;
        Self::check_override_keys(&[self.sync.strategy], overrides)
    }

    /// [`Self::apply_overrides`] without the per-strategy check (see
    /// [`Self::from_file_lenient`]).
    pub fn apply_overrides_lenient(&mut self, overrides: &[(String, String)]) -> Result<()> {
        let mut doc = TomlDoc::default();
        for (k, v) in overrides {
            doc.entries.insert(k.clone(), Self::parse_override_value(v));
        }
        self.apply_doc(&doc)?;
        self.validate()
    }

    /// Reject override keys that are unknown or belong to a strategy
    /// outside `strategies` (one entry for a single run; the swept set
    /// for a campaign), listing the valid key set.
    pub fn check_override_keys(
        strategies: &[Strategy],
        overrides: &[(String, String)],
    ) -> Result<()> {
        let snames: Vec<&str> =
            strategies.iter().map(|s| spec::canonical_name(*s)).collect();
        let sdesc = if snames.len() == 1 {
            format!("sync.strategy = {}", snames[0])
        } else {
            format!("the swept strategies are {{{}}}", snames.join(", "))
        };
        let valid_desc = || -> String {
            strategies.iter().map(|s| spec::describe_keys(*s)).collect::<Vec<_>>().join("; ")
        };
        for (k, _) in overrides {
            let Some(rest) = k.strip_prefix("sync.") else { continue };
            if rest == "strategy" || rest == "collective" {
                continue;
            }
            if let Some((table, key)) = rest.split_once('.') {
                let Some(tkind) = spec::kind_for_table(table) else {
                    // defense for standalone callers; the doc-level
                    // known-key check usually rejects these first
                    bail!(
                        "override --{k}: unknown strategy table \"sync.{table}\" \
                         (strategies: full|constant|adaptive|decreasing|qsgd|piecewise|easgd|\
                         topk|adacomm|prsgd|dasgd)"
                    );
                };
                if !strategies.contains(&tkind) {
                    bail!(
                        "override --{k} configures strategy {}, but {sdesc}; \
                         valid sync keys: {}",
                        spec::canonical_name(tkind),
                        valid_desc()
                    );
                }
                if !spec::nested_keys(tkind).contains(&key) {
                    bail!(
                        "override --{k}: {} has no knob {key:?}; valid sync keys: {}",
                        spec::canonical_name(tkind),
                        valid_desc()
                    );
                }
            } else if !strategies.iter().any(|s| spec::legacy_fields(*s).contains(&rest)) {
                let owners: Vec<&str> = spec::ALL_STRATEGIES
                    .into_iter()
                    .filter(|s| spec::legacy_fields(*s).contains(&rest))
                    .map(spec::canonical_name)
                    .collect();
                if owners.is_empty() {
                    // not a strategy knob at all (unknown keys are
                    // rejected earlier by the known-key check)
                    continue;
                }
                bail!(
                    "override --{k} is a {} knob, but {sdesc}; valid sync keys: {}",
                    owners.join("/"),
                    valid_desc()
                );
            }
        }
        Ok(())
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_doc(doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical dotted-key document form of the fully-resolved config:
    /// every field of the TOML schema, with the strategy knobs written
    /// as nested `[sync.<strategy>]` keys for *all* strategies (so sweep
    /// bases survive).  Round-trips through [`Self::from_doc`] to a
    /// config whose every strategy projection ([`SyncConfig::spec_of`])
    /// is equal, and is idempotent (`to_doc(from_doc(d)) == d` for `d`
    /// produced here) — the substrate for the dispatch layer's run-cache
    /// digest and worker wire format.
    pub fn to_doc(&self) -> TomlDoc {
        let mut doc = TomlDoc::default();
        let mut set = |k: &str, v: TomlValue| {
            doc.entries.insert(k.to_string(), v);
        };
        let s = |v: &str| TomlValue::Str(v.to_string());
        let i = |v: usize| TomlValue::Int(v as i64);

        set("name", s(&self.name));
        set("seed", TomlValue::Int(self.seed as i64));
        set("nodes", i(self.nodes));
        set("iters", i(self.iters));
        set("batch_per_node", i(self.batch_per_node));
        set("eval_every", i(self.eval_every));
        set("variance_every", i(self.variance_every));
        set("threads", i(self.threads));
        set("perf.threads", i(self.perf.threads));
        set("artifacts_dir", s(&self.artifacts_dir));
        set("checkpoint_every", i(self.checkpoint_every));
        set("checkpoint_dir", s(&self.checkpoint_dir));
        set("init_from", s(&self.init_from));

        let (backend, model) = match &self.workload.backend {
            Backend::Native(m) => ("native", m),
            Backend::Hlo(m) => ("hlo", m),
        };
        set("workload.backend", s(backend));
        set("workload.model", s(model));
        set("workload.input_dim", i(self.workload.input_dim));
        set("workload.classes", i(self.workload.classes));
        set("workload.hidden", i(self.workload.hidden));
        set("workload.noise", TomlValue::Float(self.workload.noise as f64));
        set("workload.label_noise", TomlValue::Float(self.workload.label_noise as f64));
        set("workload.eval_batches", i(self.workload.eval_batches));

        set("optim.lr0", TomlValue::Float(self.optim.lr0 as f64));
        set("optim.momentum", TomlValue::Float(self.optim.momentum as f64));
        let bounds = |b: &[usize]| {
            TomlValue::Arr(b.iter().map(|x| TomlValue::Int(*x as i64)).collect())
        };
        match &self.optim.schedule {
            LrSchedule::Const => set("optim.schedule", s("const")),
            LrSchedule::StepDecay { boundaries, factor } => {
                set("optim.schedule", s("step"));
                set("optim.boundaries", bounds(boundaries));
                set("optim.factor", TomlValue::Float(*factor as f64));
            }
            LrSchedule::Warmup { warmup_iters, warmup_factor, boundaries, factor } => {
                set("optim.schedule", s("warmup"));
                set("optim.warmup_iters", i(*warmup_iters));
                set("optim.warmup_factor", TomlValue::Float(*warmup_factor as f64));
                set("optim.boundaries", bounds(boundaries));
                set("optim.factor", TomlValue::Float(*factor as f64));
            }
        }

        set("sync.strategy", s(spec::canonical_name(self.sync.strategy)));
        set("sync.collective", s(&self.sync.collective.to_string()));
        for kind in spec::ALL_STRATEGIES {
            let name = spec::canonical_name(kind);
            for (key, val) in self.sync.spec_of(kind).nested_entries() {
                doc.entries.insert(format!("sync.{name}.{key}"), val);
            }
        }

        doc.entries.insert(
            "net.bandwidth_gbps".into(),
            TomlValue::Float(self.net.bandwidth_gbps),
        );
        doc.entries.insert("net.latency_us".into(), TomlValue::Float(self.net.latency_us));
        // `net.preset` is intentionally absent: presets resolve to the
        // bandwidth/latency values above at parse time, and the resolved
        // values are the canonical (digest) form.

        // cluster: every knob is result-affecting (modeled clocks enter
        // the run report), so all of them belong to the digest substrate
        let farr = |xs: &[f64]| TomlValue::Arr(xs.iter().map(|x| TomlValue::Float(*x)).collect());
        doc.entries.insert("cluster.skew".into(), TomlValue::Str(self.cluster.skew.clone()));
        doc.entries.insert("cluster.factors".into(), farr(&self.cluster.factors));
        doc.entries.insert("cluster.step_us".into(), TomlValue::Float(self.cluster.step_us));
        doc.entries.insert("cluster.jitter".into(), TomlValue::Float(self.cluster.jitter));
        doc.entries.insert("cluster.link_bw_gbps".into(), farr(&self.cluster.link_bw_gbps));
        doc.entries
            .insert("cluster.link_latency_us".into(), farr(&self.cluster.link_latency_us));
        let fl = &self.cluster.faults;
        doc.entries.insert("cluster.faults.seed".into(), TomlValue::Int(fl.seed as i64));
        doc.entries.insert("cluster.faults.pauses".into(), TomlValue::Int(fl.pauses as i64));
        doc.entries.insert("cluster.faults.pause_secs".into(), TomlValue::Float(fl.pause_secs));
        doc.entries.insert("cluster.faults.spikes".into(), TomlValue::Int(fl.spikes as i64));
        doc.entries.insert("cluster.faults.spike_secs".into(), TomlValue::Float(fl.spike_secs));
        doc.entries
            .insert("cluster.faults.spike_len".into(), TomlValue::Int(fl.spike_len as i64));
        doc
    }

    /// [`Self::to_doc`] rendered as canonical TOML text (byte-stable for
    /// equal configs).  Errors only on strings the TOML subset cannot
    /// represent (embedded quotes or line breaks in names/paths).
    pub fn to_toml_string(&self) -> Result<String> {
        self.to_doc().render().map_err(|e| anyhow!("serializing config: {e}"))
    }

    /// Apply a parsed document onto this config (no validation) — the
    /// shared core of [`Self::from_doc`], [`Self::from_file`], and the
    /// experiment builder's dotted `set()` overrides.
    pub(crate) fn apply_doc(&mut self, doc: &TomlDoc) -> Result<()> {
        let cfg = self;
        let known = Self::known_keys();
        for key in doc.entries.keys() {
            if !known.iter().any(|k| k == key) {
                bail!(
                    "unknown config key {key:?} (top-level: name seed nodes iters \
                     batch_per_node eval_every variance_every threads artifacts_dir \
                     checkpoint_every checkpoint_dir init_from; sections: workload optim \
                     sync net cluster perf; per-strategy tables: [sync.<strategy>] — \
                     run `adpsgd help` for the schema)"
                );
            }
        }
        let gs = |k: &str| doc.get(k).and_then(TomlValue::as_str).map(str::to_string);
        let gi = |k: &str| doc.get(k).and_then(TomlValue::as_i64);
        let gf = |k: &str| doc.get(k).and_then(TomlValue::as_f64);

        if let Some(v) = gs("name") {
            cfg.name = v;
        }
        if let Some(v) = gi("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = gi("nodes") {
            cfg.nodes = v as usize;
        }
        if let Some(v) = gi("iters") {
            cfg.iters = v as usize;
        }
        if let Some(v) = gi("batch_per_node") {
            cfg.batch_per_node = v as usize;
        }
        if let Some(v) = gi("eval_every") {
            cfg.eval_every = v as usize;
        }
        if let Some(v) = gi("variance_every") {
            cfg.variance_every = v as usize;
        }
        if let Some(v) = gi("threads") {
            cfg.threads = v as usize;
        }
        if let Some(v) = gi("perf.threads") {
            cfg.perf.threads = v as usize;
        }
        if let Some(v) = gs("artifacts_dir") {
            cfg.artifacts_dir = v;
        }
        if let Some(v) = gi("checkpoint_every") {
            cfg.checkpoint_every = v as usize;
        }
        if let Some(v) = gs("checkpoint_dir") {
            cfg.checkpoint_dir = v;
        }
        if let Some(v) = gs("init_from") {
            cfg.init_from = v;
        }

        // workload
        if let Some(b) = gs("workload.backend") {
            let name = gs("workload.model").unwrap_or_else(|| "mlp".into());
            cfg.workload.backend = match b.as_str() {
                "native" => Backend::Native(name),
                "hlo" => Backend::Hlo(name),
                other => bail!("workload.backend must be native|hlo, got {other:?}"),
            };
        }
        if let Some(v) = gi("workload.input_dim") {
            cfg.workload.input_dim = v as usize;
        }
        if let Some(v) = gi("workload.classes") {
            cfg.workload.classes = v as usize;
        }
        if let Some(v) = gi("workload.hidden") {
            cfg.workload.hidden = v as usize;
        }
        if let Some(v) = gf("workload.noise") {
            cfg.workload.noise = v as f32;
        }
        if let Some(v) = gf("workload.label_noise") {
            cfg.workload.label_noise = v as f32;
        }
        if let Some(v) = gi("workload.eval_batches") {
            cfg.workload.eval_batches = v as usize;
        }

        // optim
        if let Some(v) = gf("optim.lr0") {
            cfg.optim.lr0 = v as f32;
        }
        if let Some(v) = gf("optim.momentum") {
            cfg.optim.momentum = v as f32;
        }
        if let Some(v) = gs("optim.schedule") {
            let boundaries: Vec<usize> = doc
                .get("optim.boundaries")
                .and_then(TomlValue::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_i64().map(|i| i as usize)).collect())
                .unwrap_or_else(|| vec![2000, 3000]);
            let factor = gf("optim.factor").unwrap_or(0.1) as f32;
            cfg.optim.schedule = match v.as_str() {
                "const" => LrSchedule::Const,
                "step" => LrSchedule::StepDecay { boundaries, factor },
                "warmup" => LrSchedule::Warmup {
                    warmup_iters: gi("optim.warmup_iters").unwrap_or(0) as usize,
                    warmup_factor: gf("optim.warmup_factor").unwrap_or(8.0) as f32,
                    boundaries,
                    factor,
                },
                other => bail!("optim.schedule must be const|step|warmup, got {other:?}"),
            };
        }

        // sync
        if let Some(v) = gs("sync.strategy") {
            cfg.sync.strategy = v.parse()?;
        }
        if let Some(v) = gi("sync.period") {
            cfg.sync.period = v as usize;
            // the legacy flat key targets the shared carrier: reset the
            // per-strategy slots so this document's value takes effect
            // (nested [sync.constant]/[sync.easgd]/... tables in the
            // same document re-apply below and still win over the flat
            // key)
            cfg.sync.constant_period = None;
            cfg.sync.easgd_period = None;
            cfg.sync.prsgd_period = None;
            cfg.sync.dasgd_period = None;
        }
        if let Some(v) = gi("sync.p_init") {
            cfg.sync.p_init = v as usize;
        }
        if let Some(v) = gi("sync.warmup_iters") {
            cfg.sync.warmup_iters = v as usize;
        }
        if let Some(v) = gf("sync.ks_frac") {
            cfg.sync.ks_frac = v;
        }
        if let Some(v) = gf("sync.low") {
            cfg.sync.low = v;
        }
        if let Some(v) = gf("sync.high") {
            cfg.sync.high = v;
        }
        if let Some(v) = gi("sync.dec_first") {
            cfg.sync.dec_first = v as usize;
        }
        if let Some(v) = gi("sync.dec_second") {
            cfg.sync.dec_second = v as usize;
        }
        if let Some(v) = gi("sync.qsgd_levels") {
            cfg.sync.qsgd_levels = v as u32;
        }
        if let Some(v) = gi("sync.qsgd_bucket") {
            cfg.sync.qsgd_bucket = v as usize;
        }
        if let Some(v) = gs("sync.piecewise") {
            cfg.sync.piecewise = v;
        }
        if let Some(v) = gf("sync.easgd_alpha") {
            cfg.sync.easgd_alpha = v;
        }
        if let Some(v) = gf("sync.topk_frac") {
            cfg.sync.topk_frac = v;
        }
        if let Some(v) = gi("sync.adacomm_tau0") {
            cfg.sync.adacomm_tau0 = v as usize;
        }
        if let Some(v) = gi("sync.dasgd_delay") {
            cfg.sync.dasgd_delay = v as usize;
        }
        if let Some(v) = gs("sync.collective") {
            cfg.sync.collective = v.parse()?;
        }

        // net: the preset resolves first so explicit keys in the same
        // document refine it
        if let Some(v) = gs("net.preset") {
            cfg.net = NetConfig::preset(&v)?;
        }
        if let Some(v) = gf("net.bandwidth_gbps") {
            cfg.net.bandwidth_gbps = v;
        }
        if let Some(v) = gf("net.latency_us") {
            cfg.net.latency_us = v;
        }

        // cluster
        let garr = |k: &str| -> Result<Option<Vec<f64>>> {
            let Some(v) = doc.get(k) else { return Ok(None) };
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("{k}: expected an array of numbers"))?;
            arr.iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow!("{k}: expected an array of numbers")))
                .collect::<Result<Vec<f64>>>()
                .map(Some)
        };
        if let Some(v) = gs("cluster.skew") {
            // parse eagerly so a bad spec fails at load, not at run
            v.parse::<crate::netsim::cluster::Skew>()?;
            cfg.cluster.skew = v;
        }
        if let Some(v) = garr("cluster.factors")? {
            cfg.cluster.factors = v;
        }
        if let Some(v) = gf("cluster.step_us") {
            cfg.cluster.step_us = v;
        }
        if let Some(v) = gf("cluster.jitter") {
            cfg.cluster.jitter = v;
        }
        if let Some(v) = garr("cluster.link_bw_gbps")? {
            cfg.cluster.link_bw_gbps = v;
        }
        if let Some(v) = garr("cluster.link_latency_us")? {
            cfg.cluster.link_latency_us = v;
        }
        if let Some(v) = gi("cluster.faults.seed") {
            cfg.cluster.faults.seed = v as u64;
        }
        if let Some(v) = gi("cluster.faults.pauses") {
            cfg.cluster.faults.pauses = v as usize;
        }
        if let Some(v) = gf("cluster.faults.pause_secs") {
            cfg.cluster.faults.pause_secs = v;
        }
        if let Some(v) = gi("cluster.faults.spikes") {
            cfg.cluster.faults.spikes = v as usize;
        }
        if let Some(v) = gf("cluster.faults.spike_secs") {
            cfg.cluster.faults.spike_secs = v;
        }
        if let Some(v) = gi("cluster.faults.spike_len") {
            cfg.cluster.faults.spike_len = v as usize;
        }

        // nested per-strategy tables: every [sync.<strategy>] table is
        // applied into the carrier, so tables for strategies not
        // currently chosen still configure those strategies' knobs for
        // campaign sweeps (read back via `SyncConfig::spec_of`).  Each
        // strategy owns its storage — constant and easgd keep their
        // periods in distinct slots (`constant_period`/`easgd_period`)
        // despite sharing the legacy flat `period` fallback — so table
        // application order does not matter and no table can leak into
        // another strategy's knobs.
        // (project every spec against the pre-table carrier first, then
        // apply, so one table's writes never feed another's projection)
        let mut overlaid: Vec<spec::StrategySpec> = Vec::new();
        for kind in spec::ALL_STRATEGIES {
            let mut sp = cfg.sync.spec_of(kind);
            let mut touched = false;
            for table in spec::table_names(kind) {
                for key in spec::nested_keys(kind) {
                    if let Some(v) = doc.get(&format!("sync.{table}.{key}")) {
                        sp.set_nested(key, v)?;
                        touched = true;
                    }
                }
            }
            if touched {
                overlaid.push(sp);
            }
        }
        for sp in overlaid {
            sp.apply_knobs_to(&mut cfg.sync);
        }

        // legacy flat strategy knobs still load — note it once
        let legacy_used = doc.entries.keys().any(|k| {
            k.strip_prefix("sync.").is_some_and(|f| {
                !f.contains('.')
                    && spec::ALL_STRATEGIES
                        .into_iter()
                        .any(|s| spec::legacy_fields(s).contains(&f))
            })
        });
        if legacy_used {
            static NOTE: std::sync::Once = std::sync::Once::new();
            NOTE.call_once(|| {
                eprintln!(
                    "note: flat [sync] strategy keys (sync.p_init, sync.qsgd_levels, ...) are \
                     deprecated; prefer [sync.<strategy>] tables (e.g. [sync.adaptive]). \
                     Legacy keys keep loading."
                );
            });
        }

        Ok(())
    }

    fn known_keys() -> Vec<String> {
        let mut keys: Vec<String> = [
            "name",
            "seed",
            "nodes",
            "iters",
            "batch_per_node",
            "eval_every",
            "variance_every",
            "threads",
            "perf.threads",
            "artifacts_dir",
            "checkpoint_every",
            "checkpoint_dir",
            "init_from",
            "workload.backend",
            "workload.model",
            "workload.input_dim",
            "workload.classes",
            "workload.hidden",
            "workload.noise",
            "workload.label_noise",
            "workload.eval_batches",
            "optim.lr0",
            "optim.momentum",
            "optim.schedule",
            "optim.boundaries",
            "optim.factor",
            "optim.warmup_iters",
            "optim.warmup_factor",
            "sync.strategy",
            "sync.period",
            "sync.p_init",
            "sync.warmup_iters",
            "sync.ks_frac",
            "sync.low",
            "sync.high",
            "sync.dec_first",
            "sync.dec_second",
            "sync.qsgd_levels",
            "sync.qsgd_bucket",
            "sync.piecewise",
            "sync.easgd_alpha",
            "sync.topk_frac",
            "sync.adacomm_tau0",
            "sync.dasgd_delay",
            "sync.collective",
            "net.preset",
            "net.bandwidth_gbps",
            "net.latency_us",
            "cluster.skew",
            "cluster.factors",
            "cluster.step_us",
            "cluster.jitter",
            "cluster.link_bw_gbps",
            "cluster.link_latency_us",
            "cluster.faults.seed",
            "cluster.faults.pauses",
            "cluster.faults.pause_secs",
            "cluster.faults.spikes",
            "cluster.faults.spike_secs",
            "cluster.faults.spike_len",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for kind in spec::ALL_STRATEGIES {
            for table in spec::table_names(kind) {
                for key in spec::nested_keys(kind) {
                    keys.push(format!("sync.{table}.{key}"));
                }
            }
        }
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_document() {
        let doc = TomlDoc::parse(
            r#"
name = "fig4"
nodes = 16
iters = 4000
batch_per_node = 128

[workload]
backend = "native"
model = "mlp"
input_dim = 256

[optim]
lr0 = 0.1
schedule = "step"
boundaries = [2000, 3000]
factor = 0.1

[sync]
strategy = "adaptive"
p_init = 4
ks_frac = 0.25

[net]
bandwidth_gbps = 10.0
latency_us = 25.0
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "fig4");
        assert_eq!(cfg.total_batch(), 2048);
        assert_eq!(cfg.sync.strategy, Strategy::Adaptive);
        assert_eq!(cfg.net.bandwidth_gbps, 10.0);
        match &cfg.optim.schedule {
            LrSchedule::StepDecay { boundaries, .. } => assert_eq!(boundaries, &[2000, 3000]),
            other => panic!("wrong schedule {other:?}"),
        }
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = TomlDoc::parse("tpyo = 1").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        let doc = TomlDoc::parse("nodes = 0").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[sync]\nlow = 1.5").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn collective_knob_parses() {
        let doc = TomlDoc::parse("[sync]\ncollective = \"flat\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.collective, CollectiveAlgo::Flat);
        // default is ring
        assert_eq!(ExperimentConfig::default().sync.collective, CollectiveAlgo::Ring);
        // unknown algorithms are rejected at parse time
        let bad = TomlDoc::parse("[sync]\ncollective = \"mesh\"").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn hlo_backend_parses() {
        let doc = TomlDoc::parse("[workload]\nbackend = \"hlo\"\nmodel = \"mlp_small\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.workload.backend, Backend::Hlo("mlp_small".into()));
    }

    #[test]
    fn nested_strategy_table_parses_and_beats_flat() {
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"adaptive\"\np_init = 2\n\n[sync.adaptive]\np_init = 6\nks_frac = 0.2",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.p_init, 6, "nested key must win over flat");
        assert_eq!(cfg.sync.ks_frac, 0.2);
        assert_eq!(
            cfg.sync.spec(),
            StrategySpec::Adaptive { p_init: 6, warmup_iters: 0, ks_frac: 0.2, low: 0.7, high: 1.3 }
        );
    }

    #[test]
    fn nested_table_alias_accepted() {
        let doc =
            TomlDoc::parse("[sync]\nstrategy = \"adpsgd\"\n\n[sync.adpsgd]\np_init = 9").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.p_init, 9);
    }

    #[test]
    fn foreign_nested_table_configures_that_strategy_for_sweeps() {
        // a file may carry tables for strategies not currently chosen
        // (sweep bases): the knobs are stored and spec_of projects them,
        // so a campaign sweeping qsgd sees levels = 15 — not a silently
        // dropped table
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"constant\"\nperiod = 5\n\n[sync.qsgd]\nlevels = 15",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.strategy, Strategy::Constant);
        assert_eq!(cfg.sync.period, 5);
        assert_eq!(cfg.sync.qsgd_levels, 15);
        assert_eq!(
            cfg.sync.spec_of(Strategy::Qsgd),
            StrategySpec::Qsgd { levels: 15, bucket: SyncConfig::default().qsgd_bucket }
        );
    }

    #[test]
    fn chosen_strategy_nested_table_wins_shared_fields() {
        // constant and easgd both consume a period; each table lands in
        // its own slot, so the chosen strategy reads its own value
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"constant\"\n\n[sync.constant]\nperiod = 5\n\n[sync.easgd]\nperiod = 9\nalpha = 0.5",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::Constant { period: 5 });
        assert_eq!(cfg.sync.easgd_alpha, 0.5);
    }

    #[test]
    fn foreign_constant_table_cannot_leak_into_flat_configured_easgd() {
        // the mirrored direction: EASGD chosen via the legacy flat
        // period, with a sweep-base [sync.constant] table present — the
        // table must not rewrite the carrier EASGD falls back to
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"easgd\"\nperiod = 7\neasgd_alpha = 0.25\n\n[sync.constant]\nperiod = 5",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(
            cfg.sync.spec(),
            StrategySpec::Easgd { period: 7, alpha: 0.25 },
            "foreign constant table must not leak into the chosen EASGD run"
        );
        assert_eq!(cfg.sync.spec_of(Strategy::Constant), StrategySpec::Constant { period: 5 });
    }

    #[test]
    fn foreign_table_cannot_leak_into_chosen_strategy_flat_knobs() {
        // chosen constant configured via the flat key only; a sweep-base
        // [sync.easgd] table must not rewrite the chosen run's period
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"constant\"\nperiod = 8\n\n[sync.easgd]\nperiod = 9\nalpha = 0.5",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.period, 8, "foreign easgd table must not leak into CPSGD");
        assert_eq!(cfg.sync.easgd_alpha, 0.5, "easgd's own (unshared) knob is stored");
    }

    #[test]
    fn constant_and_easgd_periods_configure_independently() {
        // the last last-writer-wins corner: both tables in one base must
        // configure their own strategy regardless of order or of which
        // strategy is chosen
        for text in [
            "[sync]\nstrategy = \"adaptive\"\n\n[sync.constant]\nperiod = 5\n\n[sync.easgd]\nperiod = 9\nalpha = 0.5",
            "[sync]\nstrategy = \"adaptive\"\n\n[sync.easgd]\nperiod = 9\nalpha = 0.5\n\n[sync.constant]\nperiod = 5",
        ] {
            let cfg = ExperimentConfig::from_doc(&TomlDoc::parse(text).unwrap()).unwrap();
            assert_eq!(
                cfg.sync.spec_of(Strategy::Constant),
                StrategySpec::Constant { period: 5 },
                "{text}"
            );
            assert_eq!(
                cfg.sync.spec_of(Strategy::Easgd),
                StrategySpec::Easgd { period: 9, alpha: 0.5 },
                "{text}"
            );
        }
    }

    #[test]
    fn easgd_without_table_still_reads_legacy_flat_period() {
        let doc =
            TomlDoc::parse("[sync]\nstrategy = \"easgd\"\nperiod = 7\neasgd_alpha = 0.25").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::Easgd { period: 7, alpha: 0.25 });
    }

    #[test]
    fn later_flat_override_beats_earlier_nested_table() {
        // a file configures [sync.constant]; a later CLI round with the
        // legacy flat key must still take effect (flat resets the slot)
        let doc = TomlDoc::parse("[sync]\nstrategy = \"constant\"\n\n[sync.constant]\nperiod = 5")
            .unwrap();
        let mut cfg = ExperimentConfig::from_doc(&doc).unwrap();
        cfg.apply_overrides(&[("sync.period".to_string(), "9".to_string())]).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::Constant { period: 9 });
    }

    #[test]
    fn to_doc_roundtrips_and_is_canonical() {
        let doc = TomlDoc::parse(
            r#"
name = "canon"
seed = 7
nodes = 4
iters = 120
batch_per_node = 16

[workload]
backend = "native"
model = "mlp"
input_dim = 32

[optim]
lr0 = 0.05
schedule = "warmup"
warmup_iters = 10
warmup_factor = 4.0
boundaries = [60, 90]
factor = 0.1

[sync]
strategy = "adaptive"

[sync.adaptive]
p_init = 3
ks_frac = 0.2

[sync.constant]
period = 5

[sync.easgd]
period = 9
alpha = 0.5

[net]
bandwidth_gbps = 10.0
latency_us = 25.0
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let canon = cfg.to_doc();
        let text = canon.render().unwrap();
        let back = ExperimentConfig::from_doc(&TomlDoc::parse(&text).unwrap()).unwrap();
        // every strategy projection survives the round trip ...
        for kind in spec::ALL_STRATEGIES {
            assert_eq!(back.sync.spec_of(kind), cfg.sync.spec_of(kind), "{kind}");
        }
        assert_eq!(back.nodes, cfg.nodes);
        assert_eq!(back.optim.schedule, cfg.optim.schedule);
        assert_eq!(back.net, cfg.net);
        assert_eq!(back.workload, cfg.workload);
        // ... and the canonical form is idempotent (digest substrate)
        assert_eq!(back.to_doc().render().unwrap(), text);
    }

    #[test]
    fn to_doc_rejects_unrepresentable_strings() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "quo\"te".into();
        assert!(cfg.to_toml_string().is_err());
    }

    #[test]
    fn mismatched_override_is_rejected_with_key_list() {
        let overrides = vec![("sync.qsgd_levels".to_string(), "15".to_string())];
        let err = ExperimentConfig::from_overrides(&overrides).unwrap_err().to_string();
        assert!(err.contains("qsgd knob"), "{err}");
        assert!(err.contains("sync.adaptive.p_init"), "must list valid keys: {err}");

        let overrides = vec![("sync.qsgd.levels".to_string(), "15".to_string())];
        let err = ExperimentConfig::from_overrides(&overrides).unwrap_err().to_string();
        assert!(err.contains("sync.strategy = adaptive"), "{err}");
    }

    #[test]
    fn matching_override_accepted_nested_and_flat() {
        let overrides = vec![
            ("sync.strategy".to_string(), "qsgd".to_string()),
            ("sync.qsgd.levels".to_string(), "15".to_string()),
            ("sync.qsgd_bucket".to_string(), "128".to_string()),
        ];
        let cfg = ExperimentConfig::from_overrides(&overrides).unwrap();
        assert_eq!(cfg.sync.strategy, Strategy::Qsgd);
        assert_eq!(cfg.sync.qsgd_levels, 15);
        assert_eq!(cfg.sync.qsgd_bucket, 128);
    }

    #[test]
    fn unknown_strategy_table_override_rejected() {
        let overrides = vec![("sync.mesh.levels".to_string(), "15".to_string())];
        let err = ExperimentConfig::from_overrides(&overrides).unwrap_err().to_string();
        assert!(err.contains("unknown"), "{err}");
    }

    #[test]
    fn net_preset_resolves_and_rejects_unknown_names() {
        let doc = TomlDoc::parse("[net]\npreset = \"ethernet_10g\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.net, NetConfig::ethernet_10g());
        // explicit keys in the same document refine the preset
        let doc =
            TomlDoc::parse("[net]\npreset = \"ethernet_10g\"\nlatency_us = 40.0").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.net.bandwidth_gbps, 10.0);
        assert_eq!(cfg.net.latency_us, 40.0);
        // unknown names fail at parse time, listing the valid set
        let doc = TomlDoc::parse("[net]\npreset = \"carrier_pigeon\"").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("carrier_pigeon"), "{err}");
        for p in NetConfig::PRESETS {
            assert!(err.contains(p), "error must list preset {p}: {err}");
            NetConfig::preset(p).unwrap();
        }
        // the preset is resolved, not stored: to_doc carries the values
        let canon = cfg.to_doc();
        assert!(canon.get("net.preset").is_none());
        assert_eq!(canon.get("net.bandwidth_gbps").unwrap().as_f64(), Some(10.0));
    }

    #[test]
    fn cluster_table_parses_validates_and_roundtrips() {
        let doc = TomlDoc::parse(
            r#"
nodes = 4
[cluster]
skew = "straggler:4.0"
step_us = 500.0
jitter = 0.2
link_bw_gbps = [100.0, 100.0, 10.0, 100.0]
link_latency_us = [2.0, 2.0, 50.0, 2.0]
[cluster.faults]
pauses = 2
pause_secs = 0.25
spikes = 1
spike_secs = 0.002
spike_len = 6
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.skew, "straggler:4.0");
        assert_eq!(cfg.cluster.step_us, 500.0);
        assert_eq!(cfg.cluster.link_bw_gbps.len(), 4);
        assert_eq!(cfg.cluster.faults.pauses, 2);
        assert_eq!(cfg.cluster.faults.spike_len, 6);
        // canonical form carries every cluster key and is idempotent
        let text = cfg.to_doc().render().unwrap();
        let back = ExperimentConfig::from_doc(&TomlDoc::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cluster, cfg.cluster);
        assert_eq!(back.to_doc().render().unwrap(), text);
        // bad shapes and specs fail at load time
        for bad in [
            "[cluster]\nskew = \"zipf:2\"",
            "nodes = 4\n[cluster]\nfactors = [1.0, 2.0]",
            "nodes = 4\n[cluster]\nlink_bw_gbps = [1.0]",
            "[cluster]\njitter = 1.5",
            "[cluster]\nstep_us = 0.0",
            "[cluster]\nfactors = \"fast\"",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn new_strategy_tables_and_flat_keys_coexist() {
        // nested tables configure the newcomers...
        let doc = TomlDoc::parse(
            "[sync]\nstrategy = \"dasgd\"\n\n[sync.dasgd]\nperiod = 12\ndelay = 3\n\n[sync.adacomm]\ntau0 = 32\n\n[sync.prsgd]\nperiod = 6",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::DaSgd { period: 12, delay: 3 });
        assert_eq!(cfg.sync.spec_of(Strategy::AdaComm), StrategySpec::AdaComm { tau0: 32 });
        assert_eq!(cfg.sync.spec_of(Strategy::PrSgd), StrategySpec::PrSgd { period: 6 });
        // ...the legacy flat period still feeds prsgd/dasgd fallbacks...
        let doc = TomlDoc::parse("[sync]\nstrategy = \"prsgd\"\nperiod = 7").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::PrSgd { period: 7 });
        // ...and flat adacomm_tau0/dasgd_delay load like other legacy keys
        let doc = TomlDoc::parse("[sync]\nstrategy = \"adacomm\"\nadacomm_tau0 = 20").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sync.spec(), StrategySpec::AdaComm { tau0: 20 });
        // dasgd validation runs on the composed spec (delay < period)
        let doc = TomlDoc::parse("[sync]\nstrategy = \"dasgd\"\nperiod = 2\ndasgd_delay = 5")
            .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }
}
