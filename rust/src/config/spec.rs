//! Typed, per-strategy experiment knobs — the tagged replacement for the
//! flat `[sync]` knob-soup.
//!
//! A [`StrategySpec`] carries exactly the knobs its strategy consumes:
//! `Adaptive { p_init, warmup_iters, ks_frac, low, high }` cannot be
//! configured with QSGD quantization levels, and a misplaced knob is a
//! *structural* impossibility rather than a silently-ignored field.
//!
//! Three representations round-trip through this module:
//!
//! * **typed** — the enum itself, what [`crate::experiment::Experiment`]
//!   and [`crate::experiment::Campaign`] consume;
//! * **nested TOML** — `[sync.<strategy>]` tables
//!   (`[sync.adaptive]\np_init = 4`), the canonical file format, also
//!   reachable as dotted CLI overrides (`--sync.adaptive.p_init=4`);
//! * **legacy flat** — the historical `[sync]` keys (`sync.p_init`,
//!   `sync.qsgd_levels`, …), kept loading by the compat layer in
//!   [`super::ExperimentConfig::from_doc`] with a one-time deprecation
//!   note.
//!
//! The flat [`super::SyncConfig`] struct remains the storage carrier (a
//! lot of call sites patch it directly); [`SyncConfig::spec`] projects
//! flat → typed and [`StrategySpec::apply_to`] writes typed → flat, so
//! the two views cannot drift per-strategy.  Strategies that *consume*
//! the same knob name (constant/easgd both take a `period`) store it in
//! per-strategy slots (`SyncConfig::constant_period` /
//! `SyncConfig::easgd_period`, falling back to the shared legacy
//! `period` field), so one base config configures both independently.

use super::toml::TomlValue;
use super::SyncConfig;
use crate::period::Strategy;
use anyhow::{bail, Result};

/// Every strategy kind, in canonical order (used to enumerate key sets).
pub const ALL_STRATEGIES: [Strategy; 11] = [
    Strategy::Full,
    Strategy::Constant,
    Strategy::Adaptive,
    Strategy::Decreasing,
    Strategy::Qsgd,
    Strategy::Piecewise,
    Strategy::Easgd,
    Strategy::TopK,
    Strategy::AdaComm,
    Strategy::PrSgd,
    Strategy::DaSgd,
];

/// Accepted `[sync.<name>]` table names per strategy (first = canonical;
/// the rest are the same aliases `Strategy::from_str` accepts).
pub fn table_names(kind: Strategy) -> &'static [&'static str] {
    match kind {
        Strategy::Full => &["full", "fullsgd"],
        Strategy::Constant => &["constant", "cpsgd"],
        Strategy::Adaptive => &["adaptive", "adpsgd"],
        Strategy::Decreasing => &["decreasing"],
        Strategy::Qsgd => &["qsgd"],
        Strategy::Piecewise => &["piecewise"],
        Strategy::Easgd => &["easgd"],
        Strategy::TopK => &["topk"],
        Strategy::AdaComm => &["adacomm"],
        Strategy::PrSgd => &["prsgd", "pr_sgd"],
        Strategy::DaSgd => &["dasgd"],
    }
}

/// Canonical table/spec name for a strategy kind.
pub fn canonical_name(kind: Strategy) -> &'static str {
    table_names(kind)[0]
}

/// Strategy kind for a `[sync.<table>]` name, if it is one.
pub fn kind_for_table(table: &str) -> Option<Strategy> {
    ALL_STRATEGIES.into_iter().find(|k| table_names(*k).contains(&table))
}

/// Nested (`sync.<strategy>.<key>`) knob names per strategy.
pub fn nested_keys(kind: Strategy) -> &'static [&'static str] {
    match kind {
        Strategy::Full => &[],
        Strategy::Constant => &["period"],
        Strategy::Adaptive => &["p_init", "warmup_iters", "ks_frac", "low", "high"],
        Strategy::Decreasing => &["first", "second"],
        Strategy::Qsgd => &["levels", "bucket"],
        Strategy::Piecewise => &["schedule"],
        Strategy::Easgd => &["period", "alpha"],
        Strategy::TopK => &["frac"],
        Strategy::AdaComm => &["tau0"],
        Strategy::PrSgd => &["period"],
        Strategy::DaSgd => &["period", "delay"],
    }
}

/// Legacy flat (`sync.<field>`) knob names a strategy consumes.
pub fn legacy_fields(kind: Strategy) -> &'static [&'static str] {
    match kind {
        Strategy::Full => &[],
        Strategy::Constant => &["period"],
        Strategy::Adaptive => &["p_init", "warmup_iters", "ks_frac", "low", "high"],
        Strategy::Decreasing => &["dec_first", "dec_second"],
        Strategy::Qsgd => &["qsgd_levels", "qsgd_bucket"],
        Strategy::Piecewise => &["piecewise"],
        Strategy::Easgd => &["period", "easgd_alpha"],
        Strategy::TopK => &["topk_frac"],
        Strategy::AdaComm => &["adacomm_tau0"],
        Strategy::PrSgd => &["period"],
        Strategy::DaSgd => &["period", "dasgd_delay"],
    }
}

/// Human-readable list of the sync keys valid under `kind`, for error
/// messages ("valid sync keys for adaptive: …").
pub fn describe_keys(kind: Strategy) -> String {
    let name = canonical_name(kind);
    let nested: Vec<String> =
        nested_keys(kind).iter().map(|k| format!("sync.{name}.{k}")).collect();
    let legacy: Vec<String> =
        legacy_fields(kind).iter().map(|k| format!("sync.{k}")).collect();
    let mut parts = vec!["sync.strategy".to_string(), "sync.collective".to_string()];
    parts.extend(nested);
    let mut s = parts.join(", ");
    if !legacy.is_empty() {
        s.push_str(&format!(" (legacy flat: {})", legacy.join(", ")));
    }
    s
}

/// A synchronization strategy plus exactly the knobs it consumes.
#[derive(Debug, Clone, PartialEq)]
pub enum StrategySpec {
    /// FULLSGD: gradient allreduce every iteration. No knobs.
    Full,
    /// CPSGD (Algorithm 1): parameter averaging every `period` iters.
    Constant { period: usize },
    /// ADPSGD (Algorithm 2): warmup epoch at p=1, C₂ sampled for
    /// `ks_frac·K` iterations, then p adapted inside `[low, high]`.
    Adaptive { p_init: usize, warmup_iters: usize, ks_frac: f64, low: f64, high: f64 },
    /// §V-B strawman: period `first` for the first half of training,
    /// then `second`.
    Decreasing { first: usize, second: usize },
    /// QSGD: stochastic quantization to `levels` per `bucket`-sized
    /// bucket, exchanged every iteration.
    Qsgd { levels: u32, bucket: usize },
    /// Explicit piecewise period schedule ("0:4,2000:8").
    Piecewise { schedule: String },
    /// EASGD: elastic averaging every `period` iters, each node moving
    /// `alpha` of the way toward the mean.
    Easgd { period: usize, alpha: f64 },
    /// Top-k sparsification with error feedback, keeping `frac` of the
    /// gradient components.
    TopK { frac: f64 },
    /// AdaComm (arXiv 1810.08313): error-runtime-optimal decaying
    /// schedule.  Starts at period `tau0` and re-derives the period at
    /// each sync from the agreed training loss:
    /// `τ = ceil(τ0 · sqrt(F(w)/F(w0)))`, clamped to [1, τ0] — sync
    /// rarely early, often late (the mirror image of ADPSGD's warmup,
    /// optimal for wall-clock error under variable system speed).
    AdaComm { tau0: usize },
    /// Parallel Restarted SGD (arXiv 1807.06629): constant-period
    /// parameter averaging with *restart* semantics — node-local
    /// momentum is reset at every averaging point, so each period is an
    /// independent local-SGD leg from the averaged model.
    PrSgd { period: usize },
    /// DaSGD (arXiv 2006.00441): delayed averaging.  The allreduce
    /// launched at a sync point overlaps with `delay` further local
    /// steps; its result is applied as `w ← mean + (w − w_snap)`,
    /// hiding communication (and stragglers) behind compute.
    /// Requires `delay < period` so deliveries never overlap.
    DaSgd { period: usize, delay: usize },
}

impl StrategySpec {
    pub fn kind(&self) -> Strategy {
        match self {
            StrategySpec::Full => Strategy::Full,
            StrategySpec::Constant { .. } => Strategy::Constant,
            StrategySpec::Adaptive { .. } => Strategy::Adaptive,
            StrategySpec::Decreasing { .. } => Strategy::Decreasing,
            StrategySpec::Qsgd { .. } => Strategy::Qsgd,
            StrategySpec::Piecewise { .. } => Strategy::Piecewise,
            StrategySpec::Easgd { .. } => Strategy::Easgd,
            StrategySpec::TopK { .. } => Strategy::TopK,
            StrategySpec::AdaComm { .. } => Strategy::AdaComm,
            StrategySpec::PrSgd { .. } => Strategy::PrSgd,
            StrategySpec::DaSgd { .. } => Strategy::DaSgd,
        }
    }

    /// Canonical name ("adaptive", "qsgd", …): the `[sync.<name>]` table
    /// and the period-controller registry key.
    pub fn name(&self) -> &'static str {
        canonical_name(self.kind())
    }

    /// The spec a strategy gets when nothing is configured (the knob
    /// defaults of [`SyncConfig::default`]).
    pub fn default_of(kind: Strategy) -> StrategySpec {
        SyncConfig::default().spec_of(kind)
    }

    /// Whether this strategy exchanges gradients every iteration (no
    /// period controller) rather than averaging parameters periodically.
    pub fn is_gradient_mode(&self) -> bool {
        matches!(
            self,
            StrategySpec::Full | StrategySpec::Qsgd { .. } | StrategySpec::TopK { .. }
        )
    }

    /// Validate this spec's own knobs (the per-strategy half of
    /// [`super::ExperimentConfig::validate`]).
    pub fn validate(&self) -> Result<()> {
        match self {
            StrategySpec::Full => {}
            StrategySpec::Constant { period } => {
                if *period == 0 {
                    bail!("constant: period must be >= 1");
                }
            }
            StrategySpec::Adaptive { p_init, ks_frac, low, high, .. } => {
                if *p_init == 0 {
                    bail!("adaptive: p_init must be >= 1");
                }
                if !(*low < 1.0 && *high > 1.0) {
                    bail!("adaptive: thresholds must straddle 1.0 (low < 1 < high)");
                }
                if !(0.0..=1.0).contains(ks_frac) {
                    bail!("adaptive: ks_frac must be in [0, 1]");
                }
            }
            StrategySpec::Decreasing { first, second } => {
                if *first == 0 || *second == 0 {
                    bail!("decreasing: periods must be >= 1");
                }
            }
            StrategySpec::Qsgd { levels, bucket } => {
                if *levels == 0 || *bucket == 0 {
                    bail!("qsgd: levels and bucket must be >= 1");
                }
            }
            StrategySpec::Piecewise { schedule } => {
                crate::period::Piecewise::parse(schedule)
                    .map_err(|e| anyhow::anyhow!("piecewise schedule: {e}"))?;
            }
            StrategySpec::Easgd { period, alpha } => {
                if *period == 0 {
                    bail!("easgd: period must be >= 1");
                }
                if !(0.0 < *alpha && *alpha <= 1.0) {
                    bail!("easgd: alpha must be in (0, 1]");
                }
            }
            StrategySpec::TopK { frac } => {
                if !(0.0 < *frac && *frac <= 1.0) {
                    bail!("topk: frac must be in (0, 1]");
                }
            }
            StrategySpec::AdaComm { tau0 } => {
                if *tau0 == 0 {
                    bail!("adacomm: tau0 must be >= 1");
                }
            }
            StrategySpec::PrSgd { period } => {
                if *period == 0 {
                    bail!("prsgd: period must be >= 1");
                }
            }
            StrategySpec::DaSgd { period, delay } => {
                if *period == 0 {
                    bail!("dasgd: period must be >= 1");
                }
                if *delay == 0 || *delay >= *period {
                    bail!(
                        "dasgd: delay must satisfy 1 <= delay < period \
                         (got delay = {delay}, period = {period})"
                    );
                }
            }
        }
        Ok(())
    }

    /// Write this spec into the flat carrier: sets the strategy tag and
    /// the fields this strategy consumes, leaving unrelated knobs alone.
    pub fn apply_to(&self, sync: &mut SyncConfig) {
        sync.strategy = self.kind();
        self.apply_knobs_to(sync);
    }

    /// Write only this spec's knobs into the flat carrier *without*
    /// switching the strategy tag — how `[sync.<strategy>]` tables for
    /// strategies other than the chosen one are stored, so campaign
    /// sweeps (`SyncConfig::spec_of`) pick them up.
    pub fn apply_knobs_to(&self, sync: &mut SyncConfig) {
        match self {
            StrategySpec::Full => {}
            StrategySpec::Constant { period } => {
                // CPSGD and EASGD both consume a period; each writes
                // ONLY its own storage slot (spec_of reads the slot,
                // with the shared legacy `period` field as fallback) —
                // writing the shared carrier here would leak a
                // sweep-base [sync.constant] table into a
                // flat-configured EASGD run, and vice versa
                sync.constant_period = Some(*period);
            }
            StrategySpec::Adaptive { p_init, warmup_iters, ks_frac, low, high } => {
                sync.p_init = *p_init;
                sync.warmup_iters = *warmup_iters;
                sync.ks_frac = *ks_frac;
                sync.low = *low;
                sync.high = *high;
            }
            StrategySpec::Decreasing { first, second } => {
                sync.dec_first = *first;
                sync.dec_second = *second;
            }
            StrategySpec::Qsgd { levels, bucket } => {
                sync.qsgd_levels = *levels;
                sync.qsgd_bucket = *bucket;
            }
            StrategySpec::Piecewise { schedule } => sync.piecewise = schedule.clone(),
            StrategySpec::Easgd { period, alpha } => {
                sync.easgd_period = Some(*period);
                sync.easgd_alpha = *alpha;
            }
            StrategySpec::TopK { frac } => sync.topk_frac = *frac,
            StrategySpec::AdaComm { tau0 } => sync.adacomm_tau0 = *tau0,
            StrategySpec::PrSgd { period } => {
                // same slot discipline as Constant/Easgd: never the
                // shared legacy `period` carrier
                sync.prsgd_period = Some(*period);
            }
            StrategySpec::DaSgd { period, delay } => {
                sync.dasgd_period = Some(*period);
                sync.dasgd_delay = *delay;
            }
        }
    }

    /// Set one nested knob from a TOML value (`sync.<name>.<key>`).
    pub fn set_nested(&mut self, key: &str, val: &TomlValue) -> Result<()> {
        let name = self.name();
        let vu = |v: &TomlValue| -> Result<usize> {
            v.as_i64()
                .filter(|i| *i >= 0)
                .map(|i| i as usize)
                .ok_or_else(|| anyhow::anyhow!("sync.{name}.{key}: expected a non-negative integer"))
        };
        let vf = |v: &TomlValue| -> Result<f64> {
            v.as_f64().ok_or_else(|| anyhow::anyhow!("sync.{name}.{key}: expected a number"))
        };
        let vs = |v: &TomlValue| -> Result<String> {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("sync.{name}.{key}: expected a string"))
        };
        match (self, key) {
            (StrategySpec::Constant { period }, "period") => *period = vu(val)?,
            (StrategySpec::Adaptive { p_init, .. }, "p_init") => *p_init = vu(val)?,
            (StrategySpec::Adaptive { warmup_iters, .. }, "warmup_iters") => {
                *warmup_iters = vu(val)?
            }
            (StrategySpec::Adaptive { ks_frac, .. }, "ks_frac") => *ks_frac = vf(val)?,
            (StrategySpec::Adaptive { low, .. }, "low") => *low = vf(val)?,
            (StrategySpec::Adaptive { high, .. }, "high") => *high = vf(val)?,
            (StrategySpec::Decreasing { first, .. }, "first") => *first = vu(val)?,
            (StrategySpec::Decreasing { second, .. }, "second") => *second = vu(val)?,
            (StrategySpec::Qsgd { levels, .. }, "levels") => {
                *levels = u32::try_from(vu(val)?)
                    .map_err(|_| anyhow::anyhow!("sync.qsgd.levels: value out of range for u32"))?
            }
            (StrategySpec::Qsgd { bucket, .. }, "bucket") => *bucket = vu(val)?,
            (StrategySpec::Piecewise { schedule }, "schedule") => *schedule = vs(val)?,
            (StrategySpec::Easgd { period, .. }, "period") => *period = vu(val)?,
            (StrategySpec::Easgd { alpha, .. }, "alpha") => *alpha = vf(val)?,
            (StrategySpec::TopK { frac }, "frac") => *frac = vf(val)?,
            (StrategySpec::AdaComm { tau0 }, "tau0") => *tau0 = vu(val)?,
            (StrategySpec::PrSgd { period }, "period") => *period = vu(val)?,
            (StrategySpec::DaSgd { period, .. }, "period") => *period = vu(val)?,
            (StrategySpec::DaSgd { delay, .. }, "delay") => *delay = vu(val)?,
            (spec, _) => bail!(
                "sync.{}.{key} is not a knob of strategy {} (valid: {})",
                spec.name(),
                spec.name(),
                nested_keys(spec.kind()).join(", ")
            ),
        }
        Ok(())
    }

    /// The spec's knobs as `(nested_key, value)` pairs, in
    /// [`nested_keys`] order — the substrate for
    /// [`super::ExperimentConfig::to_doc`]'s canonical `[sync.<name>]`
    /// tables.
    pub fn nested_entries(&self) -> Vec<(&'static str, TomlValue)> {
        match self {
            StrategySpec::Full => vec![],
            StrategySpec::Constant { period } => {
                vec![("period", TomlValue::Int(*period as i64))]
            }
            StrategySpec::Adaptive { p_init, warmup_iters, ks_frac, low, high } => vec![
                ("p_init", TomlValue::Int(*p_init as i64)),
                ("warmup_iters", TomlValue::Int(*warmup_iters as i64)),
                ("ks_frac", TomlValue::Float(*ks_frac)),
                ("low", TomlValue::Float(*low)),
                ("high", TomlValue::Float(*high)),
            ],
            StrategySpec::Decreasing { first, second } => vec![
                ("first", TomlValue::Int(*first as i64)),
                ("second", TomlValue::Int(*second as i64)),
            ],
            StrategySpec::Qsgd { levels, bucket } => vec![
                ("levels", TomlValue::Int(*levels as i64)),
                ("bucket", TomlValue::Int(*bucket as i64)),
            ],
            StrategySpec::Piecewise { schedule } => {
                vec![("schedule", TomlValue::Str(schedule.clone()))]
            }
            StrategySpec::Easgd { period, alpha } => vec![
                ("period", TomlValue::Int(*period as i64)),
                ("alpha", TomlValue::Float(*alpha)),
            ],
            StrategySpec::TopK { frac } => vec![("frac", TomlValue::Float(*frac))],
            StrategySpec::AdaComm { tau0 } => {
                vec![("tau0", TomlValue::Int(*tau0 as i64))]
            }
            StrategySpec::PrSgd { period } => {
                vec![("period", TomlValue::Int(*period as i64))]
            }
            StrategySpec::DaSgd { period, delay } => vec![
                ("period", TomlValue::Int(*period as i64)),
                ("delay", TomlValue::Int(*delay as i64)),
            ],
        }
    }

    /// Render the canonical nested-TOML form:
    ///
    /// ```text
    /// [sync]
    /// strategy = "adaptive"
    ///
    /// [sync.adaptive]
    /// p_init = 4
    /// ...
    /// ```
    pub fn to_toml(&self) -> String {
        let name = self.name();
        let mut out = format!("[sync]\nstrategy = \"{name}\"\n");
        let body = match self {
            StrategySpec::Full => String::new(),
            StrategySpec::Constant { period } => format!("period = {period}\n"),
            StrategySpec::Adaptive { p_init, warmup_iters, ks_frac, low, high } => format!(
                "p_init = {p_init}\nwarmup_iters = {warmup_iters}\nks_frac = {ks_frac}\nlow = {low}\nhigh = {high}\n"
            ),
            StrategySpec::Decreasing { first, second } => {
                format!("first = {first}\nsecond = {second}\n")
            }
            StrategySpec::Qsgd { levels, bucket } => {
                format!("levels = {levels}\nbucket = {bucket}\n")
            }
            StrategySpec::Piecewise { schedule } => format!("schedule = \"{schedule}\"\n"),
            StrategySpec::Easgd { period, alpha } => {
                format!("period = {period}\nalpha = {alpha}\n")
            }
            StrategySpec::TopK { frac } => format!("frac = {frac}\n"),
            StrategySpec::AdaComm { tau0 } => format!("tau0 = {tau0}\n"),
            StrategySpec::PrSgd { period } => format!("period = {period}\n"),
            StrategySpec::DaSgd { period, delay } => {
                format!("period = {period}\ndelay = {delay}\n")
            }
        };
        if !body.is_empty() {
            out.push_str(&format!("\n[sync.{name}]\n{body}"));
        }
        out
    }
}

impl std::fmt::Display for StrategySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl SyncConfig {
    /// The typed spec of the *configured* strategy.
    pub fn spec(&self) -> StrategySpec {
        self.spec_of(self.strategy)
    }

    /// Project the flat knobs into the typed spec of an arbitrary
    /// strategy kind (what that strategy *would* run with under this
    /// config) — how campaigns derive per-strategy specs from one base.
    pub fn spec_of(&self, kind: Strategy) -> StrategySpec {
        match kind {
            Strategy::Full => StrategySpec::Full,
            Strategy::Constant => StrategySpec::Constant {
                period: self.constant_period.unwrap_or(self.period),
            },
            Strategy::Adaptive => StrategySpec::Adaptive {
                p_init: self.p_init,
                warmup_iters: self.warmup_iters,
                ks_frac: self.ks_frac,
                low: self.low,
                high: self.high,
            },
            Strategy::Decreasing => {
                StrategySpec::Decreasing { first: self.dec_first, second: self.dec_second }
            }
            Strategy::Qsgd => {
                StrategySpec::Qsgd { levels: self.qsgd_levels, bucket: self.qsgd_bucket }
            }
            Strategy::Piecewise => StrategySpec::Piecewise { schedule: self.piecewise.clone() },
            Strategy::Easgd => StrategySpec::Easgd {
                period: self.easgd_period.unwrap_or(self.period),
                alpha: self.easgd_alpha,
            },
            Strategy::TopK => StrategySpec::TopK { frac: self.topk_frac },
            Strategy::AdaComm => StrategySpec::AdaComm { tau0: self.adacomm_tau0 },
            Strategy::PrSgd => StrategySpec::PrSgd {
                period: self.prsgd_period.unwrap_or(self.period),
            },
            Strategy::DaSgd => StrategySpec::DaSgd {
                period: self.dasgd_period.unwrap_or(self.period),
                delay: self.dasgd_delay,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_projection_roundtrips_through_flat() {
        let specs = [
            StrategySpec::Full,
            StrategySpec::Constant { period: 7 },
            StrategySpec::Adaptive {
                p_init: 3,
                warmup_iters: 11,
                ks_frac: 0.2,
                low: 0.6,
                high: 1.4,
            },
            StrategySpec::Decreasing { first: 19, second: 3 },
            StrategySpec::Qsgd { levels: 15, bucket: 256 },
            StrategySpec::Piecewise { schedule: "0:2,100:9".into() },
            StrategySpec::Easgd { period: 6, alpha: 0.25 },
            StrategySpec::TopK { frac: 0.125 },
            StrategySpec::AdaComm { tau0: 24 },
            StrategySpec::PrSgd { period: 9 },
            StrategySpec::DaSgd { period: 10, delay: 3 },
        ];
        for spec in specs {
            let mut sync = SyncConfig::default();
            spec.apply_to(&mut sync);
            assert_eq!(sync.strategy, spec.kind());
            assert_eq!(sync.spec(), spec, "{spec:?} must survive flat projection");
        }
    }

    #[test]
    fn every_strategy_has_consistent_key_tables() {
        for kind in ALL_STRATEGIES {
            assert_eq!(nested_keys(kind).len(), legacy_fields(kind).len(), "{kind}");
            assert_eq!(kind_for_table(canonical_name(kind)), Some(kind));
            for alias in table_names(kind) {
                assert_eq!(alias.parse::<Strategy>().unwrap(), kind, "{alias}");
            }
        }
        assert_eq!(kind_for_table("mesh"), None);
    }

    #[test]
    fn validate_catches_per_strategy_nonsense() {
        assert!(StrategySpec::Constant { period: 0 }.validate().is_err());
        assert!(StrategySpec::Adaptive {
            p_init: 4,
            warmup_iters: 0,
            ks_frac: 0.25,
            low: 1.5,
            high: 2.0
        }
        .validate()
        .is_err());
        assert!(StrategySpec::Qsgd { levels: 0, bucket: 512 }.validate().is_err());
        assert!(StrategySpec::Piecewise { schedule: "5:4".into() }.validate().is_err());
        assert!(StrategySpec::Easgd { period: 8, alpha: 0.0 }.validate().is_err());
        assert!(StrategySpec::TopK { frac: 1.5 }.validate().is_err());
        assert!(StrategySpec::AdaComm { tau0: 0 }.validate().is_err());
        assert!(StrategySpec::PrSgd { period: 0 }.validate().is_err());
        assert!(StrategySpec::DaSgd { period: 4, delay: 0 }.validate().is_err());
        assert!(StrategySpec::DaSgd { period: 4, delay: 4 }.validate().is_err());
        assert!(StrategySpec::DaSgd { period: 4, delay: 3 }.validate().is_ok());
        assert!(StrategySpec::default_of(Strategy::Adaptive).validate().is_ok());
        for kind in [Strategy::AdaComm, Strategy::PrSgd, Strategy::DaSgd] {
            assert!(StrategySpec::default_of(kind).validate().is_ok(), "{kind}");
            assert!(!StrategySpec::default_of(kind).is_gradient_mode(), "{kind}");
        }
    }

    #[test]
    fn set_nested_rejects_foreign_keys() {
        let mut spec = StrategySpec::default_of(Strategy::Adaptive);
        let err = spec.set_nested("levels", &TomlValue::Int(8)).unwrap_err().to_string();
        assert!(err.contains("not a knob"), "{err}");
        spec.set_nested("p_init", &TomlValue::Int(9)).unwrap();
        match spec {
            StrategySpec::Adaptive { p_init, .. } => assert_eq!(p_init, 9),
            other => panic!("wrong variant {other:?}"),
        }
    }
}
