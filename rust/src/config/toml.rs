//! TOML-subset parser (the `toml`/`serde` crates are not in the offline
//! registry).  Supports what experiment configs need:
//!
//! * `[table]` and `[dotted.table]` headers
//! * `key = value` with string / integer / float / bool / array values
//! * dotted keys (`sync.period = 8`), comments, blank lines
//!
//! Unsupported (rejected, never silently misparsed): inline tables,
//! multi-line strings, array-of-tables, datetimes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// Render in the subset grammar [`TomlDoc::parse`] accepts.  The
    /// subset has no string escapes, so strings containing a double
    /// quote, `#`, or a line break cannot be represented and error.
    pub fn render(&self) -> Result<String, String> {
        match self {
            TomlValue::Str(s) => {
                if s.contains('"') || s.contains('#') || s.contains('\n') || s.contains('\r') {
                    Err(format!("string {s:?} is not representable (no escape support)"))
                } else {
                    Ok(format!("\"{s}\""))
                }
            }
            TomlValue::Int(i) => Ok(i.to_string()),
            TomlValue::Float(f) => {
                if !f.is_finite() {
                    return Err(format!("non-finite float {f}"));
                }
                let s = format!("{f}");
                // keep the float/integer distinction through a re-parse
                Ok(if s.contains('.') || s.contains('e') || s.contains('E') {
                    s
                } else {
                    format!("{s}.0")
                })
            }
            TomlValue::Bool(b) => Ok(b.to_string()),
            TomlValue::Arr(items) => {
                let parts: Result<Vec<String>, String> =
                    items.iter().map(TomlValue::render).collect();
                Ok(format!("[{}]", parts?.join(", ")))
            }
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Flat document: fully-qualified dotted key -> value.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err("array-of-tables is not supported"));
                }
                let name = rest.strip_suffix(']').ok_or_else(|| err("missing ']'"))?.trim();
                if name.is_empty() || !valid_key_path(name) {
                    return Err(err("invalid table name"));
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() || !valid_key_path(key) {
                return Err(err("invalid key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let full = if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
            if doc.entries.insert(full.clone(), val).is_some() {
                return Err(err(&format!("duplicate key {full:?}")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// Render as sorted dotted `key = value` lines.  The output
    /// round-trips through [`TomlDoc::parse`] to an equal document, and
    /// is byte-stable for equal documents (entries are a sorted map) —
    /// the canonical text form behind the dispatch layer's config digest
    /// and worker wire format.
    pub fn render(&self) -> Result<String, String> {
        let mut out = String::new();
        for (k, v) in &self.entries {
            let val = v.render().map_err(|e| format!("{k}: {e}"))?;
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&val);
            out.push('\n');
        }
        Ok(out)
    }

    /// All keys under a dotted prefix (for unknown-key validation).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.entries.keys().filter_map(move |k| {
            if prefix.is_empty() {
                Some(k.as_str())
            } else {
                k.strip_prefix(prefix).and_then(|r| r.strip_prefix('.')).map(|_| k.as_str())
            }
        })
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn valid_key_path(k: &str) -> bool {
    k.split('.').all(|part| {
        !part.is_empty()
            && part.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    })
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut vals = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for item in split_top_level(inner) {
                vals.push(parse_value(item.trim())?);
            }
        }
        return Ok(TomlValue::Arr(vals));
    }
    // number: underscores allowed
    let clean: String = s.chars().filter(|&c| c != '_').collect();
    if clean.contains('.') || clean.contains('e') || clean.contains('E') {
        clean.parse::<f64>().map(TomlValue::Float).map_err(|_| format!("bad float {s:?}"))
    } else {
        clean.parse::<i64>().map(TomlValue::Int).map_err(|_| format!("bad value {s:?}"))
    }
}

/// Split an array body on top-level commas (no nested arrays-of-arrays
/// needed for configs, but handle them anyway).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = TomlDoc::parse(
            r#"
# experiment
name = "fig1"
seed = 42
lr = 0.1
flag = true

[sync]
strategy = "adaptive"
p_init = 4

[net.link]
bandwidth_gbps = 100.0
"#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("fig1"));
        assert_eq!(doc.get("seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("lr").unwrap().as_f64(), Some(0.1));
        assert_eq!(doc.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("sync.strategy").unwrap().as_str(), Some("adaptive"));
        assert_eq!(doc.get("sync.p_init").unwrap().as_i64(), Some(4));
        assert_eq!(doc.get("net.link.bandwidth_gbps").unwrap().as_f64(), Some(100.0));
    }

    #[test]
    fn arrays_and_underscored_numbers() {
        let doc = TomlDoc::parse("bounds = [2_000, 3_000]\nfs = [0.1, 0.2]").unwrap();
        let a = doc.get("bounds").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(2000));
        assert_eq!(a[1].as_i64(), Some(3000));
        assert_eq!(doc.get("fs").unwrap().as_arr().unwrap()[1].as_f64(), Some(0.2));
    }

    #[test]
    fn dotted_keys() {
        let doc = TomlDoc::parse("sync.period = 8").unwrap();
        assert_eq!(doc.get("sync.period").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn comments_inside_strings() {
        let doc = TomlDoc::parse("s = \"a#b\" # real comment").unwrap();
        assert_eq!(doc.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("k =").is_err());
        assert!(TomlDoc::parse("k = nope").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("[[t]]").is_err());
    }

    #[test]
    fn duplicate_across_tables_rejected() {
        assert!(TomlDoc::parse("[a]\nb = 1\n[a]\nb = 2").is_err());
    }
}
