//! The per-node compute engine abstraction.
//!
//! A worker needs five operations; both backends provide them:
//! * [`NativeEngine`] — pure-rust workloads (fast statistics runs)
//! * [`HloAdapter`] — AOT HLO via PJRT (the product path; constructed
//!   inside the worker thread because `xla` handles are not `Send`)

use crate::config::{Backend, ExperimentConfig};
use crate::data::Batch;
use crate::runtime::{EngineFns, HloEngine, Manifest};
use crate::util::rng::Rng;
use crate::workload::Workload;
use anyhow::Result;

pub trait Engine {
    fn n_params(&self) -> usize;
    fn init(&mut self, seed: u64) -> Result<Vec<f32>>;
    /// Local fused step: updates (w, m) in place, returns batch loss.
    fn step(&mut self, w: &mut [f32], m: &mut [f32], batch: &Batch, lr: f32) -> Result<f32>;
    /// Gradient only (for FULLSGD/QSGD exchange), into `g`; returns loss.
    fn grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> Result<f32>;
    /// Apply a (possibly averaged) gradient with the fused momentum rule.
    fn apply(&mut self, w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) -> Result<()>;
    fn eval(&mut self, w: &[f32], batch: &Batch) -> Result<(f32, f32)>;
}

/// Pure-rust backend.
pub struct NativeEngine {
    wl: Box<dyn Workload>,
    momentum: f32,
    scratch_g: Vec<f32>,
}

impl NativeEngine {
    pub fn new(wl: Box<dyn Workload>, momentum: f32) -> Self {
        let n = wl.n_params();
        NativeEngine { wl, momentum, scratch_g: vec![0.0; n] }
    }
}

impl Engine for NativeEngine {
    fn n_params(&self) -> usize {
        self.wl.n_params()
    }

    fn init(&mut self, seed: u64) -> Result<Vec<f32>> {
        let mut w = vec![0.0; self.wl.n_params()];
        self.wl.init(&mut Rng::new(seed, 0x1217), &mut w);
        Ok(w)
    }

    fn step(&mut self, w: &mut [f32], m: &mut [f32], batch: &Batch, lr: f32) -> Result<f32> {
        let loss = self.wl.loss_grad(w, batch, &mut self.scratch_g);
        crate::tensor::momentum_update(w, m, &self.scratch_g, lr, self.momentum);
        Ok(loss)
    }

    fn grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> Result<f32> {
        Ok(self.wl.loss_grad(w, batch, g))
    }

    fn apply(&mut self, w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        crate::tensor::momentum_update(w, m, g, lr, self.momentum);
        Ok(())
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        Ok(self.wl.eval(w, batch))
    }
}

/// HLO/PJRT backend (thin adapter over [`HloEngine`]).
pub struct HloAdapter {
    engine: HloEngine,
}

impl Engine for HloAdapter {
    fn n_params(&self) -> usize {
        self.engine.n_params()
    }

    fn init(&mut self, seed: u64) -> Result<Vec<f32>> {
        self.engine.init(seed as i32)
    }

    fn step(&mut self, w: &mut [f32], m: &mut [f32], batch: &Batch, lr: f32) -> Result<f32> {
        self.engine.step(w, m, batch, lr)
    }

    fn grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> Result<f32> {
        self.engine.grad(w, batch, g)
    }

    fn apply(&mut self, w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        self.engine.apply(w, m, g, lr)
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        self.engine.eval(w, batch)
    }
}

/// Failure-injection wrapper: behaves as `inner` until `fail_at` steps
/// have executed on the designated rank, then errors — used by the chaos
/// tests to prove a mid-run node failure aborts the whole cluster
/// cleanly (communicator poisoning) instead of deadlocking the barrier.
///
/// Enabled via the native workload name `failing:<rank>:<step>` (the
/// inner model is the standard MLP).
pub struct FailingEngine {
    inner: NativeEngine,
    rank: usize,
    fail_rank: usize,
    fail_at: usize,
    steps: usize,
}

impl Engine for FailingEngine {
    fn n_params(&self) -> usize {
        self.inner.n_params()
    }

    fn init(&mut self, seed: u64) -> Result<Vec<f32>> {
        self.inner.init(seed)
    }

    fn step(&mut self, w: &mut [f32], m: &mut [f32], batch: &Batch, lr: f32) -> Result<f32> {
        self.steps += 1;
        if self.rank == self.fail_rank && self.steps >= self.fail_at {
            anyhow::bail!(
                "injected failure: node {} died at step {} (chaos test)",
                self.rank,
                self.steps
            );
        }
        self.inner.step(w, m, batch, lr)
    }

    fn grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> Result<f32> {
        self.steps += 1;
        if self.rank == self.fail_rank && self.steps >= self.fail_at {
            anyhow::bail!(
                "injected failure: node {} died at step {} (chaos test)",
                self.rank,
                self.steps
            );
        }
        self.inner.grad(w, batch, g)
    }

    fn apply(&mut self, w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        self.inner.apply(w, m, g, lr)
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        self.inner.eval(w, batch)
    }
}

/// Parse "failing:<rank>:<step>" (both default to 1:10).
fn parse_failing(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("failing")?;
    if rest.is_empty() {
        return Some((1, 10));
    }
    let mut it = rest.strip_prefix(':')?.split(':');
    let rank = it.next()?.parse().ok()?;
    let step = it.next().and_then(|s| s.parse().ok()).unwrap_or(10);
    Some((rank, step))
}

/// Builds one engine per worker, *inside* the worker thread.
pub type EngineFactory = Box<dyn Fn(usize) -> Result<Box<dyn Engine>> + Send + Sync>;

/// Construct the engine factory for a config.  For the HLO backend the
/// manifest is loaded once up front (cheap, shared); each worker then
/// compiles its own executables on its own PJRT client.
pub fn factory(cfg: &ExperimentConfig) -> Result<EngineFactory> {
    let momentum = cfg.optim.momentum;
    let needs_grad = cfg.sync.spec().is_gradient_mode();
    match &cfg.workload.backend {
        Backend::Native(name) if name.starts_with("failing") => {
            let (fail_rank, fail_at) = parse_failing(name)
                .ok_or_else(|| anyhow::anyhow!("bad failure spec {name:?}"))?;
            let wcfg = cfg.workload.clone();
            crate::workload::build("mlp", &wcfg)?; // validate now
            Ok(Box::new(move |rank| {
                let wl = crate::workload::build("mlp", &wcfg)?;
                Ok(Box::new(FailingEngine {
                    inner: NativeEngine::new(wl, momentum),
                    rank,
                    fail_rank,
                    fail_at,
                    steps: 0,
                }) as Box<dyn Engine>)
            }))
        }
        Backend::Native(name) => {
            let wl = crate::workload::build(name, &cfg.workload)?; // validate now
            drop(wl);
            let name = name.clone();
            let wcfg = cfg.workload.clone();
            Ok(Box::new(move |_node| {
                let wl = crate::workload::build(&name, &wcfg)?;
                Ok(Box::new(NativeEngine::new(wl, momentum)) as Box<dyn Engine>)
            }))
        }
        Backend::Hlo(model) => {
            // shared across workers *and* across campaign runs
            let manifest = Manifest::load_cached(&cfg.artifacts_dir)?;
            manifest.get(model)?; // validate now
            let model = model.clone();
            let fns = EngineFns {
                step: true,
                grad_apply: needs_grad,
                eval: true,
                sq_dev: false,
                qsgd: false,
            };
            Ok(Box::new(move |_node| {
                let engine = HloEngine::load(&manifest, &model, fns)?;
                Ok(Box::new(HloAdapter { engine }) as Box<dyn Engine>)
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthClass;

    #[test]
    fn native_engine_step_equals_grad_plus_apply() {
        let cfg = ExperimentConfig::default();
        let f = factory(&cfg).unwrap();
        let mut e1 = f(0).unwrap();
        let mut e2 = f(1).unwrap();
        let n = e1.n_params();
        let d = SynthClass::new(1, cfg.workload.input_dim, cfg.workload.classes, 1.0, 0.0);
        let batch = d.sample(&mut Rng::new(3, 0), 8);
        let w0 = e1.init(7).unwrap();
        let m0 = vec![0.01f32; n];

        let mut w_s = w0.clone();
        let mut m_s = m0.clone();
        let loss_s = e1.step(&mut w_s, &mut m_s, &batch, 0.1).unwrap();

        let mut g = vec![0.0; n];
        let loss_g = e2.grad(&w0, &batch, &mut g).unwrap();
        let mut w_a = w0.clone();
        let mut m_a = m0.clone();
        e2.apply(&mut w_a, &mut m_a, &g, 0.1).unwrap();

        assert_eq!(loss_s, loss_g);
        assert_eq!(w_s, w_a);
        assert_eq!(m_s, m_a);
    }

    #[test]
    fn factory_rejects_unknown_workload() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.backend = Backend::Native("bogus".into());
        assert!(factory(&cfg).is_err());
    }

    #[test]
    fn factory_rejects_missing_artifacts() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.backend = Backend::Hlo("mlp_small".into());
        cfg.artifacts_dir = "/nonexistent".into();
        assert!(factory(&cfg).is_err());
    }
}
