//! The distributed-training coordinator: leader + n worker nodes running
//! the paper's Algorithms 1/2 (and the FULLSGD/QSGD baselines) in
//! lockstep BSP over real threads and real collectives.
//!
//! Execution model
//! ---------------
//! Each simulated node is an OS thread owning a [`node::Node`]: its
//! parameters `w_i`, momentum `m_i` (momentum is **node-local**, as in
//! the paper — only parameters are averaged), RNG stream, data stream,
//! and compute engine (native workload or PJRT-executed HLO).  The
//! per-iteration synchronization behavior is a [`sync::SyncStep`]
//! pipeline — period gate, optional payload transform
//! (quantize/sparsify), collective exchange, S_k agreement, optional
//! elastic pull, ledger charge — so every strategy is a composition of
//! the same stages rather than a bespoke loop body.
//!
//! Synchronization runs over a pluggable
//! [`crate::collective::Collective`] (`cfg.sync.collective` selects the
//! chunked-parallel `ring` or the leader-serialized `flat`; both reduce
//! bit-identically); the per-sync wall-clock cost on the paper's testbed
//! is charged to a [`crate::netsim::CommLedger`], which prices the
//! configured algorithm.
//!
//! Period control is *replicated*: every node holds an identical
//! [`crate::period::PeriodController`] (inside its `SyncStep`) fed
//! identical `(k, S_k, γ_k)` feedback (S_k is agreed via a scalar
//! allreduce), so all replicas take identical sync decisions without a
//! central scheduler — exactly the decentralized structure of
//! Algorithm 2.

pub mod engine;
pub mod node;
pub mod observer;
pub mod sync;

use crate::collective::{self, Collective};
use crate::config::ExperimentConfig;
use crate::data::{Batch, DatasetHandle, NodeSource};
use crate::metrics::Recorder;
use crate::netsim::{CommKind, CommLedger, NetModel};
use crate::optim::lr_at;
use crate::period::Strategy;
use anyhow::{anyhow, Context, Result};
use node::Node;
use observer::{CheckpointObserver, ObserverHub, RecorderObserver, RunEvent, RunObserver};
use std::sync::{Arc, Mutex};
use sync::{ExchangeMode, SyncStep};

/// A session-injected period-controller factory: called once per worker
/// (controllers are replicated per rank) in place of the registry.
pub type ControllerFactory = dyn Fn() -> Box<dyn crate::period::PeriodController> + Send + Sync;

/// Session-level hooks threaded into one run: extra observers (beyond
/// the built-in recorder/checkpoint ones) and an optional custom period
/// controller.
#[derive(Default)]
pub(crate) struct RunHooks {
    pub observers: Vec<Box<dyn RunObserver>>,
    pub controller: Option<Arc<ControllerFactory>>,
}

/// Everything a finished run reports (curves + summary numbers).
#[derive(Debug)]
pub struct RunReport {
    pub name: String,
    pub strategy: Strategy,
    pub nodes: usize,
    pub iters: usize,
    pub n_params: usize,
    /// tail-mean of the (node-averaged) train loss
    pub final_train_loss: f64,
    pub min_train_loss: f64,
    pub best_eval_acc: f64,
    pub final_eval_acc: f64,
    pub final_eval_loss: f64,
    /// number of collective parameter/gradient exchanges
    pub syncs: u64,
    /// iters / syncs — the effective averaging period
    pub avg_period: f64,
    /// max over nodes of measured per-node compute time
    pub compute_secs: f64,
    /// measured wall-clock of the whole run (this host)
    pub wall_secs: f64,
    /// end-of-run maximum over the per-node *modeled* clocks (the
    /// `[cluster]` model: skew × per-iteration step time + faults +
    /// sync barriers).  Deterministic from config — unlike `wall_secs`
    /// it is stable across hosts, thread counts, and cache state, so
    /// campaign summaries may include it.
    pub modeled_wall_secs: f64,
    pub ledger: CommLedger,
    pub recorder: Recorder,
}

impl RunReport {
    /// Modeled execution time on the paper's testbed under `net`:
    /// per-node compute + modeled communication.
    pub fn modeled_total_secs(&self, net: &NetModel) -> f64 {
        self.compute_secs + self.ledger.modeled_secs(net)
    }

    /// Machine-readable run summary (optionally with every recorded
    /// series) — `adpsgd run --json`, CI diffing, notebooks.
    pub fn to_json(&self, with_series: bool) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("strategy", Json::str(self.strategy.to_string())),
            ("nodes", Json::num(self.nodes as f64)),
            ("iters", Json::num(self.iters as f64)),
            ("n_params", Json::num(self.n_params as f64)),
            ("final_train_loss", Json::num(self.final_train_loss)),
            ("min_train_loss", Json::num(self.min_train_loss)),
            ("best_eval_acc", Json::num(self.best_eval_acc)),
            ("final_eval_acc", Json::num(self.final_eval_acc)),
            ("final_eval_loss", Json::num(self.final_eval_loss)),
            ("syncs", Json::num(self.syncs as f64)),
            ("avg_period", Json::num(self.avg_period)),
            ("compute_secs", Json::num(self.compute_secs)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("modeled_wall_secs", Json::num(self.modeled_wall_secs)),
            ("wire_bytes", Json::num(self.ledger.total_wire_bytes() as f64)),
            ("comm_secs_model", Json::num(self.ledger.total_secs())),
        ];
        if with_series {
            let series = Json::Obj(
                self.recorder
                    .series
                    .iter()
                    .map(|(name, s)| {
                        let pts = Json::Arr(
                            s.points
                                .iter()
                                .map(|(x, y)| Json::Arr(vec![Json::num(*x), Json::num(*y)]))
                                .collect(),
                        );
                        (name.clone(), pts)
                    })
                    .collect(),
            );
            pairs.push(("series", series));
        }
        Json::obj(pairs)
    }

    pub fn one_line(&self) -> String {
        format!(
            "{:<10} loss={:.4} acc={:.4} syncs={} p̄={:.2} compute={} comm(model)={}",
            self.strategy.to_string(),
            self.final_train_loss,
            self.best_eval_acc,
            self.syncs,
            self.avg_period,
            crate::util::fmt::secs(self.compute_secs),
            crate::util::fmt::secs(self.ledger.total_secs()),
        )
    }
}

/// What a single worker thread hands back.
struct WorkerOut {
    compute_secs: f64,
    /// end-of-run maximum over the worker's replicated cluster clocks
    /// (identical on every rank — the model is deterministic)
    modeled_wall_secs: f64,
    /// rank 0 only
    ledger: Option<CommLedger>,
}

/// Build the (train-kind, eval) dataset handle and the per-node batch
/// geometry.  For HLO models the AOT artifacts fix the batch shape, so
/// `batch_per_node` is taken from the manifest.  Handles come from the
/// process-wide caches in [`crate::data::cache`] /
/// [`crate::runtime::Manifest::load_cached`], so campaign sweeps share
/// one dataset across runs instead of regenerating it per run.
fn dataset_for(cfg: &ExperimentConfig) -> Result<(DatasetHandle, usize, usize)> {
    let w = &cfg.workload;
    match &w.backend {
        crate::config::Backend::Native(_) => {
            let ds =
                crate::data::cache::synth_class(cfg.seed, w.input_dim, w.classes, w.noise, w.label_noise);
            Ok((DatasetHandle::Class(ds), cfg.batch_per_node, 0))
        }
        crate::config::Backend::Hlo(model) => {
            let man = crate::runtime::Manifest::load_cached(&cfg.artifacts_dir)?;
            let spec = man.get(model)?;
            if spec.kind == "lm" {
                let corpus = crate::data::cache::char_corpus(cfg.seed, 1 << 16);
                Ok((DatasetHandle::Text(corpus), spec.batch, spec.seq))
            } else {
                let dim = *spec.x_shape.last().unwrap();
                let classes = spec.classes.max(2);
                let ds = crate::data::cache::synth_class(cfg.seed, dim, classes, w.noise, w.label_noise);
                Ok((DatasetHandle::Class(ds), spec.batch, 0))
            }
        }
    }
}

/// Run one experiment to completion: spawn the worker cluster, feed the
/// leader's event stream to the observers, and assemble the report.
/// This is the engine under [`crate::experiment::Experiment`].
pub(crate) fn run_experiment(cfg: &ExperimentConfig, hooks: RunHooks) -> Result<RunReport> {
    cfg.validate()?;
    // kernel parallelism is process-global and bit-identical at any
    // setting, so applying it here (rather than per node) is safe even
    // when runs share the process — last writer wins, results don't move
    crate::tensor::par::set_threads(cfg.perf.threads);
    let RunHooks { observers: user_observers, controller } = hooks;
    let factory = engine::factory(cfg).context("building engine factory")?;
    let (dataset, batch, seq) = dataset_for(cfg)?;
    let wall = std::time::Instant::now();

    // n_params probe (cheap for native; for HLO reads the manifest)
    let n_params = match &cfg.workload.backend {
        crate::config::Backend::Native(name) => {
            crate::workload::build(name, &cfg.workload)?.n_params()
        }
        crate::config::Backend::Hlo(model) => {
            crate::runtime::Manifest::load_cached(&cfg.artifacts_dir)?.get(model)?.param_count
        }
    };

    // the built-in observers: the recorder (shared so the report can
    // reclaim the series afterwards) and, when configured, checkpointing
    let rec = Arc::new(Mutex::new(Recorder::new()));
    let mut observers: Vec<Box<dyn RunObserver>> =
        vec![Box::new(RecorderObserver::shared(Arc::clone(&rec)))];
    if cfg.checkpoint_every > 0 {
        observers.push(Box::new(CheckpointObserver::new(cfg.checkpoint_dir.clone())));
    }
    observers.extend(user_observers);
    let hub_slot = Mutex::new(Some(ObserverHub::new(observers)));

    let comm: Arc<dyn Collective> = collective::build(cfg.sync.collective, cfg.nodes, n_params);
    let mut outs: Vec<Option<WorkerOut>> = (0..cfg.nodes).map(|_| None).collect();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        let hub_slot = &hub_slot;
        for (rank, slot) in outs.iter_mut().enumerate() {
            let comm = Arc::clone(&comm);
            let dataset = dataset.clone();
            let factory = &factory;
            let ctrl_factory = controller.clone();
            handles.push((
                slot,
                scope.spawn(move || -> Result<WorkerOut> {
                    // the leader carries the observer hub; peers run bare
                    let hub = if rank == 0 { hub_slot.lock().unwrap().take() } else { None };
                    // catch_unwind so a panicking worker still
                    // poisons the communicator — otherwise peers
                    // would block forever at the next barrier
                    let comm2 = Arc::clone(&comm);
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        move || {
                            worker_loop(
                                cfg, rank, n_params, batch, seq, dataset, comm2, factory,
                                hub, ctrl_factory,
                            )
                        },
                    ))
                    .unwrap_or_else(|p| {
                        let msg = p
                            .downcast_ref::<String>()
                            .map(|s| s.as_str())
                            .or_else(|| p.downcast_ref::<&str>().copied())
                            .unwrap_or("<non-string panic>");
                        Err(anyhow!("node {rank} panicked: {msg}"))
                    });
                    if out.is_err() {
                        comm.poison();
                    }
                    out
                }),
            ));
        }
        // join all workers; report the most informative error (a
        // real failure beats the Poisoned errors it triggered)
        let mut first_real: Option<anyhow::Error> = None;
        let mut first_poisoned: Option<anyhow::Error> = None;
        for (slot, h) in handles {
            match h.join().map_err(|e| anyhow!("worker join failed: {e:?}")) {
                Ok(Ok(out)) => *slot = Some(out),
                Ok(Err(e)) => {
                    let is_poison = e.is::<crate::collective::Poisoned>()
                        || format!("{e:#}").contains("poisoned");
                    if is_poison {
                        first_poisoned.get_or_insert(e);
                    } else {
                        first_real.get_or_insert(e);
                    }
                }
                Err(e) => {
                    first_real.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_real.or(first_poisoned) {
            return Err(e.context("worker failed"));
        }
        Ok(())
    })?;

    let wall_secs = wall.elapsed().as_secs_f64();
    let compute_secs = outs
        .iter()
        .map(|o| o.as_ref().unwrap().compute_secs)
        .fold(0.0f64, f64::max);
    let rank0 = outs[0].take().unwrap();
    let modeled_wall_secs = rank0.modeled_wall_secs;
    let ledger = rank0.ledger.unwrap();
    // the hub (and with it the RecorderObserver's clone) died with the
    // leader thread, so the session holds the only reference now
    let recorder = match Arc::try_unwrap(rec) {
        Ok(m) => m.into_inner().expect("recorder lock"),
        Err(arc) => arc.lock().expect("recorder lock").clone(),
    };

    let loss_series = recorder.get("train_loss");
    let final_train_loss = loss_series.and_then(|s| s.tail_mean(10)).unwrap_or(f64::NAN);
    let min_train_loss = loss_series.and_then(|s| s.min_y()).unwrap_or(f64::NAN);
    let acc = recorder.get("eval_acc");
    let best_eval_acc = acc.and_then(|s| s.max_y()).unwrap_or(f64::NAN);
    let final_eval_acc = acc.and_then(|s| s.last_y()).unwrap_or(f64::NAN);
    let final_eval_loss =
        recorder.get("eval_loss").and_then(|s| s.last_y()).unwrap_or(f64::NAN);
    let syncs = ledger.syncs;
    let avg_period =
        if syncs > 0 { cfg.iters as f64 / syncs as f64 } else { f64::INFINITY };

    Ok(RunReport {
        name: cfg.name.clone(),
        strategy: cfg.sync.strategy,
        nodes: cfg.nodes,
        iters: cfg.iters,
        n_params,
        final_train_loss,
        min_train_loss,
        best_eval_acc,
        final_eval_acc,
        final_eval_loss,
        syncs,
        avg_period,
        compute_secs,
        wall_secs,
        modeled_wall_secs,
        ledger,
        recorder,
    })
}

/// How often the (instrumentation-only) mean train loss is agreed.
const LOSS_EVERY: usize = 10;

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: &ExperimentConfig,
    rank: usize,
    n_params: usize,
    batch_per_node: usize,
    seq: usize,
    dataset: DatasetHandle,
    comm: Arc<dyn Collective>,
    factory: &engine::EngineFactory,
    mut hub: Option<ObserverHub>,
    ctrl_factory: Option<Arc<ControllerFactory>>,
) -> Result<WorkerOut> {
    let n = cfg.nodes;
    let mut ledger = CommLedger::with_algo(n, cfg.sync.collective);

    let mut node =
        Node::build(cfg, rank, n_params, batch_per_node, seq, dataset, comm.as_ref(), factory)?;
    // warm starts continue the checkpointed run's global iteration count:
    // the period controller sees `resume + k` over a `resume + iters`
    // horizon, so Algorithm 2 does not re-run its p=1 warmup epoch or
    // resample C₂ from scratch, and schedule switch points stay global
    let resume = node.resume_iter;
    // per-node modeled clocks: the cluster model (skew, link asymmetry,
    // fault schedule) is fully deterministic from config, so every rank
    // derives the identical cluster timeline with zero communication —
    // the same replication trick the period controllers use.  It runs on
    // the global iteration axis, like the controllers.
    let cluster = crate::netsim::cluster::ClusterModel::from_config(
        &cfg.cluster,
        &cfg.net,
        n,
        resume + cfg.iters,
        cfg.seed,
    )?;
    let mut clock = crate::netsim::cluster::ClusterClock::new(cluster);
    let mut step = SyncStep::build(cfg, n_params, rank, resume, ctrl_factory.as_deref());
    // version-2 snapshots carry the controller's adaptive state (C₂, p):
    // restoring it makes the resume exact — without it Algorithm 2 would
    // re-seed C₂ from the first post-resume sync
    if let Some(state) = &node.resume_ctrl {
        step.restore_controller(state);
    }
    let grad_mode = step.mode == ExchangeMode::Gradient;

    if let Some(h) = hub.as_mut() {
        h.emit(&RunEvent::RunStart { cfg, n_params, resume_iter: resume })?;
    }

    // pre-averaging variance of a sync that happened this iteration —
    // the variance probe must report it instead of the (trivially zero)
    // post-averaging deviation
    let mut sync_var: Option<f64> = None;
    // scratch for the clock's per-sync wait attribution (leader only
    // reads it, but every rank laps the clock so the accounting stays
    // replicated and drained)
    let mut lap_waits: Vec<f64> = Vec::with_capacity(n);

    for k in 0..cfg.iters {
        // the LR schedule runs on the same global clock as the period
        // controller: a warm start resumes the decay schedule where the
        // checkpointed run left off instead of restarting at lr0
        let lr = lr_at(&cfg.optim.schedule, cfg.optim.lr0, resume + k);
        let batch = node.source.next_batch();

        match step.mode {
            ExchangeMode::Gradient => {
                // FULLSGD / QSGD / TopK: transform + exchange gradients,
                // then apply the agreed gradient locally
                node.grad_step(&batch)?;
                clock.step(resume + k);
                step.exchange_grad(&mut node, comm.as_ref(), &mut clock, &mut ledger, resume + k)?;
                node.apply_grad(lr)?;
            }
            ExchangeMode::Parameters => {
                // periodic parameter averaging: local step, then the
                // gated sync pipeline (see sync.rs for the stage table)
                node.local_step(&batch, lr)?;
                clock.step(resume + k);
                sync_var = None;
                if let Some(s_k) = step.maybe_sync_params(
                    &mut node,
                    comm.as_ref(),
                    &mut clock,
                    &mut ledger,
                    resume + k,
                    lr,
                )? {
                    sync_var = Some(s_k);
                    let comm_secs = clock.sync_lap(&mut lap_waits);
                    if let Some(h) = hub.as_mut() {
                        h.emit(&RunEvent::SyncDone {
                            k,
                            s_k,
                            period: step.current_period(),
                            bytes: (node.w.len() * 4) as u64,
                            comm_secs,
                            t: clock.max(),
                            waits: &lap_waits,
                        })?;
                    }
                }
            }
        }

        // ---------------- instrumentation (not charged to the ledger) -----
        let mut iter_loss = None;
        if (k + 1) % LOSS_EVERY == 0 || k + 1 == cfg.iters {
            let mean_loss =
                comm.allreduce_scalar_sum(rank, node.mean_local_loss())? / n as f64;
            iter_loss = Some(mean_loss);
            node.reset_loss_window();
        }
        if let Some(h) = hub.as_mut() {
            h.emit(&RunEvent::IterEnd { k, lr, loss: iter_loss })?;
        }

        let need_var = cfg.variance_every > 0 && (k + 1) % cfg.variance_every == 0 && !grad_mode;
        let need_eval = cfg.eval_every > 0 && ((k + 1) % cfg.eval_every == 0 || k + 1 == cfg.iters);
        if need_var || (need_eval && !grad_mode) {
            // snapshot mean parameters without disturbing training state
            node.w_pre.copy_from_slice(&node.w);
            comm.allreduce_mean(rank, &mut node.w_pre)?;
            if need_var {
                // if this iteration synchronized, the live parameters are
                // already averaged — report the pre-averaging variance S_k
                let var = match sync_var {
                    Some(s) => s,
                    None => {
                        let dev = crate::tensor::sq_deviation(&node.w_pre, &node.w);
                        comm.allreduce_scalar_sum(rank, dev)? / n as f64
                    }
                };
                if let Some(h) = hub.as_mut() {
                    h.emit(&RunEvent::VarProbe { k, var })?;
                }
            }
            if need_eval && hub.is_some() {
                let (l, a) =
                    eval_model(node.engine.as_mut(), &node.w_pre, &mut node.eval_source, cfg)?;
                if let Some(h) = hub.as_mut() {
                    h.emit(&RunEvent::EvalDone { k, loss: l, acc: a })?;
                }
            }
        } else if need_eval && grad_mode && hub.is_some() {
            // grad modes keep all nodes identical: evaluate local params
            let (l, a) = eval_model(node.engine.as_mut(), &node.w, &mut node.eval_source, cfg)?;
            if let Some(h) = hub.as_mut() {
                h.emit(&RunEvent::EvalDone { k, loss: l, acc: a })?;
            }
        }

        // ------------- checkpoint cadence (mean parameters agreed by ------
        // ------------- all ranks; the write is an observer's concern) -----
        if cfg.checkpoint_every > 0 && (k + 1) % cfg.checkpoint_every == 0 {
            // snapshot the averaged parameters without disturbing training
            node.w_pre.copy_from_slice(&node.w);
            comm.allreduce_mean(rank, &mut node.w_pre)?;
            if let Some(h) = hub.as_mut() {
                h.emit(&RunEvent::CheckpointDue {
                    iter: (resume + k + 1) as u64,
                    mean_loss: node.mean_local_loss(),
                    w: &node.w_pre,
                    ctrl: step.controller_state(),
                })?;
            }
        }
    }

    if let Some(h) = hub.as_mut() {
        h.emit(&RunEvent::RunEnd { iters: cfg.iters, node_secs: clock.nodes() })?;
    }

    Ok(WorkerOut {
        compute_secs: node.compute.secs(),
        modeled_wall_secs: clock.max(),
        ledger: hub.is_some().then_some(ledger),
    })
}

fn eval_model(
    engine: &mut dyn engine::Engine,
    w: &[f32],
    source: &mut NodeSource,
    cfg: &ExperimentConfig,
) -> Result<(f64, f64)> {
    let nb = cfg.workload.eval_batches.max(1);
    let (mut lsum, mut asum) = (0.0f64, 0.0f64);
    for _ in 0..nb {
        let b: Batch = source.next_batch();
        let (l, a) = engine.eval(w, &b)?;
        lsum += l as f64;
        asum += a as f64;
    }
    Ok((lsum / nb as f64, asum / nb as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Backend;

    /// Run a config through the session API (the tests' front door).
    fn train(cfg: ExperimentConfig) -> Result<RunReport> {
        crate::experiment::Experiment::from_config(cfg)?.run()
    }

    fn quick_cfg(strategy: Strategy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 4;
        cfg.iters = 120;
        cfg.batch_per_node = 16;
        cfg.eval_every = 60;
        cfg.workload.backend = Backend::Native("mlp".into());
        cfg.workload.input_dim = 32;
        cfg.workload.hidden = 16;
        cfg.workload.eval_batches = 4;
        cfg.optim.schedule = crate::config::LrSchedule::Const;
        cfg.optim.lr0 = 0.05;
        cfg.sync.strategy = strategy;
        cfg.sync.period = 4;
        cfg.sync.p_init = 2;
        cfg.sync.warmup_iters = 10;
        cfg.sync.ks_frac = 0.25;
        cfg
    }

    #[test]
    fn cpsgd_sync_count_matches_period() {
        let report = train(quick_cfg(Strategy::Constant)).unwrap();
        assert_eq!(report.syncs, 30); // 120 / 4
        assert!((report.avg_period - 4.0).abs() < 1e-9);
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn fullsgd_syncs_every_iteration() {
        let report = train(quick_cfg(Strategy::Full)).unwrap();
        assert_eq!(report.syncs, 120);
        assert!(report.ledger.count(CommKind::GradAllreduce) == 120);
    }

    #[test]
    fn qsgd_moves_fewer_bytes_than_fullsgd() {
        let full = train(quick_cfg(Strategy::Full)).unwrap();
        let qsgd = train(quick_cfg(Strategy::Qsgd)).unwrap();
        let fb = full.ledger.total_wire_bytes() as f64;
        let qb = qsgd.ledger.total_wire_bytes() as f64;
        assert!(qb < fb / 2.0, "qsgd bytes {qb} vs full {fb}");
        assert!(qsgd.final_train_loss.is_finite());
    }

    #[test]
    fn adaptive_records_period_and_sk() {
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.variance_every = 10;
        let report = train(cfg).unwrap();
        assert!(report.recorder.get("s_k").is_some());
        assert!(report.recorder.get("period").is_some());
        assert!(report.recorder.get("var").is_some());
        assert!(report.syncs > 0);
        assert!(report.ledger.count(CommKind::ScalarStat) > 0);
    }

    #[test]
    fn single_node_runs() {
        let mut cfg = quick_cfg(Strategy::Constant);
        cfg.nodes = 1;
        let report = train(cfg).unwrap();
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn training_actually_learns() {
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.iters = 400;
        cfg.workload.noise = 0.4;
        let report = train(cfg).unwrap();
        assert!(
            report.best_eval_acc > 0.8,
            "acc {} loss {}",
            report.best_eval_acc,
            report.final_train_loss
        );
        // loss decreased substantially from init (~ln 10 = 2.3)
        assert!(report.final_train_loss < 1.0);
    }

    #[test]
    fn piecewise_matches_paper_strategy1_budget() {
        let mut cfg = quick_cfg(Strategy::Piecewise);
        cfg.iters = 160;
        cfg.sync.piecewise = "0:4,80:8".into();
        let report = train(cfg).unwrap();
        assert_eq!(report.syncs, 30); // 80/4 + 80/8
    }

    #[test]
    fn easgd_trains_and_keeps_nodes_apart() {
        let mut cfg = quick_cfg(Strategy::Easgd);
        cfg.iters = 200;
        cfg.variance_every = 10;
        cfg.sync.period = 4;
        cfg.sync.easgd_alpha = 0.5;
        let easgd = train(cfg).unwrap();
        assert!(easgd.final_train_loss.is_finite());
        assert_eq!(easgd.syncs, 50);

        // elastic (α=0.5) leaves residual spread after syncs: its mean
        // variance exceeds CPSGD's at the same period
        let mut ccfg = quick_cfg(Strategy::Constant);
        ccfg.iters = 200;
        ccfg.variance_every = 10;
        ccfg.sync.period = 4;
        let cpsgd = train(ccfg).unwrap();
        let ev = easgd.recorder.get("var").unwrap().mean_y_in(20.0, 200.0).unwrap();
        let cv = cpsgd.recorder.get("var").unwrap().mean_y_in(20.0, 200.0).unwrap();
        assert!(ev > cv, "easgd var {ev:.3e} should exceed cpsgd var {cv:.3e}");
    }

    #[test]
    fn easgd_alpha_one_equals_cpsgd() {
        let mut ecfg = quick_cfg(Strategy::Easgd);
        ecfg.sync.easgd_alpha = 1.0;
        let e = train(ecfg).unwrap();
        let c = train(quick_cfg(Strategy::Constant)).unwrap();
        assert_eq!(e.final_train_loss, c.final_train_loss, "α=1 must reduce to CPSGD");
    }

    #[test]
    fn injected_node_failure_aborts_cluster_cleanly() {
        // chaos test: node 2 dies at step 15 mid-run; the run must
        // return an error naming the failure (not deadlock, not panic)
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.workload.backend = Backend::Native("failing:2:15".into());
        let start = std::time::Instant::now();
        let err = train(cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        assert!(msg.contains("node 2"), "{msg}");
        assert!(start.elapsed().as_secs() < 30, "must not hang");
    }

    #[test]
    fn failure_at_first_step_also_clean() {
        let mut cfg = quick_cfg(Strategy::Full);
        cfg.workload.backend = Backend::Native("failing:0:1".into());
        let err = train(cfg).unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
    }

    #[test]
    fn topk_trains_with_tiny_wire_budget() {
        let mut cfg = quick_cfg(Strategy::TopK);
        cfg.iters = 300;
        cfg.sync.topk_frac = 0.05;
        let topk = train(cfg).unwrap();
        let full = {
            let mut c = quick_cfg(Strategy::Full);
            c.iters = 300;
            train(c).unwrap()
        };
        // error feedback keeps it learning
        assert!(topk.best_eval_acc > 0.7, "topk acc {}", topk.best_eval_acc);
        // ~0.05 * 2 (idx+val) = 10% of dense payload, PS-style wire
        let ratio =
            full.ledger.total_wire_bytes() as f64 / topk.ledger.total_wire_bytes() as f64;
        assert!(ratio > 5.0, "wire ratio {ratio}");
        assert_eq!(topk.ledger.count(CommKind::SparsePs), 300);
    }

    #[test]
    fn checkpoint_and_warm_start() {
        let dir = std::env::temp_dir().join(format!("adpsgd_coord_ckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        // cold run writes snapshots
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.iters = 200;
        cfg.checkpoint_every = 100;
        cfg.checkpoint_dir = dir.to_str().unwrap().into();
        let cold = train(cfg).unwrap();
        let latest = crate::checkpoint::Checkpoint::latest(&dir).unwrap().expect("snapshots");
        let ck = crate::checkpoint::Checkpoint::load(&latest).unwrap();
        assert_eq!(ck.iter, 200);
        assert_eq!(ck.w.len(), cold.n_params);

        // warm start resumes at roughly the cold run's final loss
        let mut warm_cfg = quick_cfg(Strategy::Adaptive);
        warm_cfg.iters = 40;
        warm_cfg.init_from = dir.to_str().unwrap().into();
        let warm = train(warm_cfg).unwrap();
        let warm_first = warm.recorder.get("train_loss").unwrap().points[0].1;
        let mut cold_cfg = quick_cfg(Strategy::Adaptive);
        cold_cfg.iters = 40;
        let cold2 = train(cold_cfg).unwrap();
        let cold_first = cold2.recorder.get("train_loss").unwrap().points[0].1;
        assert!(
            warm_first < cold_first * 0.8,
            "warm start should begin near trained loss: warm {warm_first} vs cold {cold_first}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_resumes_adaptive_controller_state() {
        // regression: warm-starting used to restart Algorithm 2 at
        // iteration 0 (p=1 warmup re-run, C₂ resampled).  With the
        // resumed iteration threaded into the controller, a restart past
        // the warmup window must sync at p_init, not at p=1.
        let dir = std::env::temp_dir().join(format!("adpsgd_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let mut base = quick_cfg(Strategy::Adaptive);
        base.iters = 40;
        base.sync.warmup_iters = 10;
        base.sync.p_init = 2;
        // band wide enough that feedback never moves the period, so the
        // sync schedule is exactly p_init-periodic outside warmup
        base.sync.low = 0.01;
        base.sync.high = 100.0;

        let cold = train(base.clone()).unwrap();
        assert_eq!(cold.syncs, 25, "cold: 10 warmup syncs + 15 at p=2");

        let n_params = cold.n_params;
        crate::checkpoint::Checkpoint::new(200, 0.0, vec![0.01; n_params])
            .save(&crate::checkpoint::Checkpoint::path_for(&dir, 200))
            .unwrap();
        let mut warm_cfg = base.clone();
        warm_cfg.init_from = dir.to_str().unwrap().into();
        let warm = train(warm_cfg).unwrap();
        assert_eq!(
            warm.syncs, 20,
            "warm restart at iter 200 must skip the p=1 warmup and sync every p_init=2"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoints_carry_controller_state() {
        // a cold adaptive run past its sampling horizon must snapshot a
        // trained C₂ and the live period alongside the parameters
        let dir = std::env::temp_dir().join(format!("adpsgd_ctrl_ck_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.iters = 200;
        cfg.sync.ks_frac = 0.25; // k_s = 50 < 200: C₂ fully sampled
        cfg.checkpoint_every = 200;
        cfg.checkpoint_dir = dir.to_str().unwrap().into();
        let report = crate::experiment::Experiment::from_config(cfg).unwrap().run().unwrap();
        let latest = crate::checkpoint::Checkpoint::latest(&dir).unwrap().expect("snapshot");
        let ck = crate::checkpoint::Checkpoint::load(&latest).unwrap();
        let ctrl = ck.ctrl.expect("adaptive snapshots controller state");
        assert!(ctrl.c2_samples > 0, "C₂ running average was sampled: {ctrl:?}");
        assert!(ctrl.c2.is_finite() && ctrl.c2 > 0.0, "{ctrl:?}");
        assert!(ctrl.period >= 1);
        assert!(report.syncs > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_restores_sampled_c2_and_period() {
        // resume-equivalence regression: a restored controller must
        // adapt from the checkpointed C₂ immediately — not re-seed C₂
        // from the first post-resume sync.  The checkpoint carries an
        // absurdly large C₂, so every post-resume sync sees
        // S_k < low·γ·C₂ and the period grows deterministically:
        // restored p=4 → syncs at local k = 3, 8, 14, 21, 29, 38.
        let dir = std::env::temp_dir().join(format!("adpsgd_ctrl_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = quick_cfg(Strategy::Adaptive);
        cfg.iters = 40;
        cfg.sync.warmup_iters = 10; // resume at 200 is far past warmup
        cfg.sync.p_init = 2;

        let n_params = crate::workload::build("mlp", &cfg.workload).unwrap().n_params();
        let ctrl =
            crate::period::CtrlState { period: 4, cnt: 0, c2: 1e12, c2_samples: 1 };
        crate::checkpoint::Checkpoint::with_ctrl(200, 0.0, vec![0.01; n_params], Some(ctrl))
            .save(&crate::checkpoint::Checkpoint::path_for(&dir, 200))
            .unwrap();
        cfg.init_from = dir.to_str().unwrap().into();
        let warm = crate::experiment::Experiment::from_config(cfg).unwrap().run().unwrap();
        assert_eq!(
            warm.syncs, 6,
            "restored p=4 and huge C₂ must grow the period every sync \
             (p_init=2 would have produced ~20 syncs)"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_param_mismatch_fails_cleanly() {
        let dir = std::env::temp_dir().join(format!("adpsgd_mismatch_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        crate::checkpoint::Checkpoint::new(1, 0.0, vec![0.0; 17])
            .save(&crate::checkpoint::Checkpoint::path_for(&dir, 1))
            .unwrap();
        let mut cfg = quick_cfg(Strategy::Constant);
        cfg.init_from = dir.to_str().unwrap().into();
        let err = train(cfg).unwrap_err();
        assert!(format!("{err:#}").contains("params"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deterministic_across_runs() {
        let r1 = train(quick_cfg(Strategy::Adaptive)).unwrap();
        let r2 = train(quick_cfg(Strategy::Adaptive)).unwrap();
        assert_eq!(r1.final_train_loss, r2.final_train_loss);
        assert_eq!(r1.syncs, r2.syncs);
        let s1 = r1.recorder.get("train_loss").unwrap();
        let s2 = r2.recorder.get("train_loss").unwrap();
        assert_eq!(s1.points, s2.points);
    }

    #[test]
    fn flat_and_ring_collectives_agree_across_strategies() {
        // the full strategy matrix must be bit-identical under both
        // collective algorithms (same rank-order reduction), while the
        // cost model prices flat's leader serialization higher
        use crate::collective::Algo;
        let net = NetModel::infiniband_100g();
        for strategy in [
            Strategy::Full,
            Strategy::Constant,
            Strategy::Adaptive,
            Strategy::Qsgd,
            Strategy::TopK,
            Strategy::Easgd,
            Strategy::AdaComm,
            Strategy::PrSgd,
            Strategy::DaSgd,
        ] {
            let mut fcfg = quick_cfg(strategy);
            fcfg.sync.collective = Algo::Flat;
            let mut rcfg = quick_cfg(strategy);
            rcfg.sync.collective = Algo::Ring;
            let f = train(fcfg).unwrap();
            let r = train(rcfg).unwrap();
            assert_eq!(f.syncs, r.syncs, "{strategy}");
            assert_eq!(f.avg_period, r.avg_period, "{strategy}");
            assert_eq!(
                f.final_train_loss, r.final_train_loss,
                "{strategy}: loss under flat vs ring must be bit-identical"
            );
            let sf = f.recorder.get("train_loss").unwrap();
            let sr = r.recorder.get("train_loss").unwrap();
            assert_eq!(sf.points, sr.points, "{strategy}");
            assert!(
                f.ledger.modeled_secs(&net) >= r.ledger.modeled_secs(&net),
                "{strategy}: flat must never model faster than ring"
            );
        }
    }

    /// A straggler-heavy cluster for the heterogeneity tests: one node
    /// 4× slower, jittered step times, a pause and a delay spike.
    fn stragglerize(cfg: &mut ExperimentConfig) {
        cfg.cluster.skew = "straggler:4.0".into();
        cfg.cluster.jitter = 0.1;
        cfg.cluster.faults.pauses = 2;
        cfg.cluster.faults.pause_secs = 0.05;
        cfg.cluster.faults.spikes = 2;
        cfg.cluster.faults.spike_secs = 2e-3;
    }

    #[test]
    fn cluster_model_moves_clocks_never_bytes() {
        // the ISSUE's core invariant: a straggler-heavy scenario changes
        // modeled wall-clock per strategy while leaving the training
        // trajectory bit-identical to the uniform run of the same seed
        let mut walls = Vec::new();
        for strategy in [
            Strategy::Constant,
            Strategy::Adaptive,
            Strategy::AdaComm,
            Strategy::PrSgd,
            Strategy::DaSgd,
        ] {
            let uni = train(quick_cfg(strategy)).unwrap();
            let mut scfg = quick_cfg(strategy);
            stragglerize(&mut scfg);
            let skew = train(scfg).unwrap();
            assert_eq!(
                uni.final_train_loss, skew.final_train_loss,
                "{strategy}: cluster knobs must never touch parameter math"
            );
            assert_eq!(
                uni.recorder.get("train_loss").unwrap().points,
                skew.recorder.get("train_loss").unwrap().points,
                "{strategy}"
            );
            assert_eq!(uni.syncs, skew.syncs, "{strategy}");
            assert_eq!(
                uni.ledger.total_wire_bytes(),
                skew.ledger.total_wire_bytes(),
                "{strategy}: wire bytes are topology-, not timing-, dependent"
            );
            assert!(
                skew.modeled_wall_secs > uni.modeled_wall_secs,
                "{strategy}: stragglers/faults must slow the modeled clock \
                 (skew {} vs uniform {})",
                skew.modeled_wall_secs,
                uni.modeled_wall_secs
            );
            walls.push(skew.modeled_wall_secs);
        }
        // strategies pay differently for the same cluster: DaSGD's
        // overlap must beat CPSGD's barrier at the same period
        assert!(
            walls[4] < walls[0],
            "dasgd {} should overlap away barrier time vs cpsgd {}",
            walls[4],
            walls[0]
        );
    }

    #[test]
    fn cluster_knobs_leave_checkpointed_parameters_bit_identical() {
        // strongest form of the invariant: the final averaged parameter
        // bytes of a skewed/faulted run equal the uniform run's exactly
        let dir_a = std::env::temp_dir().join(format!("adpsgd_hetero_a_{}", std::process::id()));
        let dir_b = std::env::temp_dir().join(format!("adpsgd_hetero_b_{}", std::process::id()));
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
        let mut a = quick_cfg(Strategy::Adaptive);
        a.checkpoint_every = 120;
        a.checkpoint_dir = dir_a.to_str().unwrap().into();
        let mut b = a.clone();
        b.checkpoint_dir = dir_b.to_str().unwrap().into();
        stragglerize(&mut b);
        train(a).unwrap();
        train(b).unwrap();
        let load = |dir: &std::path::Path| {
            let p = crate::checkpoint::Checkpoint::latest(dir).unwrap().expect("snapshot");
            crate::checkpoint::Checkpoint::load(&p).unwrap()
        };
        let (ca, cb) = (load(&dir_a), load(&dir_b));
        let bits = |w: &[f32]| w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&ca.w), bits(&cb.w), "parameter bytes must be identical");
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn modeled_wall_clock_is_deterministic_and_thread_invariant() {
        // modeled time feeds stable campaign summaries, so it must not
        // depend on kernel thread count or repetition
        let mut cfg = quick_cfg(Strategy::Adaptive);
        stragglerize(&mut cfg);
        cfg.perf.threads = 1;
        let r1 = train(cfg.clone()).unwrap();
        let r2 = train({
            let mut c = cfg.clone();
            c.perf.threads = 4;
            c
        })
        .unwrap();
        let r3 = train(cfg).unwrap();
        assert_eq!(r1.modeled_wall_secs.to_bits(), r2.modeled_wall_secs.to_bits());
        assert_eq!(r1.modeled_wall_secs.to_bits(), r3.modeled_wall_secs.to_bits());
        assert!(r1.modeled_wall_secs > 0.0);
    }

    #[test]
    fn adacomm_decays_its_period_as_the_loss_falls() {
        let mut cfg = quick_cfg(Strategy::AdaComm);
        cfg.iters = 400;
        cfg.sync.adacomm_tau0 = 8;
        let report = train(cfg).unwrap();
        assert!(report.final_train_loss.is_finite());
        assert!(
            report.syncs > 50,
            "τ must decay below τ₀=8 as the loss falls (got {} syncs)",
            report.syncs
        );
        assert!(report.ledger.count(CommKind::ScalarStat) > 0, "loss agreement is charged");
    }

    #[test]
    fn prsgd_momentum_restart_changes_the_trajectory() {
        // PR-SGD at period p is CPSGD + momentum restart: with real
        // momentum the trajectories must differ, with zero momentum the
        // restart is a no-op and they must be bit-identical
        let mut p = quick_cfg(Strategy::PrSgd);
        p.optim.momentum = 0.9;
        let mut c = quick_cfg(Strategy::Constant);
        c.optim.momentum = 0.9;
        let rp = train(p).unwrap();
        let rc = train(c).unwrap();
        assert_eq!(rp.syncs, rc.syncs, "same schedule");
        assert_ne!(
            rp.final_train_loss, rc.final_train_loss,
            "momentum restart must alter training"
        );

        let mut p0 = quick_cfg(Strategy::PrSgd);
        p0.optim.momentum = 0.0;
        let mut c0 = quick_cfg(Strategy::Constant);
        c0.optim.momentum = 0.0;
        assert_eq!(
            train(p0).unwrap().final_train_loss,
            train(c0).unwrap().final_train_loss,
            "zero momentum: PR-SGD degenerates to CPSGD"
        );
    }

    #[test]
    fn dasgd_delivers_late_and_still_trains() {
        let mut cfg = quick_cfg(Strategy::DaSgd);
        cfg.sync.dasgd_delay = 2;
        let report = train(cfg).unwrap();
        assert_eq!(report.syncs, 30, "period-4 launches over 120 iters");
        assert!(report.final_train_loss.is_finite());
        assert!(report.final_train_loss < 2.0, "delayed averaging must still learn");
        // delayed averaging differs from synchronous averaging
        let cpsgd = train(quick_cfg(Strategy::Constant)).unwrap();
        assert_ne!(report.final_train_loss, cpsgd.final_train_loss);
    }
}
