//! Per-worker node state: everything one simulated node owns.
//!
//! A [`Node`] bundles the parameter vector `w_i`, node-local momentum
//! `m_i` (the paper averages only parameters), the gradient scratch, the
//! pre-sync snapshot buffer, the node's data streams, its compute
//! engine, and the compute stopwatch.  Construction performs the
//! cluster-wide pieces of startup — the engine-health agreement and the
//! shared-initial-point broadcast (all nodes start from rank 0's `w₀`,
//! as the paper requires) — so the training loop proper only ever sees a
//! healthy, initialized node.

use super::engine::{Engine, EngineFactory};
use crate::collective::Collective;
use crate::config::ExperimentConfig;
use crate::data::{Batch, DatasetHandle, NodeSource};
use crate::util::timer::Timer;
use anyhow::{anyhow, bail, Context, Result};

/// One worker's complete training state.
pub struct Node {
    pub rank: usize,
    /// cluster size (ranks in the collective)
    pub n: usize,
    pub engine: Box<dyn Engine>,
    /// parameters w_i
    pub w: Vec<f32>,
    /// node-local momentum m_i
    pub m: Vec<f32>,
    /// scratch: pre-sync snapshot / mean-parameter probe buffer
    pub w_pre: Vec<f32>,
    /// gradient scratch (gradient-exchange modes)
    pub g: Vec<f32>,
    /// training batch stream (per-node RNG stream)
    pub source: NodeSource,
    /// held-out stream for evaluation (leader only consumes it)
    pub eval_source: NodeSource,
    /// accumulated local compute time (the figure models' numerator)
    pub compute: Timer,
    /// local loss accumulated since the last agreement window
    pub loss_acc: f64,
    pub loss_cnt: u32,
    /// global iteration this run resumes from (the checkpoint's `iter`
    /// for warm starts, 0 for cold starts) — threaded into the period
    /// controller so Algorithm 2 continues where it left off instead of
    /// re-running its warmup epoch and C₂ sampling
    pub resume_iter: usize,
    /// the checkpoint's period-controller state (warm starts from a
    /// version-2 snapshot) — restored into the sync pipeline so resume
    /// is exact: the sampled C₂ and current period p survive the restart
    pub resume_ctrl: Option<crate::period::CtrlState>,
}

impl Node {
    /// Construct this rank's node: build the engine (agreeing
    /// cluster-wide that every peer succeeded), establish the shared
    /// initial point, and open the data streams.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        cfg: &ExperimentConfig,
        rank: usize,
        n_params: usize,
        batch_per_node: usize,
        seq: usize,
        dataset: DatasetHandle,
        comm: &dyn Collective,
        factory: &EngineFactory,
    ) -> Result<Node> {
        // --- engine construction + cluster health check ------------------
        let engine_res = factory(rank);
        let healthy =
            comm.allreduce_scalar_sum(rank, if engine_res.is_ok() { 0.0 } else { 1.0 })?;
        if healthy > 0.0 {
            return match engine_res {
                Err(e) => Err(e).context(format!("node {rank}: engine construction")),
                Ok(_) => bail!("node {rank}: peer failed during engine construction"),
            };
        }
        let mut engine = engine_res.unwrap();
        debug_assert_eq!(engine.n_params(), n_params);

        // --- shared initial point (paper: all nodes start from w_0) ------
        let mut resume_iter = 0usize;
        let mut resume_ctrl = None;
        let mut w = if cfg.init_from.is_empty() {
            engine.init(cfg.seed)?
        } else {
            // warm start: all nodes load the same snapshot
            let p = std::path::Path::new(&cfg.init_from);
            let file = if p.is_dir() {
                crate::checkpoint::Checkpoint::latest(p)?
                    .ok_or_else(|| anyhow!("no checkpoints in {}", p.display()))?
            } else {
                p.to_path_buf()
            };
            let ck = crate::checkpoint::Checkpoint::load(&file)?;
            if ck.w.len() != n_params {
                bail!(
                    "checkpoint {} has {} params, model has {n_params}",
                    file.display(),
                    ck.w.len()
                );
            }
            resume_iter = ck.iter as usize;
            resume_ctrl = ck.ctrl;
            ck.w
        };
        comm.broadcast(rank, &mut w)?;

        let source =
            NodeSource::new(dataset.clone(), cfg.seed, rank as u64, batch_per_node, seq);
        let eval_source =
            NodeSource::new(dataset, cfg.seed ^ 0xEA11, 0xE0 + rank as u64, batch_per_node, seq);

        Ok(Node {
            rank,
            n: cfg.nodes,
            engine,
            w,
            m: vec![0.0f32; n_params],
            w_pre: vec![0.0f32; n_params],
            g: vec![0.0f32; n_params],
            source,
            eval_source,
            compute: Timer::new(),
            loss_acc: 0.0,
            loss_cnt: 0,
            resume_iter,
            resume_ctrl,
        })
    }

    /// Local fused step (parameter-averaging modes): updates (w, m) in
    /// place, timed as compute, loss accumulated for the agreement
    /// window.
    pub fn local_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        self.compute.start();
        let r = self.engine.step(&mut self.w, &mut self.m, batch, lr);
        self.compute.stop();
        let loss = r?;
        self.loss_acc += loss as f64;
        self.loss_cnt += 1;
        Ok(loss)
    }

    /// Gradient-only step (gradient-exchange modes): fills `self.g`.
    pub fn grad_step(&mut self, batch: &Batch) -> Result<f32> {
        self.compute.start();
        let r = self.engine.grad(&self.w, batch, &mut self.g);
        self.compute.stop();
        let loss = r?;
        self.loss_acc += loss as f64;
        self.loss_cnt += 1;
        Ok(loss)
    }

    /// Apply the (averaged) gradient in `self.g` with the fused momentum
    /// rule.
    pub fn apply_grad(&mut self, lr: f32) -> Result<()> {
        self.compute.start();
        let r = self.engine.apply(&mut self.w, &mut self.m, &self.g, lr);
        self.compute.stop();
        r
    }

    /// Mean local loss over the current agreement window.
    pub fn mean_local_loss(&self) -> f64 {
        self.loss_acc / self.loss_cnt.max(1) as f64
    }

    /// Start a new loss-agreement window.
    pub fn reset_loss_window(&mut self) {
        self.loss_acc = 0.0;
        self.loss_cnt = 0;
    }
}
