//! The run-observer event stream: typed events out of the coordinator
//! loop, consumers plugged in at session build time.
//!
//! The training loop no longer hard-codes *what happens* to its
//! measurements — it emits [`RunEvent`]s on the leader rank, and an
//! [`ObserverHub`] fans them out to every registered [`RunObserver`]:
//!
//! * [`RecorderObserver`] rebuilds the metric series every figure and
//!   test consumes (`train_loss`, `s_k`, `period`, `var`, `eval_acc`,
//!   …) — exactly the pushes the loop used to make inline;
//! * [`CheckpointObserver`] writes parameter snapshots on
//!   [`RunEvent::CheckpointDue`] — the collective mean-parameter
//!   agreement stays in the loop (all ranks participate), only the
//!   leader-side *write* lives here;
//! * user observers (live progress, external metric sinks, early-stop
//!   probes) ride the same stream via
//!   `ExperimentBuilder::observer`.
//!
//! Observers run on the leader worker's thread, between iterations: an
//! observer error aborts the run cleanly (the cluster tears down through
//! the same poisoned-collective path as any worker failure).

use crate::config::ExperimentConfig;
use crate::metrics::Recorder;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// One typed event out of the coordinator loop.  `k` is the run-local
/// iteration index (0-based); warm-started runs report their global
/// offset once in [`RunEvent::RunStart`].
#[derive(Debug)]
pub enum RunEvent<'a> {
    /// Emitted once before the first iteration.
    RunStart {
        cfg: &'a ExperimentConfig,
        n_params: usize,
        /// global iteration the run resumes from (0 for cold starts)
        resume_iter: usize,
    },
    /// Emitted after every iteration.  `loss` carries the cluster-agreed
    /// mean train loss on agreement windows, `None` in between.
    IterEnd { k: usize, lr: f32, loss: Option<f64> },
    /// A parameter synchronization completed: the agreed variance `S_k`,
    /// the controller's (post-feedback) period, and the payload bytes.
    /// The timing fields come from the replicated
    /// [`crate::netsim::cluster::ClusterClock`]: `comm_secs` is the
    /// modeled wire cost of this sync, `t` the post-sync modeled
    /// cluster time, and `waits` the per-node barrier-wait seconds
    /// accumulated since the previous sync (rank order) — together the
    /// raw material `adpsgd trace` attributes per-node time from.
    SyncDone {
        k: usize,
        s_k: f64,
        period: usize,
        bytes: u64,
        comm_secs: f64,
        t: f64,
        waits: &'a [f64],
    },
    /// A variance probe sampled `Var[W_k]` (instrumentation).
    VarProbe { k: usize, var: f64 },
    /// A held-out evaluation completed.
    EvalDone { k: usize, loss: f64, acc: f64 },
    /// The checkpoint cadence fired: `w` holds the cluster-mean
    /// parameters after `iter` completed iterations (1-based), and
    /// `ctrl` the period controller's state (for exact warm-start
    /// resume; `None` for stateless strategies).
    CheckpointDue {
        iter: u64,
        mean_loss: f64,
        w: &'a [f32],
        ctrl: Option<crate::period::CtrlState>,
    },
    /// Emitted once after the last iteration.  `node_secs` is every
    /// node's final modeled clock (rank order), so consumers can close
    /// the per-node time attribution without replaying the run.
    RunEnd { iters: usize, node_secs: &'a [f64] },
}

/// A consumer of the coordinator's event stream.
pub trait RunObserver: Send {
    fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()>;
}

/// Leader-side fan-out of one run's events to all observers.
pub struct ObserverHub {
    observers: Vec<Box<dyn RunObserver>>,
}

impl ObserverHub {
    pub fn new(observers: Vec<Box<dyn RunObserver>>) -> Self {
        ObserverHub { observers }
    }

    pub fn emit(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        // fan out to *every* observer even when one fails: a metrics
        // sink blowing up must not starve the checkpoint writer or the
        // journal of this event (in particular the terminal `RunEnd`).
        // The first error is remembered and returned after the loop,
        // so an observer failure still aborts the run cleanly.
        let mut first_err: Option<anyhow::Error> = None;
        for o in &mut self.observers {
            if let Err(e) = o.on_event(ev) {
                first_err.get_or_insert(e.context("run observer failed"));
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Rebuilds the historical [`Recorder`] series from the event stream.
/// The recorder is shared (`Arc<Mutex<…>>`) so the session can hand the
/// final series to [`crate::coordinator::RunReport`] after the run.
pub struct RecorderObserver {
    rec: Arc<Mutex<Recorder>>,
}

impl RecorderObserver {
    pub fn shared(rec: Arc<Mutex<Recorder>>) -> Self {
        RecorderObserver { rec }
    }
}

impl RunObserver for RecorderObserver {
    fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        let mut rec = self.rec.lock().expect("recorder lock");
        match ev {
            RunEvent::IterEnd { k, lr, loss: Some(loss) } => {
                rec.push("train_loss", *k as f64, *loss);
                rec.push("lr", *k as f64, *lr as f64);
            }
            RunEvent::SyncDone { k, s_k, period, .. } => {
                rec.push("s_k", *k as f64, *s_k);
                rec.push("period", *k as f64, *period as f64);
                rec.push("sync_at", *k as f64, 1.0);
            }
            RunEvent::VarProbe { k, var } => rec.push("var", *k as f64, *var),
            RunEvent::EvalDone { k, loss, acc } => {
                rec.push("eval_loss", *k as f64, *loss);
                rec.push("eval_acc", *k as f64, *acc);
            }
            _ => {}
        }
        Ok(())
    }
}

/// Writes a parameter snapshot on every [`RunEvent::CheckpointDue`].
pub struct CheckpointObserver {
    dir: PathBuf,
}

impl CheckpointObserver {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointObserver { dir: dir.into() }
    }
}

impl RunObserver for CheckpointObserver {
    fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        if let RunEvent::CheckpointDue { iter, mean_loss, w, ctrl } = ev {
            crate::checkpoint::Checkpoint::with_ctrl(*iter, *mean_loss, w.to_vec(), *ctrl)
                .save(&crate::checkpoint::Checkpoint::path_for(&self.dir, *iter))
                .context("writing checkpoint")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_observer_rebuilds_series() {
        let rec = Arc::new(Mutex::new(Recorder::new()));
        let mut obs = RecorderObserver::shared(Arc::clone(&rec));
        obs.on_event(&RunEvent::IterEnd { k: 0, lr: 0.1, loss: None }).unwrap();
        obs.on_event(&RunEvent::IterEnd { k: 9, lr: 0.1, loss: Some(2.0) }).unwrap();
        obs.on_event(&RunEvent::SyncDone {
            k: 3,
            s_k: 0.5,
            period: 4,
            bytes: 64,
            comm_secs: 1e-3,
            t: 0.05,
            waits: &[0.0, 2e-3],
        })
        .unwrap();
        obs.on_event(&RunEvent::VarProbe { k: 5, var: 0.25 }).unwrap();
        obs.on_event(&RunEvent::EvalDone { k: 9, loss: 1.5, acc: 0.7 }).unwrap();
        let rec = rec.lock().unwrap();
        assert_eq!(rec.get("train_loss").unwrap().points, vec![(9.0, 2.0)]);
        assert!(rec.get("lr").is_some());
        assert_eq!(rec.get("s_k").unwrap().points, vec![(3.0, 0.5)]);
        assert_eq!(rec.get("period").unwrap().points, vec![(3.0, 4.0)]);
        assert_eq!(rec.get("sync_at").unwrap().points, vec![(3.0, 1.0)]);
        assert_eq!(rec.get("var").unwrap().points, vec![(5.0, 0.25)]);
        assert_eq!(rec.get("eval_acc").unwrap().points, vec![(9.0, 0.7)]);
    }

    #[test]
    fn checkpoint_observer_writes_snapshots() {
        let dir = std::env::temp_dir().join(format!("adpsgd_obs_ckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut obs = CheckpointObserver::new(&dir);
        let w = vec![0.5f32; 16];
        let ctrl = crate::period::CtrlState { period: 6, cnt: 2, c2: 1.25, c2_samples: 9 };
        obs.on_event(&RunEvent::CheckpointDue {
            iter: 40,
            mean_loss: 0.1,
            w: &w,
            ctrl: Some(ctrl),
        })
        .unwrap();
        let latest = crate::checkpoint::Checkpoint::latest(&dir).unwrap().expect("snapshot");
        let ck = crate::checkpoint::Checkpoint::load(&latest).unwrap();
        assert_eq!(ck.iter, 40);
        assert_eq!(ck.w, w);
        assert_eq!(ck.ctrl, Some(ctrl), "controller state rides the snapshot");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hub_propagates_observer_errors() {
        struct Failing;
        impl RunObserver for Failing {
            fn on_event(&mut self, _: &RunEvent<'_>) -> Result<()> {
                anyhow::bail!("observer exploded")
            }
        }
        let mut hub = ObserverHub::new(vec![Box::new(Failing)]);
        let err = hub.emit(&RunEvent::RunEnd { iters: 1, node_secs: &[] }).unwrap_err();
        assert!(format!("{err:#}").contains("observer exploded"));
    }

    #[test]
    fn a_failing_observer_does_not_starve_later_observers() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Failing;
        impl RunObserver for Failing {
            fn on_event(&mut self, _: &RunEvent<'_>) -> Result<()> {
                anyhow::bail!("first observer exploded")
            }
        }
        struct Counting(Arc<AtomicUsize>, Arc<AtomicUsize>);
        impl RunObserver for Counting {
            fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
                self.0.fetch_add(1, Ordering::SeqCst);
                if matches!(ev, RunEvent::RunEnd { .. }) {
                    self.1.fetch_add(1, Ordering::SeqCst);
                }
                Ok(())
            }
        }
        let seen = Arc::new(AtomicUsize::new(0));
        let ends = Arc::new(AtomicUsize::new(0));
        let mut hub = ObserverHub::new(vec![
            Box::new(Failing),
            Box::new(Counting(Arc::clone(&seen), Arc::clone(&ends))),
        ]);
        // the error still surfaces (the run must abort)…
        let err = hub.emit(&RunEvent::RunEnd { iters: 5, node_secs: &[] }).unwrap_err();
        assert!(format!("{err:#}").contains("first observer exploded"), "{err:#}");
        // …but the observer *after* the failing one still saw the
        // terminal event — a journal or checkpoint sink gets its
        // RunEnd even when an earlier sink is broken
        assert_eq!(seen.load(Ordering::SeqCst), 1);
        assert_eq!(ends.load(Ordering::SeqCst), 1);

        // and a later error never masks the first one
        struct AlsoFailing;
        impl RunObserver for AlsoFailing {
            fn on_event(&mut self, _: &RunEvent<'_>) -> Result<()> {
                anyhow::bail!("second observer exploded")
            }
        }
        let mut hub = ObserverHub::new(vec![Box::new(Failing), Box::new(AlsoFailing)]);
        let err = hub.emit(&RunEvent::RunEnd { iters: 5, node_secs: &[] }).unwrap_err();
        assert!(format!("{err:#}").contains("first observer exploded"), "{err:#}");
    }
}
