//! The synchronization pipeline: every strategy, decomposed into stages.
//!
//! The paper's strategies differ only in *which stages run*, never in
//! the loop structure, so [`SyncStep`] composes them explicitly instead
//! of the historical inlined `if`-chains:
//!
//! | stage            | FULLSGD | QSGD | TopK | CPSGD | ADPSGD | EASGD | ADACOMM | PRSGD | DASGD |
//! |------------------|---------|------|------|-------|--------|-------|---------|-------|-------|
//! | period gate      |    —    |  —   |  —   |   ✓   |   ✓    |   ✓   |    ✓    |   ✓   |   ✓   |
//! | payload transform|    —    | QSGD | top-k|   —   |   —    |   —   |    —    |   —   |   —   |
//! | collective       |  grads  | grads| grads| params| params | params| params  | params| params|
//! | S_k agreement    |    —    |  —   |  —   |   ✓   |   ✓    |   ✓   |    ✓    |   ✓   |   ✓   |
//! | elastic pull     |    —    |  —   |  —   |   —   |   —    |   ✓   |    —    |   —   |   —   |
//! | momentum restart |    —    |  —   |  —   |   —   |   —    |   —   |    —    |   ✓   |   —   |
//! | delayed apply    |    —    |  —   |  —   |   —   |   —    |   —   |    —    |   —   |   ✓   |
//! | loss agreement   |    —    |  —   |  —   |   —   |   —    |   —   |    ✓    |   —   |   —   |
//! | extra ledger stat|    —    |  —   |  —   |   —   |  S_k   |   —   |  F(w)   |   —   |   —   |
//! | period feedback  |    —    |  —   |  —   | no-op |  Alg. 2| no-op | τ decay | no-op | no-op |
//!
//! Gradient-mode strategies run [`SyncStep::exchange_grad`] every
//! iteration; parameter-mode strategies run
//! [`SyncStep::maybe_sync_params`], whose period gate is the
//! [`PeriodController`].  Compression plugs in through the
//! [`GradTransform`] hook (QSGD quantization and top-k sparsification
//! both flow through it — there is no bespoke branch per codec), the
//! collective through [`crate::collective::Collective`], and the cost
//! through the [`CommLedger`], which prices the configured collective
//! algorithm.  New strategies are new stage combinations, not new loop
//! bodies.

use super::node::Node;
use crate::collective::{Collective, Poisoned};
use crate::config::{ExperimentConfig, StrategySpec};
use crate::netsim::cluster::ClusterClock;
use crate::netsim::{CommKind, CommLedger};
use crate::period::{registry, PeriodController};
use crate::quant::QsgdConfig;
use crate::sparse::{Residual, TopKConfig};
use crate::util::rng::Rng;

/// Whether a strategy exchanges gradients every iteration or parameters
/// periodically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// FULLSGD / QSGD / TopK: a (possibly compressed) gradient exchange
    /// every iteration; the averaged gradient then drives the update.
    Gradient,
    /// CPSGD / ADPSGD / EASGD / schedules: local updates, with parameter
    /// averaging when the period controller fires.
    Parameters,
}

/// Lossy payload transform applied to the gradient before its exchange
/// (the compression stage of the pipeline).  Implementations are
/// node-local (they may carry residual/RNG state) and report the wire
/// bytes their encoded form would occupy so the ledger can price the
/// exchange.
pub trait GradTransform: Send {
    /// Compress `g` in place; returns the encoded wire bytes.
    fn apply(&mut self, g: &mut [f32]) -> u64;
    /// Ledger category the transformed exchange is charged as.
    fn kind(&self) -> CommKind;
}

/// QSGD stochastic quantization (fused quantize+dequantize; see
/// [`crate::quant`]).  Charged as a PS-style compressed allgather.
pub struct QsgdTransform {
    cfg: QsgdConfig,
    rng: Rng,
    /// bucket-norm buffer reused across syncs (the transform runs every
    /// exchange; without this it would reallocate per call)
    scratch: crate::quant::QsgdScratch,
}

impl GradTransform for QsgdTransform {
    fn apply(&mut self, g: &mut [f32]) -> u64 {
        crate::quant::quantize_inplace_with(g, &self.cfg, &mut self.rng, &mut self.scratch)
    }

    fn kind(&self) -> CommKind {
        CommKind::QuantAllgather
    }
}

/// Top-k sparsification with error feedback (see [`crate::sparse`]).
pub struct TopKTransform {
    cfg: TopKConfig,
    res: Residual,
}

impl GradTransform for TopKTransform {
    fn apply(&mut self, g: &mut [f32]) -> u64 {
        crate::sparse::sparsify_inplace(g, &mut self.res, &self.cfg)
    }

    fn kind(&self) -> CommKind {
        CommKind::SparsePs
    }
}

/// One node's synchronization pipeline: the stage composition for the
/// configured strategy.  Replicated per worker (like the period
/// controller) so all ranks take identical decisions without a central
/// scheduler.
pub struct SyncStep {
    pub mode: ExchangeMode,
    controller: Option<Box<dyn PeriodController>>,
    transform: Option<Box<dyn GradTransform>>,
    /// EASGD: move this fraction toward the mean instead of adopting it.
    elastic_alpha: Option<f32>,
    /// ADPSGD: charge the S_k scalar exchange to the ledger.
    charge_scalar_stat: bool,
    /// PR-SGD: zero the momentum buffer after adopting the average
    /// (each averaging point restarts the local SGD phase).
    reset_momentum: bool,
    /// DaSGD: delayed-averaging state (`None` for every other strategy).
    dasgd: Option<DaSgd>,
}

/// DaSGD's in-flight average.  The allreduce launched at a sync point is
/// applied `delay` iterations later as `w ← mean + (w − snap)`, crediting
/// the local progress made while the collective was in flight.  Modeled
/// time overlaps communication with compute: nothing barriers at launch,
/// and the delivery only waits until the collective's modeled completion
/// (`ready_at`).
struct DaSgd {
    delay: usize,
    /// parameters at launch (the in-flight average's reference point)
    snap: Vec<f32>,
    /// the agreed mean, held until delivery
    mean: Vec<f32>,
    /// global iteration index at which the pending mean lands
    deliver_at: usize,
    /// modeled completion time of the in-flight allreduce
    ready_at: f64,
    pending: bool,
}

impl SyncStep {
    /// Compose the pipeline for `cfg`'s strategy.  `rank` seeds the
    /// quantizer's per-node RNG stream.  The stage composition is driven
    /// entirely by the typed [`StrategySpec`]: the period gate comes
    /// from the controller [`registry`] (or from `controller_factory`,
    /// the session-level injection seam that bypasses the registry), the
    /// payload transform and elastic pull from the spec's own payload.
    ///
    /// `resume_iter` is the warm-start offset: controllers see global
    /// iteration indices, so k-fraction horizons (ADPSGD's `K_s`, the
    /// decreasing schedule's switch point) are computed over the global
    /// span `resume_iter + iters` — a run checkpointed at 200 and
    /// resumed for 3800 more iterations adapts on the same global
    /// schedule as the cold 4000-iteration run.
    pub fn build(
        cfg: &ExperimentConfig,
        n_params: usize,
        rank: usize,
        resume_iter: usize,
        controller_factory: Option<&super::ControllerFactory>,
    ) -> SyncStep {
        let spec = cfg.sync.spec();
        let controller = match controller_factory {
            Some(f) => Some(f()),
            None => registry::build(
                &spec,
                &registry::Ctx { total_iters: resume_iter + cfg.iters },
            ),
        };
        let mode = if controller.is_none() {
            ExchangeMode::Gradient
        } else {
            ExchangeMode::Parameters
        };
        let transform: Option<Box<dyn GradTransform>> = match &spec {
            StrategySpec::Qsgd { levels, bucket } => Some(Box::new(QsgdTransform {
                cfg: QsgdConfig { levels: *levels, bucket: *bucket },
                rng: Rng::new(cfg.seed ^ 0x9569D, rank as u64),
                scratch: crate::quant::QsgdScratch::default(),
            })),
            StrategySpec::TopK { frac } => Some(Box::new(TopKTransform {
                cfg: TopKConfig { keep_frac: *frac },
                res: Residual::new(n_params),
            })),
            _ => None,
        };
        let elastic_alpha = match &spec {
            // α = 1 degenerates to CPSGD: the elastic stage composes away
            StrategySpec::Easgd { alpha, .. } if *alpha < 1.0 => Some(*alpha as f32),
            _ => None,
        };
        let dasgd = match &spec {
            StrategySpec::DaSgd { delay, .. } => Some(DaSgd {
                delay: *delay,
                snap: vec![0.0; n_params],
                mean: vec![0.0; n_params],
                deliver_at: 0,
                ready_at: 0.0,
                pending: false,
            }),
            _ => None,
        };
        SyncStep {
            mode,
            controller,
            transform,
            elastic_alpha,
            charge_scalar_stat: matches!(spec, StrategySpec::Adaptive { .. }),
            reset_momentum: matches!(spec, StrategySpec::PrSgd { .. }),
            dasgd,
        }
    }

    /// Current averaging period (for the Fig 3 trajectory log).
    pub fn current_period(&self) -> usize {
        self.controller.as_ref().map(|c| c.current_period()).unwrap_or(1)
    }

    /// The period controller's adaptive state for a checkpoint (`None`
    /// in gradient mode or for stateless controllers).  All ranks hold
    /// identical controllers, so the leader's snapshot speaks for the
    /// cluster.
    pub fn controller_state(&self) -> Option<crate::period::CtrlState> {
        self.controller.as_ref().and_then(|c| c.snapshot())
    }

    /// Restore a checkpointed controller state (warm start): Algorithm
    /// 2 resumes with its sampled C₂ and adapted period instead of
    /// re-seeding them from the first post-resume sync.
    pub fn restore_controller(&mut self, state: &crate::period::CtrlState) {
        if let Some(c) = self.controller.as_mut() {
            c.restore(state);
        }
    }

    /// Gradient-mode chain: payload transform (timed as compute) →
    /// ledger charge → collective exchange → modeled barrier.  The
    /// averaged gradient lands back in `node.g`.  The exchange prices
    /// against the cluster's bottleneck link *at iteration `k`* (delay
    /// spikes hit whatever exchange is in flight), and every node's
    /// modeled clock barriers on the slowest participant.
    pub fn exchange_grad(
        &mut self,
        node: &mut Node,
        comm: &dyn Collective,
        clock: &mut ClusterClock,
        ledger: &mut CommLedger,
        k: usize,
    ) -> Result<(), Poisoned> {
        let net = clock.net_at(k);
        let secs = match self.transform.as_mut() {
            Some(t) => {
                node.compute.start();
                let wire = t.apply(&mut node.g);
                node.compute.stop();
                ledger.record(&net, t.kind(), node.n, wire)
            }
            None => {
                ledger.record(&net, CommKind::GradAllreduce, node.n, (node.g.len() * 4) as u64)
            }
        };
        clock.barrier(secs);
        comm.allreduce_mean(node.rank, &mut node.g)
    }

    /// Parameter-mode chain: delayed delivery (DaSGD) → period gate →
    /// pre-sync snapshot → ledger charge → collective exchange → S_k
    /// agreement → elastic pull → momentum restart → loss agreement →
    /// extra ledger stat → modeled barrier → period feedback.  Returns
    /// the agreed S_k when a synchronization happened, `None` otherwise.
    ///
    /// `k` is the *global* iteration index (warm starts pass
    /// `resume_iter + local_k`), matching the [`PeriodController`]
    /// contract; the modeled clock runs on the same axis.
    ///
    /// Heterogeneity discipline: the clock and ledger consume the
    /// cluster model, the parameter math never does — identical configs
    /// modulo `[cluster]` produce bit-identical parameters.
    pub fn maybe_sync_params(
        &mut self,
        node: &mut Node,
        comm: &dyn Collective,
        clock: &mut ClusterClock,
        ledger: &mut CommLedger,
        k: usize,
        lr: f32,
    ) -> Result<Option<f64>, Poisoned> {
        // DaSGD delivery runs before the period gate so a landing mean
        // is never starved by the next trigger: w ← mean + (w − snap)
        // credits the local progress made while the average was in
        // flight (arXiv 2006.00441 eq. 4)
        if let Some(d) = self.dasgd.as_mut() {
            if d.pending && k >= d.deliver_at {
                for (wj, (mj, sj)) in
                    node.w.iter_mut().zip(d.mean.iter().zip(d.snap.iter()))
                {
                    *wj = mj + (*wj - sj);
                }
                clock.wait_until(d.ready_at);
                d.pending = false;
            }
        }
        let ctrl =
            self.controller.as_mut().expect("parameter mode requires a period controller");
        if !ctrl.should_sync(k) {
            return Ok(None);
        }
        let net = clock.net_at(k);
        if let Some(d) = self.dasgd.as_mut() {
            if d.pending {
                // the previous average is still in flight (a restored
                // phase can collide): skip the trigger, don't stack
                return Ok(None);
            }
            d.snap.copy_from_slice(&node.w);
            d.mean.copy_from_slice(&node.w);
            let secs =
                ledger.record(&net, CommKind::ParamAvg, node.n, (node.w.len() * 4) as u64);
            comm.allreduce_mean(node.rank, &mut d.mean)?;
            let dev = crate::tensor::sq_deviation(&d.mean, &d.snap);
            let s_k = comm.allreduce_scalar_sum(node.rank, dev)? / node.n as f64;
            // overlap: no barrier — the collective completes at
            // (slowest launcher + transfer), and only the delivery waits
            d.deliver_at = k + d.delay;
            d.ready_at = clock.max() + secs;
            d.pending = true;
            ctrl.on_sync(k, s_k, lr);
            return Ok(Some(s_k));
        }
        node.w_pre.copy_from_slice(&node.w);
        let mut secs =
            ledger.record(&net, CommKind::ParamAvg, node.n, (node.w.len() * 4) as u64);
        comm.allreduce_mean(node.rank, &mut node.w)?;
        // S_k = (1/n) sum_i ||w_bar - w_i||^2  (Algorithm 2 line 11)
        let dev = crate::tensor::sq_deviation(&node.w, &node.w_pre);
        let s_k = comm.allreduce_scalar_sum(node.rank, dev)? / node.n as f64;
        if let Some(alpha) = self.elastic_alpha {
            // EASGD (paper [57]): α of the way toward the mean (α=1 is
            // exactly CPSGD and composes out of the pipeline entirely)
            crate::tensor::elastic_pull(&mut node.w, &node.w_pre, alpha);
        }
        if self.reset_momentum {
            node.m.fill(0.0);
        }
        if ctrl.wants_loss() {
            // AdaComm: agree the current loss so every replica derives
            // the same τ from the same number (priced like S_k)
            let loss =
                comm.allreduce_scalar_sum(node.rank, node.mean_local_loss())? / node.n as f64;
            secs += ledger.record(&net, CommKind::ScalarStat, node.n, 8);
            ctrl.observe_loss(loss);
        }
        if self.charge_scalar_stat {
            // the paper's extra scalar exchange (only ADPSGD pays it)
            secs += ledger.record(&net, CommKind::ScalarStat, node.n, 4);
        }
        // BSP sync: every node's modeled clock meets the slowest, then
        // pays the transfer — this is where stragglers hurt
        clock.barrier(secs);
        ctrl.on_sync(k, s_k, lr);
        Ok(Some(s_k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_for(strategy: Strategy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.sync.strategy = strategy;
        cfg
    }

    use crate::period::Strategy;

    #[test]
    fn mode_per_strategy() {
        for (s, mode) in [
            (Strategy::Full, ExchangeMode::Gradient),
            (Strategy::Qsgd, ExchangeMode::Gradient),
            (Strategy::TopK, ExchangeMode::Gradient),
            (Strategy::Constant, ExchangeMode::Parameters),
            (Strategy::Adaptive, ExchangeMode::Parameters),
            (Strategy::Easgd, ExchangeMode::Parameters),
            (Strategy::Piecewise, ExchangeMode::Parameters),
            (Strategy::Decreasing, ExchangeMode::Parameters),
            (Strategy::AdaComm, ExchangeMode::Parameters),
            (Strategy::PrSgd, ExchangeMode::Parameters),
            (Strategy::DaSgd, ExchangeMode::Parameters),
        ] {
            let step = SyncStep::build(&cfg_for(s), 64, 0, 0, None);
            assert_eq!(step.mode, mode, "{s}");
        }
    }

    #[test]
    fn stage_composition_per_strategy() {
        let full = SyncStep::build(&cfg_for(Strategy::Full), 64, 0, 0, None);
        assert!(full.transform.is_none() && full.controller.is_none());
        assert!(!full.charge_scalar_stat && full.elastic_alpha.is_none());

        let qsgd = SyncStep::build(&cfg_for(Strategy::Qsgd), 64, 0, 0, None);
        assert_eq!(qsgd.transform.as_ref().unwrap().kind(), CommKind::QuantAllgather);

        let topk = SyncStep::build(&cfg_for(Strategy::TopK), 64, 0, 0, None);
        assert_eq!(topk.transform.as_ref().unwrap().kind(), CommKind::SparsePs);

        let adp = SyncStep::build(&cfg_for(Strategy::Adaptive), 64, 0, 0, None);
        assert!(adp.charge_scalar_stat && adp.controller.is_some());

        let mut ecfg = cfg_for(Strategy::Easgd);
        ecfg.sync.easgd_alpha = 0.5;
        let easgd = SyncStep::build(&ecfg, 64, 0, 0, None);
        assert_eq!(easgd.elastic_alpha, Some(0.5));

        // α = 1 degenerates to CPSGD: the elastic stage composes away
        ecfg.sync.easgd_alpha = 1.0;
        let cpsgd_like = SyncStep::build(&ecfg, 64, 0, 0, None);
        assert_eq!(cpsgd_like.elastic_alpha, None);

        // the newcomers compose their own single extra stage each
        let prsgd = SyncStep::build(&cfg_for(Strategy::PrSgd), 64, 0, 0, None);
        assert!(prsgd.reset_momentum && prsgd.dasgd.is_none());
        let dasgd = SyncStep::build(&cfg_for(Strategy::DaSgd), 64, 0, 0, None);
        let d = dasgd.dasgd.as_ref().expect("dasgd carries delayed-apply state");
        assert_eq!(d.delay, ExperimentConfig::default().sync.dasgd_delay);
        assert_eq!(d.snap.len(), 64);
        assert!(!d.pending && !dasgd.reset_momentum);
        let cpsgd = SyncStep::build(&cfg_for(Strategy::Constant), 64, 0, 0, None);
        assert!(!cpsgd.reset_momentum && cpsgd.dasgd.is_none());
    }

    #[test]
    fn injected_controller_overrides_registry() {
        let step = SyncStep::build(
            &cfg_for(Strategy::Constant),
            64,
            0,
            0,
            Some(&|| {
                Box::new(crate::period::Constant::new(7)) as Box<dyn PeriodController>
            }),
        );
        assert_eq!(step.mode, ExchangeMode::Parameters);
        assert_eq!(step.current_period(), 7);
    }

    #[test]
    fn transforms_report_wire_bytes() {
        let mut q = QsgdTransform {
            cfg: QsgdConfig::default(),
            rng: Rng::new(1, 0),
            scratch: crate::quant::QsgdScratch::default(),
        };
        let mut g = vec![0.5f32; 4096];
        let wire = q.apply(&mut g);
        assert!(wire > 0 && wire < 4096 * 4, "compressed: {wire}");

        let mut t = TopKTransform {
            cfg: TopKConfig { keep_frac: 0.1 },
            res: Residual::new(4096),
        };
        let mut g = vec![0.5f32; 4096];
        let wire = t.apply(&mut g);
        assert_eq!(wire, TopKConfig { keep_frac: 0.1 }.wire_bytes(4096));
        assert_eq!(g.iter().filter(|v| **v != 0.0).count(), 410); // ceil(409.6)
    }
}
