//! Synthetic datasets + sharded batch sources.
//!
//! The paper trains on CIFAR-10 / ImageNet; offline we substitute
//! synthetic tasks that preserve the statistical behaviour the paper
//! measures (DESIGN.md §1): class-conditional Gaussian mixtures for
//! image classification, and a procedurally generated character corpus
//! for the end-to-end LM driver.  Every node samples from its own RNG
//! stream, which reproduces the paper's "globally shuffled each epoch"
//! i.i.d. regime while keeping runs exactly deterministic.

use crate::util::rng::Rng;

/// One mini-batch, already in the flat layouts the engines consume.
#[derive(Debug, Clone)]
pub enum Batch {
    /// x: `[batch * dim]` f32 row-major, y: `[batch]` class ids.
    Class { x: Vec<f32>, y: Vec<i32>, batch: usize, dim: usize },
    /// x/y: `[batch * seq]` token ids (y = x shifted by one).
    Lm { x: Vec<i32>, y: Vec<i32>, batch: usize, seq: usize },
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        match self {
            Batch::Class { batch, .. } | Batch::Lm { batch, .. } => *batch,
        }
    }
}

// ---------------------------------------------------------------------------
// synthetic classification
// ---------------------------------------------------------------------------

/// Class-conditional Gaussian mixture over `dim` features:
/// `x = mu_y + noise * N(0, I)`, with optional label noise.
///
/// `mu_c` entries are drawn N(0, 1) once from the dataset seed, so the
/// Bayes error is controlled by `noise` (higher = harder).  This gives
/// SGD the properties the paper's figures rely on: nonzero gradient
/// noise, a loss that decays over thousands of iterations, and a
/// generalization gap sensitive to batch size.
#[derive(Debug, Clone)]
pub struct SynthClass {
    pub dim: usize,
    pub classes: usize,
    pub noise: f32,
    pub label_noise: f32,
    means: Vec<f32>, // [classes * dim]
}

impl SynthClass {
    pub fn new(seed: u64, dim: usize, classes: usize, noise: f32, label_noise: f32) -> Self {
        let mut rng = Rng::new(seed, 0xDA7A);
        let mut means = vec![0.0f32; classes * dim];
        rng.fill_normal(&mut means, 1.0);
        SynthClass { dim, classes, noise, label_noise, means }
    }

    /// Sample a batch into a [`Batch::Class`]; `rng` is the caller's
    /// stream (per node, or per the eval set).
    pub fn sample(&self, rng: &mut Rng, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let mut c = rng.below(self.classes);
            let row = &mut x[b * self.dim..(b + 1) * self.dim];
            let mu = &self.means[c * self.dim..(c + 1) * self.dim];
            for (xi, mi) in row.iter_mut().zip(mu) {
                *xi = mi + rng.normal() * self.noise;
            }
            if self.label_noise > 0.0 && rng.f32() < self.label_noise {
                c = rng.below(self.classes);
            }
            y[b] = c as i32;
        }
        Batch::Class { x, y, batch, dim: self.dim }
    }
}

// ---------------------------------------------------------------------------
// procedurally generated character corpus (LM driver)
// ---------------------------------------------------------------------------

/// Deterministic pseudo-English corpus from a tiny phrase grammar.
/// Tokens are `byte - 32` (printable ASCII), vocab 96 — matching the
/// `txf_*` model presets.
#[derive(Debug, Clone)]
pub struct CharCorpus {
    pub text: Vec<u8>,
    pub vocab: usize,
}

const SUBJECTS: [&str; 8] = [
    "the worker", "each node", "the leader", "one replica", "the model",
    "the gradient", "this layer", "the optimizer",
];
const VERBS: [&str; 8] = [
    "averages", "updates", "computes", "sends", "reduces", "samples",
    "synchronizes", "anneals",
];
const OBJECTS: [&str; 8] = [
    "the parameters", "a minibatch", "the variance", "its momentum",
    "the learning rate", "a local step", "the period", "the loss",
];
const ADVERBS: [&str; 6] = ["quickly", "slowly", "periodically", "adaptively", "rarely", "often"];

impl CharCorpus {
    /// Generate about `target_len` bytes of text.
    pub fn generate(seed: u64, target_len: usize) -> Self {
        let mut rng = Rng::new(seed, 0xC0);
        let mut text = Vec::with_capacity(target_len + 64);
        while text.len() < target_len {
            let s = SUBJECTS[rng.below(SUBJECTS.len())];
            let v = VERBS[rng.below(VERBS.len())];
            let o = OBJECTS[rng.below(OBJECTS.len())];
            text.extend_from_slice(s.as_bytes());
            text.push(b' ');
            text.extend_from_slice(v.as_bytes());
            text.push(b' ');
            text.extend_from_slice(o.as_bytes());
            if rng.f32() < 0.5 {
                text.push(b' ');
                text.extend_from_slice(ADVERBS[rng.below(ADVERBS.len())].as_bytes());
            }
            text.extend_from_slice(b". ");
        }
        CharCorpus { text, vocab: 96 }
    }

    #[inline]
    fn tok(&self, i: usize) -> i32 {
        (self.text[i].saturating_sub(32) as i32).min(self.vocab as i32 - 1)
    }

    /// Sample `batch` windows of length `seq` (+1 shift target).
    pub fn sample(&self, rng: &mut Rng, batch: usize, seq: usize) -> Batch {
        assert!(self.text.len() > seq + 1, "corpus shorter than seq");
        let mut x = vec![0i32; batch * seq];
        let mut y = vec![0i32; batch * seq];
        for b in 0..batch {
            let start = rng.below(self.text.len() - seq - 1);
            for t in 0..seq {
                x[b * seq + t] = self.tok(start + t);
                y[b * seq + t] = self.tok(start + t + 1);
            }
        }
        Batch::Lm { x, y, batch, seq }
    }
}

// ---------------------------------------------------------------------------
// sharded batch source
// ---------------------------------------------------------------------------

/// A per-node stream over a dataset: owns the node's RNG stream so each
/// node sees an independent shard-equivalent sample sequence.
pub struct NodeSource {
    pub rng: Rng,
    pub dataset: DatasetHandle,
    pub batch: usize,
    pub seq: usize,
}

/// Shareable dataset handle (datasets are immutable after construction).
#[derive(Clone)]
pub enum DatasetHandle {
    Class(std::sync::Arc<SynthClass>),
    Text(std::sync::Arc<CharCorpus>),
}

impl NodeSource {
    pub fn new(dataset: DatasetHandle, seed: u64, node: u64, batch: usize, seq: usize) -> Self {
        NodeSource { rng: Rng::new(seed, 0xB000 + node), dataset, batch, seq }
    }

    pub fn next_batch(&mut self) -> Batch {
        match &self.dataset {
            DatasetHandle::Class(d) => d.sample(&mut self.rng, self.batch),
            DatasetHandle::Text(d) => d.sample(&mut self.rng, self.batch, self.seq),
        }
    }
}

/// Process-wide dataset cache.
///
/// Datasets are pure functions of their construction parameters and
/// immutable afterwards, so sweeps (campaigns, figure regenerations)
/// share one `Arc` per distinct parameter set instead of regenerating
/// the class means / corpus text for every run.  Sampling stays
/// per-node-RNG, so sharing changes no training bytes.
pub mod cache {
    use super::{CharCorpus, SynthClass};
    use crate::util::memo;
    use std::sync::{Arc, OnceLock};

    type SynthKey = (u64, usize, usize, u32, u32);

    pub fn synth_class(
        seed: u64,
        dim: usize,
        classes: usize,
        noise: f32,
        label_noise: f32,
    ) -> Arc<SynthClass> {
        static CACHE: memo::Cache<SynthKey, SynthClass> = OnceLock::new();
        let key = (seed, dim, classes, noise.to_bits(), label_noise.to_bits());
        memo::get_or_build(&CACHE, key, || {
            SynthClass::new(seed, dim, classes, noise, label_noise)
        })
    }

    pub fn char_corpus(seed: u64, target_len: usize) -> Arc<CharCorpus> {
        static CACHE: memo::Cache<(u64, usize), CharCorpus> = OnceLock::new();
        memo::get_or_build(&CACHE, (seed, target_len), || CharCorpus::generate(seed, target_len))
    }

    #[cfg(test)]
    mod tests {
        use std::sync::Arc;

        #[test]
        fn same_key_shares_one_dataset() {
            let a = super::synth_class(11, 8, 4, 1.0, 0.0);
            let b = super::synth_class(11, 8, 4, 1.0, 0.0);
            assert!(Arc::ptr_eq(&a, &b));
            let c = super::synth_class(12, 8, 4, 1.0, 0.0);
            assert!(!Arc::ptr_eq(&a, &c));
            let t1 = super::char_corpus(5, 1024);
            let t2 = super::char_corpus(5, 1024);
            assert!(Arc::ptr_eq(&t1, &t2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_class_shapes_and_determinism() {
        let d = SynthClass::new(1, 8, 4, 0.5, 0.0);
        let b1 = d.sample(&mut Rng::new(2, 0), 16);
        let b2 = d.sample(&mut Rng::new(2, 0), 16);
        match (&b1, &b2) {
            (Batch::Class { x: x1, y: y1, .. }, Batch::Class { x: x2, y: y2, .. }) => {
                assert_eq!(x1.len(), 16 * 8);
                assert_eq!(x1, x2);
                assert_eq!(y1, y2);
                assert!(y1.iter().all(|&c| (0..4).contains(&c)));
            }
            _ => panic!("wrong batch kind"),
        }
    }

    #[test]
    fn synth_class_is_learnable_signal() {
        // nearest-mean classification should beat chance easily at low noise
        let d = SynthClass::new(3, 16, 4, 0.3, 0.0);
        let Batch::Class { x, y, batch, dim } = d.sample(&mut Rng::new(9, 1), 256) else {
            panic!()
        };
        let mut correct = 0;
        for b in 0..batch {
            let row = &x[b * dim..(b + 1) * dim];
            let mut best = (f64::MAX, 0);
            for c in 0..4 {
                let mu = &d.means[c * dim..(c + 1) * dim];
                let dist = crate::tensor::sq_deviation(row, mu);
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == y[b] {
                correct += 1;
            }
        }
        assert!(correct > 240, "nearest-mean acc only {correct}/256");
    }

    #[test]
    fn label_noise_applied() {
        let d = SynthClass::new(1, 4, 2, 0.01, 0.5);
        let Batch::Class { x, y, batch, dim } = d.sample(&mut Rng::new(5, 2), 512) else {
            panic!()
        };
        // with 50% label noise, ~25% of labels disagree with the nearest mean
        let mut flipped = 0;
        for b in 0..batch {
            let row = &x[b * dim..(b + 1) * dim];
            let d0 = crate::tensor::sq_deviation(row, &d.means[0..dim]);
            let d1 = crate::tensor::sq_deviation(row, &d.means[dim..2 * dim]);
            let near = if d0 < d1 { 0 } else { 1 };
            if near != y[b] {
                flipped += 1;
            }
        }
        assert!(flipped > 64, "label noise not applied ({flipped}/512 flips)");
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let c = CharCorpus::generate(7, 4096);
        assert!(c.text.len() >= 4096);
        let Batch::Lm { x, y, batch, seq } = c.sample(&mut Rng::new(1, 1), 4, 32) else {
            panic!()
        };
        assert_eq!(x.len(), 4 * 32);
        assert!(x.iter().chain(&y).all(|&t| (0..96).contains(&t)));
        // y is x shifted by one within each row
        for b in 0..batch {
            for t in 0..seq - 1 {
                assert_eq!(y[b * seq + t], x[b * seq + t + 1]);
            }
        }
    }

    #[test]
    fn node_sources_are_independent_streams() {
        let d = DatasetHandle::Class(std::sync::Arc::new(SynthClass::new(1, 8, 4, 1.0, 0.0)));
        let mut a = NodeSource::new(d.clone(), 42, 0, 8, 0);
        let mut b = NodeSource::new(d, 42, 1, 8, 0);
        let (Batch::Class { x: xa, .. }, Batch::Class { x: xb, .. }) =
            (a.next_batch(), b.next_batch())
        else {
            panic!()
        };
        assert_ne!(xa, xb);
    }
}
