//! Capped exponential backoff with deterministic jitter and a bounded
//! retry budget — the fleet's redial schedule.
//!
//! The schedule doubles from [`Backoff::base`] up to [`Backoff::cap`];
//! each delay is then jittered into `[nominal/2, nominal]` by a
//! deterministic hash of `(salt, attempt)` so concurrent slot threads
//! redialing the same restarted agent fan out instead of stampeding,
//! while the schedule itself stays reproducible (no RNG, no global
//! state — the same salt always sleeps the same).  When
//! [`Backoff::budget`] attempts have all failed, [`Backoff::retry`]
//! gives up with the typed [`RetryBudgetExhausted`] error so callers
//! can distinguish "agent is really gone" from a transient dial error.

use anyhow::{bail, Result};
use std::time::Duration;

/// Typed give-up error: every attempt in the retry budget failed.
/// Downcastable through the `anyhow` chain, like
/// [`super::super::proto::VersionSkew`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryBudgetExhausted {
    /// How many attempts were made (== the configured budget).
    pub attempts: u32,
    /// What was being retried (an agent address, for diagnostics).
    pub what: String,
}

impl std::fmt::Display for RetryBudgetExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retry budget exhausted: {} failed {} consecutive attempts — giving up",
            self.what, self.attempts
        )
    }
}

impl std::error::Error for RetryBudgetExhausted {}

/// The redial schedule.  `Default` is tuned for an agent restart
/// mid-campaign: ~250ms first redial, doubling to an 8s cap, giving up
/// after 10 attempts (≈45s of patience end to end).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backoff {
    /// First (pre-jitter) delay.
    pub base: Duration,
    /// Largest (pre-jitter) delay; the doubling saturates here.
    pub cap: Duration,
    /// Maximum number of attempts before [`RetryBudgetExhausted`].
    pub budget: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff {
            base: Duration::from_millis(250),
            cap: Duration::from_secs(8),
            budget: 10,
        }
    }
}

impl Backoff {
    /// The jittered delay before attempt `attempt + 1` (i.e. the sleep
    /// *after* attempt `attempt` failed).  Nominal value is
    /// `base · 2^attempt` saturating at `cap`; jitter deterministically
    /// maps `(salt, attempt)` into `[nominal/2, nominal]`.
    pub fn delay(&self, attempt: u32, salt: &str) -> Duration {
        let nominal = self
            .base
            .checked_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
            .map_or(self.cap, |d| d.min(self.cap));
        // first 8 hex chars of the content digest → a uniform fraction
        let digest =
            super::super::runcache::content_digest(format!("{salt}#{attempt}").as_bytes());
        let x = u32::from_str_radix(&digest[..8], 16).unwrap_or(0);
        let frac = 0.5 + 0.5 * (x as f64 / u32::MAX as f64);
        nominal.mul_f64(frac)
    }

    /// Run `op` until it succeeds, sleeping the schedule between
    /// failures.  `still_wanted` is polled during the sleeps (in 50ms
    /// steps) so a retry loop stops promptly when the work it would
    /// reconnect for is already done or aborted; returning `false`
    /// fails the retry with a plain (non-budget) error.  After `budget`
    /// failures the typed [`RetryBudgetExhausted`] is returned, with
    /// the last underlying error in its context chain.
    pub fn retry<T>(
        &self,
        what: &str,
        still_wanted: impl Fn() -> bool,
        mut op: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..self.budget.max(1) {
            if !still_wanted() {
                bail!("retrying {what} abandoned: the work it would serve is gone");
            }
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    crate::obs::metrics().counter("fleet.backoff_attempts").inc();
                    last = Some(e);
                }
            }
            // sleep the schedule, but stay responsive to cancellation
            let mut left = self.delay(attempt, what);
            while !left.is_zero() {
                if !still_wanted() {
                    bail!("retrying {what} abandoned: the work it would serve is gone");
                }
                let step = left.min(Duration::from_millis(50));
                std::thread::sleep(step);
                left = left.saturating_sub(step);
            }
        }
        let exhausted = RetryBudgetExhausted {
            attempts: self.budget.max(1),
            what: what.to_string(),
        };
        Err(match last {
            Some(e) => anyhow::Error::new(exhausted).context(format!("last error: {e:#}")),
            None => anyhow::Error::new(exhausted),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn quick() -> Backoff {
        Backoff { base: Duration::from_millis(1), cap: Duration::from_millis(4), budget: 3 }
    }

    #[test]
    fn delays_double_to_the_cap_and_jitter_stays_in_bounds() {
        let b = Backoff {
            base: Duration::from_millis(100),
            cap: Duration::from_secs(2),
            budget: 10,
        };
        let mut prev_nominal = Duration::ZERO;
        for attempt in 0..16 {
            let nominal = b
                .base
                .checked_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
                .map_or(b.cap, |d| d.min(b.cap));
            assert!(nominal >= prev_nominal, "nominal schedule is monotone");
            assert!(nominal <= b.cap, "nominal schedule saturates at the cap");
            prev_nominal = nominal;
            for salt in ["10.0.0.1:7070", "10.0.0.2:7070", "x"] {
                let d = b.delay(attempt, salt);
                assert!(
                    d >= nominal.mul_f64(0.5) && d <= nominal,
                    "attempt {attempt} salt {salt}: {d:?} outside [{:?}, {nominal:?}]",
                    nominal.mul_f64(0.5),
                );
            }
        }
        // the shift-overflow region (attempt ≥ 32) still just returns the cap
        assert!(b.delay(40, "x") <= b.cap);
    }

    #[test]
    fn jitter_is_deterministic_per_salt_and_spreads_across_salts() {
        let b = Backoff::default();
        assert_eq!(b.delay(3, "agent-a"), b.delay(3, "agent-a"));
        // two agents redialing on the same schedule should not sleep in
        // lockstep on every attempt (that is the stampede jitter exists
        // to break)
        let differs = (0..8).any(|a| b.delay(a, "agent-a") != b.delay(a, "agent-b"));
        assert!(differs, "jitter must spread distinct salts apart");
    }

    #[test]
    fn retry_passes_success_through_and_counts_the_budget() {
        let calls = AtomicU32::new(0);
        let got = quick()
            .retry("t", || true, || {
                if calls.fetch_add(1, Ordering::Relaxed) < 2 {
                    bail!("transient")
                }
                Ok(42)
            })
            .unwrap();
        assert_eq!(got, 42);
        assert_eq!(calls.load(Ordering::Relaxed), 3, "succeeded on the last attempt");
    }

    #[test]
    fn retry_budget_exhaustion_is_the_typed_error() {
        let calls = AtomicU32::new(0);
        let err = quick()
            .retry::<()>("agent 10.0.0.9:7070", || true, || {
                calls.fetch_add(1, Ordering::Relaxed);
                bail!("connection refused")
            })
            .unwrap_err();
        assert_eq!(calls.load(Ordering::Relaxed), 3, "budget bounds the attempts");
        let typed = err
            .downcast_ref::<RetryBudgetExhausted>()
            .unwrap_or_else(|| panic!("not typed: {err:#}"));
        assert_eq!(typed.attempts, 3);
        assert!(typed.what.contains("10.0.0.9"), "{typed}");
        let msg = format!("{err:#}");
        assert!(msg.contains("retry budget exhausted"), "{msg}");
        assert!(msg.contains("connection refused"), "last cause must survive: {msg}");
    }

    #[test]
    fn retry_stops_promptly_when_no_longer_wanted() {
        let err = quick()
            .retry::<()>("t", || false, || bail!("unreachable"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("abandoned"), "{err:#}");
        assert!(err.downcast_ref::<RetryBudgetExhausted>().is_none());
    }
}
