//! Content-addressed artifact staging: ship warm-start snapshots (and
//! other driver-local files) to agents that do not hold them.
//!
//! The run cache already fingerprints a warm start by the *bytes* of
//! the resolved `init_from` snapshot ([`content_digest`]).  Staging
//! reuses that digest as the transfer key end to end:
//!
//! 1. The dispatcher builds a [`BlobCatalog`] over a campaign's runs —
//!    digest → local path for every resolvable `init_from` — and
//!    rewrites each remote-bound config's `init_from` to
//!    `blob:<digest>` ([`BlobCatalog::wire_cfg`]).
//! 2. The agent's cache probe understands the `blob:` scheme (the
//!    digest *is* the content hash, so the cache key is identical on
//!    both ends) — a warm agent answers without ever pulling the bytes.
//! 3. On a miss, the agent checks its [`BlobStore`]; if the digest is
//!    absent it sends a `BlobRequest` frame and the dispatcher answers
//!    with the bytes (binary on the TCP transport).  The store verifies
//!    the digest before trusting them, writes atomically
//!    (temp + rename, the run cache's convention), and rewrites the
//!    config to the staged path before executing.
//!
//! An HLO `manifest.json` can ride the same frames (the store is
//! digest-keyed, not snapshot-specific), but staging a *full* artifacts
//! directory is future work — see ROADMAP.

use super::super::runcache::content_digest;
use crate::config::ExperimentConfig;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// The wire scheme for a content-addressed `init_from` reference:
/// `blob:<digest>` where `<digest>` is the snapshot's
/// [`content_digest`].
pub const BLOB_SCHEME: &str = "blob:";

/// Orphaned temp files older than this are swept by [`BlobStore::gc`]
/// (same grace the run cache uses for its own temp files).
const TMP_GRACE: Duration = Duration::from_secs(900);

fn valid_digest(digest: &str) -> Result<()> {
    if digest.is_empty() || !digest.chars().all(|c| c.is_ascii_hexdigit()) {
        bail!("blob digest {digest:?} is not a hex content digest");
    }
    Ok(())
}

/// An agent-side store of pulled artifacts: one `<digest>.blob` file
/// per artifact under `<cache-dir>/blobs/`, digest-verified on write,
/// size-bounded by [`BlobStore::gc`] (oldest-first, like the run
/// cache).
pub struct BlobStore {
    dir: PathBuf,
}

impl BlobStore {
    /// A store rooted at `dir` (created lazily on first write).
    pub fn new(dir: impl Into<PathBuf>) -> BlobStore {
        BlobStore { dir: dir.into() }
    }

    /// The conventional store location under an agent's cache dir.
    pub fn under_cache(cache_dir: &Path) -> BlobStore {
        BlobStore::new(cache_dir.join("blobs"))
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Where `digest`'s bytes live (whether or not they are present).
    pub fn path_for(&self, digest: &str) -> PathBuf {
        self.dir.join(format!("{digest}.blob"))
    }

    /// The staged path for `digest`, if the bytes are already here.
    pub fn get(&self, digest: &str) -> Option<PathBuf> {
        valid_digest(digest).ok()?;
        let p = self.path_for(digest);
        p.is_file().then_some(p)
    }

    /// Store `bytes` under `digest`, verifying the content hash first —
    /// a peer that ships bytes not matching the digest it was asked for
    /// is answering the wrong question, and a poisoned store would
    /// corrupt every future run keyed on that digest.  Atomic
    /// (unique temp + rename), so concurrent pulls of the same digest
    /// race safely.
    pub fn put(&self, digest: &str, bytes: &[u8]) -> Result<PathBuf> {
        valid_digest(digest)?;
        let actual = content_digest(bytes);
        if actual != digest {
            bail!(
                "staged blob does not match its digest: expected {digest}, bytes hash to \
                 {actual} ({} bytes) — refusing to store",
                bytes.len()
            );
        }
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating blob store {}", self.dir.display()))?;
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            ".{digest}.{}.{}.tmp",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let path = self.path_for(digest);
        std::fs::write(&tmp, bytes)
            .with_context(|| format!("writing blob temp {}", tmp.display()))?;
        std::fs::rename(&tmp, &path).with_context(|| {
            std::fs::remove_file(&tmp).ok();
            format!("publishing blob {}", path.display())
        })?;
        Ok(path)
    }

    /// Bound the store to `max_bytes`, evicting oldest-modified blobs
    /// first and sweeping orphaned temp files past their grace period.
    /// Returns `(evicted_blobs, bytes_freed)`.  Eviction is always
    /// safe: an evicted digest is simply re-pulled on next use.
    pub fn gc(&self, max_bytes: u64) -> Result<(usize, u64)> {
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            // no store yet: nothing to bound
            Err(_) => return Ok((0, 0)),
        };
        let mut blobs: Vec<(PathBuf, u64, std::time::SystemTime)> = Vec::new();
        let mut freed = 0u64;
        let mut evicted = 0usize;
        for entry in entries.flatten() {
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            let meta = match entry.metadata() {
                Ok(m) if m.is_file() => m,
                _ => continue,
            };
            let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            if name.ends_with(".tmp") {
                let stale = mtime
                    .elapsed()
                    .map(|age| age > TMP_GRACE)
                    .unwrap_or(false);
                if stale && std::fs::remove_file(&path).is_ok() {
                    freed += meta.len();
                }
                continue;
            }
            if name.ends_with(".blob") {
                blobs.push((path, meta.len(), mtime));
            }
        }
        let mut total: u64 = blobs.iter().map(|(_, len, _)| len).sum();
        blobs.sort_by_key(|(_, _, mtime)| *mtime);
        for (path, len, _) in blobs {
            if total <= max_bytes {
                break;
            }
            if std::fs::remove_file(&path).is_ok() {
                total = total.saturating_sub(len);
                freed += len;
                evicted += 1;
            }
        }
        Ok((evicted, freed))
    }
}

/// The dispatcher's side of staging: digest → local path for every
/// artifact a campaign's runs reference, plus the `init_from` →
/// `blob:<digest>` rewrite applied to remote-bound configs.
#[derive(Debug, Default)]
pub struct BlobCatalog {
    by_digest: HashMap<String, PathBuf>,
    // original `init_from` string → digest, for the wire rewrite
    by_source: HashMap<String, String>,
}

impl BlobCatalog {
    /// A catalog with nothing staged (local-only dispatch).
    pub fn empty() -> BlobCatalog {
        BlobCatalog::default()
    }

    /// True when no run references a stageable artifact.
    pub fn is_empty(&self) -> bool {
        self.by_digest.is_empty()
    }

    /// Number of distinct artifacts catalogued.
    pub fn len(&self) -> usize {
        self.by_digest.len()
    }

    /// Build the catalog over a set of run configs: resolve each
    /// non-empty `init_from` (a directory resolves to its latest
    /// checkpoint, exactly as the run-cache digest does), hash the
    /// bytes, and record digest → path.  An unresolvable reference is
    /// left alone — the run keeps its original path and fails (locally
    /// or remotely) with its own actionable error, unchanged from the
    /// pre-fleet behavior.
    pub fn for_runs<'a>(cfgs: impl IntoIterator<Item = &'a ExperimentConfig>) -> BlobCatalog {
        let mut catalog = BlobCatalog::default();
        for cfg in cfgs {
            let source = cfg.init_from.trim();
            if source.is_empty()
                || source.starts_with(BLOB_SCHEME)
                || catalog.by_source.contains_key(source)
            {
                continue;
            }
            let p = Path::new(source);
            let resolved = if p.is_dir() {
                crate::checkpoint::Checkpoint::latest(p).ok().flatten()
            } else {
                Some(p.to_path_buf())
            };
            if let Some((file, bytes)) =
                resolved.and_then(|f| std::fs::read(&f).ok().map(|b| (f, b)))
            {
                let digest = content_digest(&bytes);
                catalog.by_digest.insert(digest.clone(), file);
                catalog.by_source.insert(source.to_string(), digest);
            }
        }
        catalog
    }

    /// The remote-bound form of `cfg`: `init_from` rewritten to
    /// `blob:<digest>` when the catalog staged it.  Local execution
    /// keeps the original config — only the wire copy is rewritten.
    pub fn wire_cfg(&self, cfg: &ExperimentConfig) -> ExperimentConfig {
        match self.by_source.get(cfg.init_from.trim()) {
            Some(digest) => {
                let mut wire = cfg.clone();
                wire.init_from = format!("{BLOB_SCHEME}{digest}");
                wire
            }
            None => cfg.clone(),
        }
    }

    /// The local path holding `digest`'s bytes, if catalogued.
    pub fn resolve(&self, digest: &str) -> Option<&Path> {
        self.by_digest.get(digest).map(PathBuf::as_path)
    }

    /// Read `digest`'s bytes for a `BlobRequest` answer, re-verifying
    /// the content hash — if the file changed since the catalog was
    /// built, shipping it would poison the agent's digest-keyed store.
    pub fn read(&self, digest: &str) -> Result<Vec<u8>> {
        let path = self
            .resolve(digest)
            .ok_or_else(|| anyhow!("blob {digest} is not in this dispatch's catalog"))?;
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading staged artifact {}", path.display()))?;
        let actual = content_digest(&bytes);
        if actual != digest {
            bail!(
                "staged artifact {} changed on disk since the catalog was built \
                 (expected {digest}, now {actual})",
                path.display()
            );
        }
        Ok(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "adpsgd_fleet_blobs_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn store_roundtrips_and_refuses_mismatched_bytes() {
        let dir = tmpdir("store");
        let store = BlobStore::new(dir.join("blobs"));
        let bytes = b"snapshot payload".to_vec();
        let digest = content_digest(&bytes);

        assert!(store.get(&digest).is_none(), "empty store has nothing");
        let path = store.put(&digest, &bytes).unwrap();
        assert_eq!(store.get(&digest), Some(path.clone()));
        assert_eq!(std::fs::read(&path).unwrap(), bytes);

        // wrong bytes for the digest: refused, store unpoisoned
        let err = store.put(&digest, b"tampered").unwrap_err();
        assert!(format!("{err:#}").contains("does not match"), "{err:#}");
        assert_eq!(std::fs::read(&path).unwrap(), bytes, "original entry untouched");

        // a non-hex digest is rejected before touching the filesystem
        assert!(store.put("../escape", &bytes).is_err());
        assert!(store.get("../escape").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_gc_bounds_oldest_first() {
        let dir = tmpdir("gc");
        let store = BlobStore::new(dir.join("blobs"));
        let mut digests = Vec::new();
        for i in 0..4u8 {
            let bytes = vec![i; 1000];
            let digest = content_digest(&bytes);
            store.put(&digest, &bytes).unwrap();
            digests.push(digest);
            // spread mtimes so oldest-first eviction order is well-defined
            std::thread::sleep(Duration::from_millis(30));
        }
        // bound to ~2.5 entries: the two oldest must go
        let (evicted, freed) = store.gc(2500).unwrap();
        assert_eq!(evicted, 2, "two oldest blobs evicted");
        assert_eq!(freed, 2000);
        assert!(store.get(&digests[0]).is_none());
        assert!(store.get(&digests[1]).is_none());
        assert!(store.get(&digests[2]).is_some());
        assert!(store.get(&digests[3]).is_some());
        // already under the bound: a second pass is a no-op
        assert_eq!(store.gc(2500).unwrap(), (0, 0));
        // gc of a store that never existed is a quiet no-op too
        assert_eq!(BlobStore::new(dir.join("nope")).gc(0).unwrap(), (0, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn catalog_rewrites_init_from_and_preserves_the_cache_key() {
        let dir = tmpdir("catalog");
        let snap = dir.join("warm.adpk");
        Checkpoint::new(5, 0.0, vec![0.5; 8]).save(&snap).unwrap();

        let mut cfg = ExperimentConfig::default();
        cfg.name = "blob_catalog".into();
        cfg.init_from = snap.to_str().unwrap().into();
        let mut plain = ExperimentConfig::default();
        plain.name = "no_warm_start".into();

        let catalog = BlobCatalog::for_runs([&cfg, &plain]);
        assert_eq!(catalog.len(), 1, "only the warm start is stageable");

        let wire = catalog.wire_cfg(&cfg);
        assert!(wire.init_from.starts_with(BLOB_SCHEME), "{}", wire.init_from);
        let digest = wire.init_from.strip_prefix(BLOB_SCHEME).unwrap();

        // the key property: the wire form and the local form hash to
        // the same cache key, so driver and agent agree on hits
        use super::super::super::runcache::cfg_digest;
        assert_eq!(cfg_digest(&cfg).unwrap(), cfg_digest(&wire).unwrap());

        // the catalog serves the exact snapshot bytes back
        assert_eq!(catalog.read(digest).unwrap(), std::fs::read(&snap).unwrap());
        assert!(catalog.resolve(digest).is_some());
        assert!(catalog.read("00ff00ff").is_err(), "uncatalogued digest is an error");

        // a config without a warm start passes through untouched
        let untouched = catalog.wire_cfg(&plain);
        assert!(untouched.init_from.is_empty());

        // editing the file after cataloguing is caught at read time
        std::fs::write(&snap, b"changed").unwrap();
        let err = catalog.read(digest).unwrap_err();
        assert!(format!("{err:#}").contains("changed on disk"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
