//! Elastic agent fleet: discovery, reconnection, and artifact staging
//! on top of the [`super::net`] remote fabric.
//!
//! The PR-5 fabric assumed a static, trusted, always-up world — a fixed
//! `--remote host:port` list, plaintext token auth, no rejoin after an
//! agent restart, and warm-start snapshots that only exist on the
//! driver.  This module turns it into a cluster substrate:
//!
//! * **[`registry`]** — a lightweight membership endpoint (`adpsgd
//!   registry --listen ADDR`).  Agents announce themselves with their
//!   capacity under a liveness lease and re-announce on a cadence; the
//!   dispatcher resolves membership from the registry (`--fleet ADDR`,
//!   alongside any static `--remote` list) and adds slot threads as
//!   members join — mid-campaign joins pick up queued runs, expired
//!   leases stop attracting new work.
//! * **[`backoff`]** — the redial schedule.  A dropped or restarted
//!   agent is redialed under capped exponential backoff with
//!   deterministic jitter and a bounded retry budget
//!   ([`backoff::RetryBudgetExhausted`] is the typed give-up error).
//!   Completed runs are never redriven on rejoin (the
//!   [`super::RunCache`] memoizes them); in-flight ones requeue through
//!   the normal crashed-run path.
//! * **[`blobs`]** — content-addressed artifact staging.  A warm-start
//!   snapshot is shipped on the wire as `blob:<digest>` (the digest the
//!   run-cache key already hashes), so an agent can probe its cache
//!   *before* holding the bytes, and on a miss pull them with a
//!   [`super::proto::Frame::BlobRequest`] answered by the dispatcher's
//!   [`blobs::BlobCatalog`].  Pulled bytes land in the agent's
//!   digest-verified [`blobs::BlobStore`], reusing the run cache's
//!   directory and GC conventions.
//!
//! Authentication is challenge-response ([`super::proto::auth_proof`]):
//! the agent opens every connection with a nonce challenge and the
//! client answers with a keyed digest — the shared token never travels
//! the wire in either direction.  Mid-run cancellation
//! ([`super::proto::Frame::Cancel`]) lets the dispatcher kill an
//! orphaned run inside an agent's worker child instead of letting it
//! silently train to completion.  TLS on the wire remains future work.

pub mod backoff;
pub mod blobs;
pub mod registry;

pub use backoff::{Backoff, RetryBudgetExhausted};
pub use blobs::{BlobCatalog, BlobStore};
pub use registry::{Member, Registry};

use anyhow::{bail, Result};

/// Validate a list of agent endpoints (`--remote`) at parse time:
/// empty/whitespace entries and duplicate addresses are configuration
/// errors and should fail with a clear message up front, not deep in
/// the dial loop.
pub fn validate_endpoints(endpoints: &[String]) -> Result<()> {
    let mut seen = std::collections::BTreeSet::new();
    for (i, raw) in endpoints.iter().enumerate() {
        let addr = raw.trim();
        if addr.is_empty() {
            bail!(
                "--remote entry {} is empty — expected a comma-separated list of \
                 host:port agent endpoints",
                i + 1
            );
        }
        if addr.split_whitespace().count() > 1 {
            bail!(
                "--remote entry {} ({addr:?}) contains whitespace — expected one \
                 host:port endpoint per comma-separated entry",
                i + 1
            );
        }
        if !seen.insert(addr.to_string()) {
            bail!(
                "--remote lists agent {addr:?} more than once — duplicate endpoints \
                 would double-count its slots; list each agent exactly once"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(list: &[&str]) -> Result<()> {
        validate_endpoints(&list.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn endpoint_validation_accepts_sane_lists() {
        v(&[]).unwrap();
        v(&["127.0.0.1:7070"]).unwrap();
        v(&["a:1", "b:2", "c:3"]).unwrap();
        // surrounding whitespace is tolerated (the CLI trims), inner is not
        v(&[" a:1 ", "b:2"]).unwrap();
    }

    #[test]
    fn endpoint_validation_rejects_empty_whitespace_and_duplicates() {
        let e = v(&["a:1", ""]).unwrap_err().to_string();
        assert!(e.contains("entry 2") && e.contains("empty"), "{e}");
        let e = v(&["   "]).unwrap_err().to_string();
        assert!(e.contains("empty"), "{e}");
        let e = v(&["host one:1"]).unwrap_err().to_string();
        assert!(e.contains("whitespace"), "{e}");
        let e = v(&["a:1", "b:2", "a:1"]).unwrap_err().to_string();
        assert!(e.contains("more than once") && e.contains("a:1"), "{e}");
        // duplicates are detected on the trimmed form
        let e = v(&["a:1", " a:1"]).unwrap_err().to_string();
        assert!(e.contains("more than once"), "{e}");
    }
}
