//! Fleet membership: a lightweight TCP registry where agents announce
//! themselves under a liveness lease and the dispatcher resolves the
//! current member set.
//!
//! The protocol is deliberately tiny — one JSON line in, one JSON line
//! out, one request per connection — and versioned with the same
//! [`PROTO_VERSION`] header (and typed [`VersionSkew`] rejection) as
//! the run protocol:
//!
//! * agent → registry: `{"type":"announce","addr":A,"slots":S,
//!   "ttl_ms":T,"v":V}` — upserts the member under a lease expiring
//!   `ttl_ms` from now; answered with `{"type":"ok","members":N}`.
//!   Agents re-announce every `ttl/3` (see the agent's announce loop),
//!   so a crashed agent silently ages out.
//! * dispatcher → registry: `{"type":"list","v":V}` — answered with
//!   `{"type":"members","agents":[{"addr":A,"slots":S,"lease_ms":L},…]}`
//!   holding every unexpired member, sorted by address for determinism;
//!   `lease_ms` is the time remaining on the member's lease (what
//!   `adpsgd status` renders as the lease age).
//!
//! The registry holds no secrets and schedules nothing: it is a
//! phonebook, not a broker.  Authentication happens end-to-end between
//! dispatcher and agent (the challenge-response handshake), so a stale
//! or malicious registry entry can waste a dial attempt but never
//! impersonate an agent that holds no token.

use super::super::proto::{VersionSkew, PROTO_VERSION};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-connection I/O deadline: a registry exchange is one short line
/// each way, so anything slower than this is a wedged peer.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One live fleet member, as resolved from the registry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Member {
    /// The agent's dialable `host:port` endpoint.
    pub addr: String,
    /// Advertised concurrent-run capacity.
    pub slots: u32,
    /// Milliseconds remaining on the liveness lease at list time (0
    /// from registries that predate the field).
    pub lease_ms: u64,
}

/// The registry daemon (`adpsgd registry --listen ADDR`).
pub struct Registry {
    listener: TcpListener,
    members: Arc<Mutex<HashMap<String, (u32, Instant)>>>,
}

impl Registry {
    /// Bind the listening socket (port 0 picks a free port; the bound
    /// address is printed by [`Registry::serve`] and queryable here).
    pub fn bind(listen: &str) -> Result<Registry> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("registry: binding {listen}"))?;
        Ok(Registry { listener, members: Arc::new(Mutex::new(HashMap::new())) })
    }

    /// The bound listening address.
    pub fn addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("registry: local_addr")
    }

    /// Accept loop: one thread per connection, one request per
    /// connection.  Runs until the process exits.
    pub fn serve(self) -> Result<()> {
        let addr = self.addr()?;
        println!("registry: listening on {addr}");
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("registry: accept failed: {e}");
                    continue;
                }
            };
            let members = Arc::clone(&self.members);
            std::thread::spawn(move || {
                if let Err(e) = handle(&members, stream) {
                    eprintln!("registry: request failed: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Bind and serve on a background thread, returning the bound
    /// address (tests, benches, and the agent's self-registry mode).
    pub fn spawn(listen: &str) -> Result<SocketAddr> {
        let registry = Registry::bind(listen)?;
        let addr = registry.addr()?;
        std::thread::spawn(move || {
            if let Err(e) = registry.serve() {
                eprintln!("registry: serve failed: {e:#}");
            }
        });
        Ok(addr)
    }
}

/// Drop expired leases, logging each member that ages out.
fn prune(members: &mut HashMap<String, (u32, Instant)>) {
    let now = Instant::now();
    members.retain(|addr, (_, expiry)| {
        let live = *expiry > now;
        if !live {
            println!("registry: {addr} lease expired");
        }
        live
    });
}

fn handle(members: &Mutex<HashMap<String, (u32, Instant)>>, stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut reader = BufReader::new(stream.try_clone().context("registry: clone stream")?);
    let mut line = String::new();
    reader.read_line(&mut line).with_context(|| format!("registry: reading from {peer}"))?;
    let reply = match request(members, &line) {
        Ok(json) => json,
        Err(e) => Json::obj(vec![
            ("type", Json::str("error")),
            ("message", Json::str(format!("{e:#}"))),
            ("v", Json::num(PROTO_VERSION as f64)),
        ]),
    };
    let mut stream = stream;
    stream
        .write_all(format!("{}\n", reply.to_string_compact()).as_bytes())
        .with_context(|| format!("registry: answering {peer}"))?;
    Ok(())
}

fn request(
    members: &Mutex<HashMap<String, (u32, Instant)>>,
    line: &str,
) -> Result<Json> {
    let v = Json::parse(line.trim()).map_err(|e| anyhow!("registry request: {e}"))?;
    match v.get("v").and_then(Json::as_f64) {
        Some(x) if x as u64 == PROTO_VERSION => {}
        got => return Err(anyhow::Error::new(VersionSkew { got: got.map(|x| x as u64) })),
    }
    let version = ("v", Json::num(PROTO_VERSION as f64));
    match v.get("type").and_then(Json::as_str) {
        Some("announce") => {
            let addr = v
                .get("addr")
                .and_then(Json::as_str)
                .filter(|a| !a.trim().is_empty())
                .ok_or_else(|| anyhow!("announce: missing \"addr\""))?
                .trim()
                .to_string();
            let slots = v.get("slots").and_then(Json::as_f64).unwrap_or(1.0).max(1.0) as u32;
            let ttl_ms = v.get("ttl_ms").and_then(Json::as_f64).unwrap_or(15_000.0);
            let ttl = Duration::from_millis(ttl_ms.clamp(100.0, 3_600_000.0) as u64);
            let mut m = members.lock().expect("registry members lock");
            prune(&mut m);
            if m.insert(addr.clone(), (slots, Instant::now() + ttl)).is_none() {
                println!("registry: {addr} joined ({slots} slots, lease {ttl:?})");
            }
            let n = m.len();
            Ok(Json::obj(vec![
                ("type", Json::str("ok")),
                ("members", Json::num(n as f64)),
                version,
            ]))
        }
        Some("list") => {
            let mut m = members.lock().expect("registry members lock");
            prune(&mut m);
            let now = Instant::now();
            let mut agents: Vec<(String, u32, u64)> = m
                .iter()
                .map(|(a, (s, expiry))| {
                    let lease_ms = expiry.saturating_duration_since(now).as_millis() as u64;
                    (a.clone(), *s, lease_ms)
                })
                .collect();
            agents.sort();
            Ok(Json::obj(vec![
                ("type", Json::str("members")),
                (
                    "agents",
                    Json::Arr(
                        agents
                            .into_iter()
                            .map(|(addr, slots, lease_ms)| {
                                Json::obj(vec![
                                    ("addr", Json::str(addr)),
                                    ("slots", Json::num(slots as f64)),
                                    ("lease_ms", Json::num(lease_ms as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                version,
            ]))
        }
        Some(other) => bail!("registry request: unknown type {other:?}"),
        None => bail!("registry request: missing \"type\""),
    }
}

/// One round trip: connect, send a line, read the answer.
fn exchange(registry: &str, request: Json) -> Result<Json> {
    let stream = TcpStream::connect(registry)
        .with_context(|| format!("connecting to registry {registry}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut writer = stream.try_clone().context("registry: clone stream")?;
    writer
        .write_all(format!("{}\n", request.to_string_compact()).as_bytes())
        .with_context(|| format!("writing to registry {registry}"))?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .with_context(|| format!("reading from registry {registry}"))?;
    if line.trim().is_empty() {
        bail!("registry {registry} closed the connection without answering");
    }
    let v = Json::parse(line.trim())
        .map_err(|e| anyhow!("registry {registry} answer: {e}"))?;
    match v.get("v").and_then(Json::as_f64) {
        Some(x) if x as u64 == PROTO_VERSION => {}
        got => return Err(anyhow::Error::new(VersionSkew { got: got.map(|x| x as u64) })),
    }
    if v.get("type").and_then(Json::as_str) == Some("error") {
        bail!(
            "registry {registry} rejected the request: {}",
            v.get("message").and_then(Json::as_str).unwrap_or("<no message>")
        );
    }
    Ok(v)
}

/// Announce an agent to the registry: upsert `agent_addr` with `slots`
/// capacity under a lease of `ttl`.  Called from the agent's announce
/// loop every `ttl/3`.
pub fn announce(registry: &str, agent_addr: &str, slots: u32, ttl: Duration) -> Result<()> {
    exchange(
        registry,
        Json::obj(vec![
            ("type", Json::str("announce")),
            ("addr", Json::str(agent_addr)),
            ("slots", Json::num(slots as f64)),
            ("ttl_ms", Json::num(ttl.as_millis() as f64)),
            ("v", Json::num(PROTO_VERSION as f64)),
        ]),
    )?;
    Ok(())
}

/// Resolve the current member set (unexpired leases only, sorted by
/// address).  Called from the dispatcher's membership poll.
pub fn members(registry: &str) -> Result<Vec<Member>> {
    let v = exchange(
        registry,
        Json::obj(vec![("type", Json::str("list")), ("v", Json::num(PROTO_VERSION as f64))]),
    )?;
    let agents = match v.get("agents").and_then(Json::as_arr) {
        Some(items) => items,
        None => bail!("registry {registry}: malformed members answer (no \"agents\" array)"),
    };
    agents
        .iter()
        .map(|a| {
            let addr = a
                .get("addr")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("registry member without \"addr\""))?
                .to_string();
            let slots = a.get("slots").and_then(Json::as_f64).unwrap_or(1.0).max(1.0) as u32;
            let lease_ms = a.get("lease_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            Ok(Member { addr, slots, lease_ms })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn announce_list_and_lease_expiry() {
        let addr = Registry::spawn("127.0.0.1:0").unwrap().to_string();
        assert!(members(&addr).unwrap().is_empty(), "fresh registry has no members");

        announce(&addr, "10.0.0.1:7070", 4, Duration::from_secs(30)).unwrap();
        announce(&addr, "10.0.0.2:7070", 2, Duration::from_millis(150)).unwrap();
        let m = members(&addr).unwrap();
        assert_eq!(
            m.iter().map(|x| (x.addr.as_str(), x.slots)).collect::<Vec<_>>(),
            vec![("10.0.0.1:7070", 4), ("10.0.0.2:7070", 2)],
            "members are sorted by address"
        );
        // the remaining lease rides the list reply (lease_ms is
        // time-dependent, so bound it instead of pinning it)
        assert!(m[0].lease_ms > 20_000 && m[0].lease_ms <= 30_000, "{:?}", m[0]);
        assert!(m[1].lease_ms <= 150, "{:?}", m[1]);

        // re-announcing refreshes in place, never duplicates
        announce(&addr, "10.0.0.1:7070", 6, Duration::from_secs(30)).unwrap();
        let m = members(&addr).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!((m[0].addr.as_str(), m[0].slots), ("10.0.0.1:7070", 6));

        // the short lease ages out; the long one survives
        std::thread::sleep(Duration::from_millis(300));
        let m = members(&addr).unwrap();
        assert_eq!(m.len(), 1, "expired lease must be pruned: {m:?}");
        assert_eq!(m[0].addr, "10.0.0.1:7070");
    }

    #[test]
    fn malformed_and_version_skewed_requests_are_rejected_clearly() {
        let addr = Registry::spawn("127.0.0.1:0").unwrap().to_string();

        // a bad request is answered with a typed error line, and the
        // registry keeps serving afterwards
        let err = exchange(
            &addr,
            Json::obj(vec![("type", Json::str("warp")), ("v", Json::num(PROTO_VERSION as f64))]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown type"), "{err:#}");

        // an unversioned peer gets the skew diagnosis end to end
        let err = exchange(&addr, Json::obj(vec![("type", Json::str("list"))])).unwrap_err();
        assert!(format!("{err:#}").contains("version skew"), "{err:#}");

        // announcing without an address is rejected, not stored
        let err = announce(&addr, "   ", 1, Duration::from_secs(1)).unwrap_err();
        assert!(format!("{err:#}").contains("addr"), "{err:#}");
        assert!(members(&addr).unwrap().is_empty());

        // and a normal request still works after all that
        announce(&addr, "10.0.0.3:7070", 1, Duration::from_secs(5)).unwrap();
        assert_eq!(members(&addr).unwrap().len(), 1);
    }

    #[test]
    fn unreachable_registry_is_a_clear_connect_error() {
        // bind-then-drop to find a port that is very likely closed
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = members(&format!("127.0.0.1:{port}")).unwrap_err();
        assert!(format!("{err:#}").contains("connecting to registry"), "{err:#}");
    }
}
