//! The dispatch layer: how many runs become one result set.
//!
//! [`crate::experiment::Campaign`] describes *what* to run; this
//! subsystem decides *how*: which runs are already answered by the
//! persistent content-addressed [`runcache`], how many execute
//! concurrently, whether they execute on in-process threads, in
//! `adpsgd worker` subprocesses speaking the [`proto`] line protocol,
//! or on remote `adpsgd agent` daemons over the [`net`] TCP transport
//! (mixed local+remote slots drain one queue), and how crashed or
//! *hung* workers — including silent or disconnected agents — are
//! recovered: all behind [`pool::Dispatcher`], which merges results
//! deterministically in declaration order no matter the parallelism,
//! worker mix, or completion order.
//!
//! Supervision (see [`pool`]): subprocess reads are deadline-aware, so
//! a child that stops heartbeating ([`proto::HEARTBEAT_EVERY`]) for
//! [`pool::DispatchOptions::heartbeat_timeout`] is declared hung,
//! killed, and its run retried on another slot; children live in the
//! process-wide [`shared_worker_pool`] and are reused warm across
//! sequential campaigns, with graceful shutdown (stdin EOF → bounded
//! wait → kill); the cache probe runs on the pool's own threads; and
//! [`runcache::RunCache::gc`] bounds long-lived cache directories.
//!
//! The remote side is elastic (see [`fleet`]): agents announce
//! themselves to a registry (`--fleet ADDR`) and the dispatcher adds
//! slot threads as members join mid-campaign; a dropped agent is
//! redialed under capped exponential backoff with jitter
//! ([`fleet::Backoff`]); warm-start snapshots the agent lacks are
//! pulled content-addressed over `BlobRequest`/`Blob` frames
//! ([`fleet::blobs`]); sessions authenticate by challenge-response
//! ([`proto::auth_proof`] — the shared token never travels the wire);
//! and an orphaned in-flight run is killed with a `cancel` frame
//! instead of silently training to completion.
//!
//! Layering: `experiment` (describe) → `dispatch` (schedule, memoize,
//! transport) → `coordinator` (execute one run).  The coordinator knows
//! nothing about caching or subprocesses; campaigns know nothing about
//! queues or retries.
//!
//! ## The run cache in one paragraph
//!
//! Every fully-resolved run config has a canonical text
//! ([`crate::config::ExperimentConfig::to_doc`]); the digest of its
//! result-affecting subset (plus content digests of any warm-start
//! snapshot and HLO manifest) keys a directory of serialized
//! [`crate::coordinator::RunReport`]s.  Re-running a campaign, resuming
//! an aborted sweep, or sharing runs across the `figures/*` campaigns
//! then skips completed work entirely — a hit is bit-identical to the
//! original report, and any result-affecting knob change busts the key
//! by construction.  See [`runcache`] for the exact hashed/not-hashed
//! policy.
//!
//! ## Process-default cache
//!
//! Campaigns executed through [`crate::experiment::Campaign::run`]
//! consult the process-wide default cache directory: unset by default,
//! taken from `$ADPSGD_RUN_CACHE` when present, and settable by
//! launchers ([`set_default_cache_dir`]) — which is how `adpsgd figures
//! --cache-dir` gives all six figure campaigns memoization without
//! touching their definitions.

pub mod fleet;
pub mod net;
pub mod pool;
pub mod proto;
pub mod runcache;

pub use fleet::{Backoff, BlobCatalog, BlobStore, Registry, RetryBudgetExhausted};
pub use net::{Agent, AgentConfig, RemoteAgentClient};
pub use pool::{DispatchOptions, DispatchedRun, Dispatcher, WorkerKind, WorkerPool};
pub use runcache::{cfg_digest, GcPlan, GcPolicy, GcStats, GcVictim, RunCache};

use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

/// The process-wide shared [`WorkerPool`]: every [`Dispatcher::new`]
/// borrows it, so sequential campaigns (and all six `adpsgd figures`
/// sweeps) reuse warm `adpsgd worker` children instead of paying a
/// respawn per campaign.  Tests and benchmarks that need isolation use
/// [`Dispatcher::with_pool`] with a private pool instead.
pub fn shared_worker_pool() -> Arc<WorkerPool> {
    static POOL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
    Arc::clone(POOL.get_or_init(|| Arc::new(WorkerPool::new())))
}

fn default_cache_cell() -> &'static Mutex<Option<PathBuf>> {
    static CELL: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    CELL.get_or_init(|| Mutex::new(std::env::var_os("ADPSGD_RUN_CACHE").map(PathBuf::from)))
}

/// The process-wide default run-cache directory (used by
/// [`DispatchOptions::default`]): `$ADPSGD_RUN_CACHE` unless a launcher
/// overrode it.  `None` disables caching by default.
pub fn default_cache_dir() -> Option<PathBuf> {
    default_cache_cell().lock().expect("default cache cell").clone()
}

/// Override the process-default run-cache directory (`None` disables).
/// Launchers call this once before building campaigns.
pub fn set_default_cache_dir(dir: Option<PathBuf>) {
    *default_cache_cell().lock().expect("default cache cell") = dir;
}

fn default_options_cell() -> &'static Mutex<Option<DispatchOptions>> {
    static CELL: OnceLock<Mutex<Option<DispatchOptions>>> = OnceLock::new();
    CELL.get_or_init(|| Mutex::new(None))
}

/// The process-wide default dispatch profile, used by
/// [`crate::experiment::Campaign::run`] (the implicit-profile entry
/// point every `figures/*` campaign goes through).  Unset by default —
/// then `run()` behaves exactly as before: thread workers, the
/// campaign's own parallelism, the process-default cache dir.  A
/// launcher that sets it (`adpsgd figures --jobs/--workers/--remote/…`)
/// gives every implicit campaign the full pool/supervision/remote
/// treatment without touching campaign definitions.
pub fn default_options() -> DispatchOptions {
    default_options_cell()
        .lock()
        .expect("default options cell")
        .clone()
        .unwrap_or_default()
}

/// Install (or with `None` clear) the process-default dispatch profile.
/// Launchers call this once before building campaigns.
pub fn set_default_options(opts: Option<DispatchOptions>) {
    *default_options_cell().lock().expect("default options cell") = opts;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cache_dir_is_settable() {
        // restore whatever was there (the environment may set it, and
        // concurrent tests read it through DispatchOptions::default)
        let prev = default_cache_dir();
        set_default_cache_dir(Some(PathBuf::from("/tmp/adpsgd_cache_test")));
        assert_eq!(default_cache_dir(), Some(PathBuf::from("/tmp/adpsgd_cache_test")));
        set_default_cache_dir(prev.clone());
        assert_eq!(default_cache_dir(), prev);
    }
}
