//! The `adpsgd agent` daemon: remote run capacity behind one TCP port.
//!
//! An agent accepts dispatcher connections, authenticates each with the
//! `Hello`/`HelloAck` handshake (protocol version — enforced by frame
//! parsing — plus an optional shared-secret token), advertises its slot
//! capacity, and then serves [`Frame::RunRequest`]s concurrently:
//! every request gets its own handler thread (at most `slots` in
//! flight per connection — requests past the advertised capacity are
//! refused with an `Error` frame — with execution additionally bounded
//! by a process-wide slot semaphore, so several connections cannot
//! oversubscribe the machine), its own heartbeat pump (armed from the
//! moment the request is read, so even time spent *waiting* for a slot
//! re-arms the dispatcher's deadline), and executes in a warm
//! `adpsgd worker` child checked out of a [`WorkerPool`] — the exact
//! supervision stack local subprocess dispatch uses, including the
//! heartbeat-deadline hang kill.
//!
//! Outcome mapping onto terminal frames: a finished run answers
//! [`Frame::RunResult`]; a deterministic failure answers
//! [`Frame::Error`] (the dispatcher aborts); a crashed or hung child
//! answers [`Frame::Crashed`] (the dispatcher *requeues*, possibly onto
//! this same agent, which then uses a fresh child).  If the agent
//! process itself dies, the dispatcher sees the connection drop and
//! requeues through the same path — there is no outcome a remote
//! failure can produce that the local supervision model doesn't already
//! have.
//!
//! With `--cache-dir` the agent probes its own
//! [`RunCache`] before executing, so a warm agent
//! answers repeats from disk without recomputation (and caches what it
//! does compute) — cache hits are logged, and the verify script asserts
//! them on its warm re-run.

use crate::dispatch::net::transport;
use crate::dispatch::pool::{Outcome, WorkerPool};
use crate::dispatch::proto::{Frame, HEARTBEAT_EVERY};
use crate::dispatch::runcache::RunCache;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How an agent serves (CLI: `adpsgd agent`).
#[derive(Debug, Clone)]
pub struct AgentConfig {
    /// Bind address, e.g. `0.0.0.0:7070`; port 0 picks a free port
    /// (the bound address is printed on stdout either way).
    pub listen: String,
    /// Concurrent run capacity advertised to every client and enforced
    /// across all connections by a slot semaphore.
    pub slots: usize,
    /// Shared secret clients must present in their `Hello`; `None`
    /// accepts any client.
    pub token: Option<String>,
    /// Agent-side run cache: probed before executing, written after.
    /// `None` disables (every request executes).
    pub cache_dir: Option<PathBuf>,
    /// Binary for the agent's worker children; `None` = this
    /// executable (tests and benches, whose own executable has no
    /// `worker` subcommand, must set it).
    pub worker_exe: Option<PathBuf>,
    /// Supervision deadline for the agent's worker children — the same
    /// meaning as `DispatchOptions::heartbeat_timeout` locally.
    pub heartbeat_timeout: Duration,
}

impl Default for AgentConfig {
    fn default() -> Self {
        AgentConfig {
            listen: "127.0.0.1:0".into(),
            slots: std::thread::available_parallelism().map(usize::from).unwrap_or(2),
            token: None,
            cache_dir: None,
            worker_exe: None,
            heartbeat_timeout: HEARTBEAT_EVERY * 20,
        }
    }
}

/// Counting semaphore bounding concurrent run execution across every
/// connection (std has no semaphore; Mutex + Condvar is enough here).
struct Slots {
    free: Mutex<usize>,
    freed: Condvar,
}

struct Permit<'a>(&'a Slots);

impl Slots {
    fn new(n: usize) -> Slots {
        Slots { free: Mutex::new(n.max(1)), freed: Condvar::new() }
    }

    fn acquire(&self) -> Permit<'_> {
        let mut free = self.free.lock().expect("agent slots");
        while *free == 0 {
            free = self.freed.wait(free).expect("agent slots");
        }
        *free -= 1;
        Permit(self)
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        *self.0.free.lock().expect("agent slots") += 1;
        self.0.freed.notify_one();
    }
}

/// Everything the connection and run-handler threads share.
struct Shared {
    cfg: AgentConfig,
    pool: Arc<WorkerPool>,
    cache: Option<RunCache>,
    slots: Slots,
    /// observability: runs answered from the agent's own cache
    cache_hits: Arc<AtomicUsize>,
    /// observability: total runs answered (any outcome)
    served: Arc<AtomicUsize>,
}

/// A bound (but not yet serving) agent.
pub struct Agent {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl Agent {
    /// Bind over the process-wide shared worker pool (the CLI entry:
    /// sequential runs reuse warm children).
    pub fn bind(cfg: AgentConfig) -> Result<Agent> {
        Agent::bind_with_pool(cfg, crate::dispatch::shared_worker_pool())
    }

    /// Bind over an explicit pool (tests and benches isolate their
    /// children this way).
    pub fn bind_with_pool(mut cfg: AgentConfig, pool: Arc<WorkerPool>) -> Result<Agent> {
        // clamp once, here: the semaphore, the HelloAck advertisement,
        // and the per-connection in-flight cap must all see the same
        // number (slots = 0 would otherwise advertise a capacity the
        // connection loop rejects every request against)
        cfg.slots = cfg.slots.max(1);
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding agent listener on {}", cfg.listen))?;
        let addr = listener.local_addr().context("reading bound agent address")?;
        let cache = cfg.cache_dir.as_ref().map(RunCache::new);
        let slots = Slots::new(cfg.slots);
        Ok(Agent {
            listener,
            addr,
            shared: Arc::new(Shared {
                pool,
                cache,
                slots,
                cfg,
                cache_hits: Arc::new(AtomicUsize::new(0)),
                served: Arc::new(AtomicUsize::new(0)),
            }),
        })
    }

    /// The bound address (resolves `--listen host:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter handle for runs the agent answered from its own cache.
    pub fn cache_hit_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.shared.cache_hits)
    }

    /// Counter handle for all runs the agent answered.
    pub fn served_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.shared.served)
    }

    /// Accept and serve connections forever on this thread (the CLI
    /// entry).  Each connection gets its own thread; each run request
    /// gets its own handler thread under the slot semaphore.
    pub fn serve(self) -> Result<()> {
        println!(
            "agent: listening on {} (slots {}, token {}, cache {})",
            self.addr,
            self.shared.cfg.slots,
            if self.shared.cfg.token.is_some() { "required" } else { "open" },
            self.shared
                .cfg
                .cache_dir
                .as_ref()
                .map(|d| d.display().to_string())
                .unwrap_or_else(|| "disabled".into()),
        );
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let shared = Arc::clone(&self.shared);
                    std::thread::spawn(move || handle_connection(shared, stream, peer));
                }
                Err(e) => {
                    // transient accept errors (EMFILE under load) must
                    // not kill the daemon
                    eprintln!("agent: note: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// Serve on a background thread, returning the bound address (the
    /// in-process entry for tests and benchmarks).  The thread runs for
    /// the life of the process.
    pub fn spawn(cfg: AgentConfig, pool: Arc<WorkerPool>) -> Result<SocketAddr> {
        let agent = Agent::bind_with_pool(cfg, pool)?;
        let addr = agent.addr();
        std::thread::spawn(move || {
            if let Err(e) = agent.serve() {
                eprintln!("agent: serve loop failed: {e:#}");
            }
        });
        Ok(addr)
    }
}

/// Write one frame to the shared connection writer.  Encoding happens
/// outside the lock; the single `write_all` under it keeps concurrent
/// handlers' frames from interleaving mid-payload.
fn send(writer: &Mutex<TcpStream>, frame: &Frame) -> Result<()> {
    let bytes = transport::encode_frame(frame)?;
    let mut w = writer.lock().expect("agent connection writer");
    std::io::Write::write_all(&mut *w, &bytes).context("writing to client")?;
    std::io::Write::flush(&mut *w).context("flushing to client")
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream, peer: SocketAddr) {
    stream.set_nodelay(true).ok();
    // bound every write: a frozen or partitioned dispatcher must fail a
    // blocked heartbeat/terminal send (freeing the handler, its pump,
    // and the in-flight slot) instead of pinning them under the writer
    // lock until the kernel's TCP retransmission timeout — the agent
    // mirror of the dispatcher's heartbeat deadline.  A slow-but-alive
    // peer is fine: the timeout is per write syscall, each of which
    // only needs *some* buffer space to progress.
    stream
        .set_write_timeout(Some(super::HANDSHAKE_TIMEOUT.max(shared.cfg.heartbeat_timeout)))
        .ok();
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            eprintln!("agent: note: could not clone stream for {peer}: {e}");
            return;
        }
    };
    let mut reader = std::io::BufReader::new(stream);

    // -- handshake: exactly one Hello, token-checked, then HelloAck ----
    if let Err(e) = reader.get_ref().set_read_timeout(Some(super::HANDSHAKE_TIMEOUT)) {
        eprintln!("agent: note: could not arm handshake timeout for {peer}: {e}");
        return;
    }
    match transport::read_frame(&mut reader) {
        Ok(Some(Frame::Hello { token })) => {
            let want = shared.cfg.token.as_deref().unwrap_or("");
            if !want.is_empty() && token != want {
                let _ = send(
                    &writer,
                    &Frame::Error {
                        id: 0,
                        message: "agent: invalid or missing shared-secret token".into(),
                    },
                );
                println!("agent: rejected {peer} (bad token)");
                return;
            }
            if send(&writer, &Frame::HelloAck { slots: shared.cfg.slots as u32 }).is_err() {
                return;
            }
        }
        Ok(Some(other)) => {
            let _ = send(
                &writer,
                &Frame::Error {
                    id: 0,
                    message: format!(
                        "agent: expected a hello frame to open the session, got a {} frame",
                        other.kind()
                    ),
                },
            );
            println!("agent: rejected {peer} (no hello)");
            return;
        }
        Ok(None) => return,
        Err(e) => {
            // includes the typed version-skew diagnosis: the client
            // sees exactly why it was turned away
            let _ = send(
                &writer,
                &Frame::Error { id: 0, message: format!("agent: rejecting connection: {e:#}") },
            );
            println!("agent: rejected {peer} ({e:#})");
            return;
        }
    }
    if reader.get_ref().set_read_timeout(None).is_err() {
        return;
    }
    println!("agent: session with {peer} open");

    // -- session: serve run requests until the client disconnects ------
    // a well-behaved dispatcher keeps at most `slots` requests in
    // flight per connection (that is exactly what HelloAck advertised);
    // bounding it here keeps a defective or abusive client from
    // pinning an unbounded number of handler+pump threads
    let in_flight = Arc::new(AtomicUsize::new(0));
    loop {
        match transport::read_frame(&mut reader) {
            Ok(Some(Frame::RunRequest { id, cfg })) => {
                if in_flight.fetch_add(1, Ordering::SeqCst) >= shared.cfg.slots {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    let _ = send(
                        &writer,
                        &Frame::Error {
                            id,
                            message: format!(
                                "agent: too many concurrent requests on this connection \
                                 (advertised capacity is {} slots)",
                                shared.cfg.slots
                            ),
                        },
                    );
                    continue;
                }
                let shared = Arc::clone(&shared);
                let writer = Arc::clone(&writer);
                let in_flight = Arc::clone(&in_flight);
                std::thread::spawn(move || serve_run(shared, writer, peer, id, cfg, in_flight));
            }
            Ok(Some(other)) => {
                let _ = send(
                    &writer,
                    &Frame::Error {
                        id: other.id(),
                        message: format!(
                            "agent: expected a run_request, got a {} frame",
                            other.kind()
                        ),
                    },
                );
            }
            Ok(None) => break,
            Err(e) => {
                // length-delimited framing survives a bad payload, but a
                // client sending one is defective: answer and hang up
                let _ = send(
                    &writer,
                    &Frame::Error { id: 0, message: format!("agent: malformed frame: {e:#}") },
                );
                eprintln!("agent: note: closing session with {peer}: {e:#}");
                break;
            }
        }
    }
    // unstick any handler blocked in a send to this session: the
    // client is gone, so fail their writes now rather than at the
    // write timeout
    reader.get_ref().shutdown(std::net::Shutdown::Both).ok();
    println!("agent: session with {peer} closed");
}

/// One run request end to end: heartbeat pump from the moment the
/// request exists, slot acquisition, agent-cache probe, execution in a
/// warm worker child, terminal frame.
fn serve_run(
    shared: Arc<Shared>,
    writer: Arc<Mutex<TcpStream>>,
    peer: SocketAddr,
    id: u64,
    cfg: crate::config::ExperimentConfig,
    in_flight: Arc<AtomicUsize>,
) {
    let label = cfg.name.clone();
    println!("agent: run {label:?} started (id {id}, {peer})");
    let started = Instant::now();
    // when a heartbeat write fails the client is gone (disconnected,
    // lease killed): handlers still queued on the slot semaphore skip
    // execution instead of computing for nobody
    let client_gone = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (frame, note) = {
        // prove liveness from request receipt: slot waits and cache
        // parses re-arm the dispatcher's deadline too, exactly like a
        // busy child (the shared pump stops+joins when the guard drops,
        // or early if the client is gone)
        let writer = Arc::clone(&writer);
        let gone = Arc::clone(&client_gone);
        let _pump = crate::dispatch::proto::heartbeat_pump(move || {
            let ok = send(&writer, &Frame::Heartbeat { id }).is_ok();
            if !ok {
                gone.store(true, Ordering::SeqCst);
            }
            ok
        });
        execute(&shared, id, cfg, &client_gone)
    };
    shared.served.fetch_add(1, Ordering::Relaxed);
    // release the connection's in-flight slot BEFORE the terminal frame
    // goes out: the dispatcher reuses its slot the moment it receives
    // the result, and its next request must never race the decrement
    // into a spurious over-capacity rejection
    in_flight.fetch_sub(1, Ordering::SeqCst);
    match send(&writer, &frame) {
        Ok(()) => println!(
            "agent: run {label:?} {note} in {:.2}s (id {id})",
            started.elapsed().as_secs_f64()
        ),
        Err(e) => eprintln!(
            "agent: note: could not answer run {label:?} (client gone?): {e:#}"
        ),
    }
}

/// Probe the agent cache, else execute in a warm worker child; map the
/// outcome onto its terminal frame (plus a log tag).  A run whose
/// client vanished while it waited for a slot is abandoned without
/// executing; a run already inside a worker child runs to completion
/// (and, with a cache configured, its result is cached — a retried
/// campaign then hits it instead of recomputing).
fn execute(
    shared: &Shared,
    id: u64,
    cfg: crate::config::ExperimentConfig,
    client_gone: &std::sync::atomic::AtomicBool,
) -> (Frame, &'static str) {
    let mut key: Option<(String, String)> = None;
    if let Some(cache) = &shared.cache {
        // the same RunCache::probe the dispatcher's slots use, so the
        // key/restamp semantics cannot diverge between the two sites
        match cache.probe(&cfg) {
            Ok((_, _, Some(report))) => {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
                return (Frame::RunResult { id, report }, "answered from cache");
            }
            Ok((digest, canonical, None)) => key = Some((digest, canonical)),
            Err(e) => {
                return (
                    Frame::Error { id, message: format!("agent: hashing run config: {e:#}") },
                    "failed (unhashable config)",
                )
            }
        }
    }
    let _permit = shared.slots.acquire();
    if client_gone.load(Ordering::SeqCst) {
        // the slot wait outlived the session: don't burn a worker on a
        // result nobody will read (the terminal send would fail anyway)
        return (
            Frame::Crashed { id, message: "agent: client disconnected before the run started".into() },
            "abandoned (client gone)",
        );
    }
    let mut client = match shared.pool.checkout(shared.cfg.worker_exe.as_deref()) {
        Ok(c) => c,
        Err(e) => {
            return (
                Frame::Crashed { id, message: format!("agent: spawning worker: {e:#}") },
                "crashed (no worker)",
            )
        }
    };
    match client.run(&cfg, shared.cfg.heartbeat_timeout) {
        Outcome::Done(report) => {
            if let (Some(cache), Some((digest, canonical))) = (&shared.cache, &key) {
                if let Err(e) = cache.put(digest, canonical, &report) {
                    eprintln!("agent: note: cache write failed for {:?}: {e:#}", report.name);
                }
            }
            shared.pool.checkin(client);
            (Frame::RunResult { id, report }, "executed")
        }
        Outcome::RunFailed(e) => {
            // the child is healthy (it *reported* the failure): park it
            shared.pool.checkin(client);
            (Frame::Error { id, message: format!("{e:#}") }, "failed")
        }
        Outcome::Crashed(e) => {
            // dropping the client reaps the dead/hung child and prunes
            // its pid; the dispatcher decides whether to retry
            drop(client);
            (Frame::Crashed { id, message: format!("{e:#}") }, "crashed (worker lost)")
        }
    }
}
