//! The `adpsgd agent` daemon: remote run capacity behind one TCP port.
//!
//! An agent accepts dispatcher connections, authenticates each with the
//! challenge-response handshake (the agent opens with a fresh
//! [`Frame::Challenge`] nonce; the client answers [`Frame::Hello`] with
//! the keyed digest [`auth_proof`] of the shared token over that nonce,
//! so the secret never travels the wire; protocol version is enforced
//! by frame parsing), advertises its slot capacity with
//! [`Frame::HelloAck`], and then serves [`Frame::RunRequest`]s
//! concurrently:
//! every request gets its own handler thread (at most `slots` in
//! flight per connection — requests past the advertised capacity are
//! refused with an `Error` frame — with execution additionally bounded
//! by a process-wide slot semaphore, so several connections cannot
//! oversubscribe the machine), its own heartbeat pump (armed from the
//! moment the request is read, so even time spent *waiting* for a slot
//! re-arms the dispatcher's deadline), and executes in a warm
//! `adpsgd worker` child checked out of a [`WorkerPool`] — the exact
//! supervision stack local subprocess dispatch uses, including the
//! heartbeat-deadline hang kill.  A request carrying the proto-v6
//! `stream` flag additionally has its child's journal-shaped observer
//! event batches relayed up the session as `events` frames on the same
//! id — best-effort cargo the dispatcher merges into its campaign
//! journal tagged with this agent as origin.
//!
//! Outcome mapping onto terminal frames: a finished run answers
//! [`Frame::RunResult`]; a deterministic failure answers
//! [`Frame::Error`] (the dispatcher aborts); a crashed or hung child
//! answers [`Frame::Crashed`] (the dispatcher *requeues*, possibly onto
//! this same agent, which then uses a fresh child).  If the agent
//! process itself dies, the dispatcher sees the connection drop and
//! requeues through the same path — there is no outcome a remote
//! failure can produce that the local supervision model doesn't already
//! have.
//!
//! With `--cache-dir` the agent probes its own
//! [`RunCache`] before executing, so a warm agent
//! answers repeats from disk without recomputation (and caches what it
//! does compute) — cache hits are logged, and the verify script asserts
//! them on its warm re-run.  `--cache-max-bytes` bounds that cache (and
//! the agent's blob store) with [`RunCache::gc`] at startup and after
//! every session closes, so long-lived agents don't grow unboundedly.
//!
//! Fleet duties (see [`crate::dispatch::fleet`]): with `--fleet ADDR`
//! the agent announces itself to the registry under a liveness lease
//! and re-announces on a cadence, so dispatchers discover it without a
//! static `--remote` list.  A run config whose `init_from` is a
//! `blob:<digest>` reference is resolved from the agent's
//! [`BlobStore`], pulled from the dispatcher over
//! [`Frame::BlobRequest`]/[`Frame::Blob`] on a miss (after the cache
//! probe — a warm agent never pulls bytes it won't use).  A
//! [`Frame::Cancel`] kills the worker child executing that request, as
//! does a failed heartbeat write (the client is gone — nobody will read
//! the result), so orphaned runs never silently train to completion.

use crate::dispatch::fleet::{self, BlobStore};
use crate::dispatch::net::transport;
use crate::dispatch::pool::{Outcome, WorkerPool};
use crate::dispatch::proto::{auth_proof, Frame, HEARTBEAT_EVERY};
use crate::dispatch::runcache::{GcPolicy, RunCache};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Liveness lease an announcing agent asks the registry for; the agent
/// re-announces every third of this, so two consecutive announce
/// failures still leave the lease intact.
pub const ANNOUNCE_TTL: Duration = Duration::from_secs(15);

/// How an agent serves (CLI: `adpsgd agent`).
#[derive(Debug, Clone)]
pub struct AgentConfig {
    /// Bind address, e.g. `0.0.0.0:7070`; port 0 picks a free port
    /// (the bound address is printed on stdout either way).
    pub listen: String,
    /// Concurrent run capacity advertised to every client and enforced
    /// across all connections by a slot semaphore.
    pub slots: usize,
    /// Shared secret clients must present in their `Hello`; `None`
    /// accepts any client.
    pub token: Option<String>,
    /// Agent-side run cache: probed before executing, written after.
    /// `None` disables (every request executes).
    pub cache_dir: Option<PathBuf>,
    /// Binary for the agent's worker children; `None` = this
    /// executable (tests and benches, whose own executable has no
    /// `worker` subcommand, must set it).
    pub worker_exe: Option<PathBuf>,
    /// Supervision deadline for the agent's worker children — the same
    /// meaning as `DispatchOptions::heartbeat_timeout` locally.
    pub heartbeat_timeout: Duration,
    /// Size bound for the agent's run cache and blob store, enforced at
    /// startup and after every session closes.  `None` = unbounded.
    pub cache_max_bytes: Option<u64>,
    /// Fleet registry to announce to (`--fleet host:port`); `None`
    /// serves only statically-configured dispatchers.
    pub fleet: Option<String>,
    /// The address announced to the registry; defaults to the bound
    /// listen address (override when binding `0.0.0.0` behind NAT or
    /// a distinct external name).
    pub advertise: Option<String>,
}

impl Default for AgentConfig {
    fn default() -> Self {
        AgentConfig {
            listen: "127.0.0.1:0".into(),
            slots: std::thread::available_parallelism().map(usize::from).unwrap_or(2),
            token: None,
            cache_dir: None,
            worker_exe: None,
            heartbeat_timeout: HEARTBEAT_EVERY * 20,
            cache_max_bytes: None,
            fleet: None,
            advertise: None,
        }
    }
}

/// Counting semaphore bounding concurrent run execution across every
/// connection (std has no semaphore; Mutex + Condvar is enough here).
struct Slots {
    free: Mutex<usize>,
    freed: Condvar,
}

struct Permit<'a>(&'a Slots);

impl Slots {
    fn new(n: usize) -> Slots {
        Slots { free: Mutex::new(n.max(1)), freed: Condvar::new() }
    }

    fn acquire(&self) -> Permit<'_> {
        let mut free = self.free.lock().expect("agent slots");
        while *free == 0 {
            free = self.freed.wait(free).expect("agent slots");
        }
        *free -= 1;
        Permit(self)
    }

    /// Slots currently held by executing runs (process-wide, not
    /// per-connection) — what `adpsgd status` reports as in-flight.
    fn in_use(&self, total: usize) -> usize {
        total.saturating_sub(*self.free.lock().expect("agent slots"))
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        *self.0.free.lock().expect("agent slots") += 1;
        self.0.freed.notify_one();
    }
}

/// Everything the connection and run-handler threads share.
struct Shared {
    cfg: AgentConfig,
    pool: Arc<WorkerPool>,
    cache: Option<RunCache>,
    /// content-addressed store for artifacts pulled over `BlobRequest`
    /// (under the cache dir, or a per-port temp dir without one)
    blobs: BlobStore,
    slots: Slots,
    /// observability: runs answered from the agent's own cache
    cache_hits: Arc<AtomicUsize>,
    /// observability: total runs answered (any outcome)
    served: Arc<AtomicUsize>,
}

impl Shared {
    /// Bound the run cache and blob store to `cache_max_bytes` (no-op
    /// without a bound).  Called at startup and at session close, so a
    /// long-lived agent stays bounded between campaigns.
    fn run_gc(&self, when: &str) {
        let Some(max) = self.cfg.cache_max_bytes else { return };
        if let Some(cache) = &self.cache {
            match cache.gc(&GcPolicy { max_bytes: Some(max), ..GcPolicy::default() }) {
                Ok(stats) if stats.evicted > 0 || stats.tmp_swept > 0 => println!(
                    "agent: cache gc ({when}): evicted {} entries ({} bytes), kept {}, \
                     swept {} tmp",
                    stats.evicted, stats.evicted_bytes, stats.kept, stats.tmp_swept
                ),
                Ok(_) => {}
                Err(e) => crate::obs::log!("agent", "cache gc failed: {e:#}"),
            }
        }
        match self.blobs.gc(max) {
            Ok((evicted, freed)) if evicted > 0 => println!(
                "agent: blob gc ({when}): evicted {evicted} blobs ({freed} bytes)"
            ),
            Ok(_) => {}
            Err(e) => crate::obs::log!("agent", "blob gc failed: {e:#}"),
        }
    }

    /// The live snapshot answering a proto-v5 `stats_request`
    /// (`adpsgd status`): advertised capacity, process-wide in-flight
    /// runs, session counters, and the full [`crate::obs::metrics`]
    /// snapshot — an opaque JSON object on the wire, so new fields
    /// never need a protocol bump.
    fn stats_snapshot(&self) -> Json {
        Json::obj(vec![
            ("slots", Json::num(self.cfg.slots as f64)),
            ("in_flight", Json::num(self.slots.in_use(self.cfg.slots) as f64)),
            ("served", Json::num(self.served.load(Ordering::Relaxed) as f64)),
            ("cache_hits", Json::num(self.cache_hits.load(Ordering::Relaxed) as f64)),
            ("metrics", crate::obs::metrics().snapshot()),
        ])
    }
}

/// Per-connection state the session loop and run handlers share:
/// request ids are scoped to a connection (two dispatchers may both be
/// on id 1), so the routing tables must be too.
struct Session {
    writer: Arc<Mutex<TcpStream>>,
    /// run handlers waiting for a `Blob`/`Error` answer to their
    /// `BlobRequest`, keyed by request id
    blob_waits: Mutex<HashMap<u64, mpsc::Sender<Frame>>>,
    /// worker-child pid per in-flight request id, for `Cancel` and for
    /// the orphan kill when a heartbeat write finds the client gone
    children: Mutex<HashMap<u64, u32>>,
    /// requests cancelled before (or while) they held a child
    cancelled: Mutex<std::collections::HashSet<u64>>,
}

impl Session {
    fn new(writer: Arc<Mutex<TcpStream>>) -> Session {
        Session {
            writer,
            blob_waits: Mutex::new(HashMap::new()),
            children: Mutex::new(HashMap::new()),
            cancelled: Mutex::new(std::collections::HashSet::new()),
        }
    }

    /// Kill the worker child executing request `id`, if any — the
    /// `Cancel` path and the orphaned-run path both land here.
    fn kill_child_of(&self, id: u64) {
        let pid = self.children.lock().expect("agent children").get(&id).copied();
        if let Some(pid) = pid {
            println!("agent: killing worker child {pid} (run id {id} abandoned)");
            kill_pid(pid);
        }
    }
}

/// Best-effort SIGTERM by pid (the child is ours, but it is checked out
/// by a handler thread that is blocked reading from it, so the kill has
/// to go around the `WorkerClient` handle).
fn kill_pid(pid: u32) {
    let _ = std::process::Command::new("sh")
        .arg("-c")
        .arg(format!("kill {pid} 2>/dev/null"))
        .status();
}

/// A nonce for one connection's challenge: unique per (process, time,
/// connection) so a captured proof is useless against any later
/// handshake.
fn fresh_nonce(peer: &SocketAddr) -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    crate::dispatch::runcache::content_digest(
        format!(
            "nonce\n{}\n{}\n{}\n{}",
            std::process::id(),
            t,
            COUNTER.fetch_add(1, Ordering::Relaxed),
            peer
        )
        .as_bytes(),
    )
}

/// A bound (but not yet serving) agent.
pub struct Agent {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl Agent {
    /// Bind over the process-wide shared worker pool (the CLI entry:
    /// sequential runs reuse warm children).
    pub fn bind(cfg: AgentConfig) -> Result<Agent> {
        Agent::bind_with_pool(cfg, crate::dispatch::shared_worker_pool())
    }

    /// Bind over an explicit pool (tests and benches isolate their
    /// children this way).
    pub fn bind_with_pool(mut cfg: AgentConfig, pool: Arc<WorkerPool>) -> Result<Agent> {
        // clamp once, here: the semaphore, the HelloAck advertisement,
        // and the per-connection in-flight cap must all see the same
        // number (slots = 0 would otherwise advertise a capacity the
        // connection loop rejects every request against)
        cfg.slots = cfg.slots.max(1);
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding agent listener on {}", cfg.listen))?;
        let addr = listener.local_addr().context("reading bound agent address")?;
        let cache = cfg.cache_dir.as_ref().map(RunCache::new);
        let blobs = match &cfg.cache_dir {
            Some(dir) => BlobStore::under_cache(dir),
            // no cache dir: staged artifacts still need to land
            // somewhere; the port keeps concurrent agents apart
            None => BlobStore::new(
                std::env::temp_dir().join(format!("adpsgd_agent_blobs_{}", addr.port())),
            ),
        };
        let slots = Slots::new(cfg.slots);
        let agent = Agent {
            listener,
            addr,
            shared: Arc::new(Shared {
                pool,
                cache,
                blobs,
                slots,
                cfg,
                cache_hits: Arc::new(AtomicUsize::new(0)),
                served: Arc::new(AtomicUsize::new(0)),
            }),
        };
        // a long-lived agent restarting onto an old cache dir bounds it
        // before serving anything
        agent.shared.run_gc("startup");
        Ok(agent)
    }

    /// The bound address (resolves `--listen host:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter handle for runs the agent answered from its own cache.
    pub fn cache_hit_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.shared.cache_hits)
    }

    /// Counter handle for all runs the agent answered.
    pub fn served_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.shared.served)
    }

    /// Accept and serve connections forever on this thread (the CLI
    /// entry).  Each connection gets its own thread; each run request
    /// gets its own handler thread under the slot semaphore.
    pub fn serve(self) -> Result<()> {
        println!(
            "agent: listening on {} (slots {}, token {}, cache {})",
            self.addr,
            self.shared.cfg.slots,
            if self.shared.cfg.token.is_some() { "required" } else { "open" },
            self.shared
                .cfg
                .cache_dir
                .as_ref()
                .map(|d| d.display().to_string())
                .unwrap_or_else(|| "disabled".into()),
        );
        if let Some(registry) = self.shared.cfg.fleet.clone() {
            let advertise = self
                .shared
                .cfg
                .advertise
                .clone()
                .unwrap_or_else(|| self.addr.to_string());
            let slots = self.shared.cfg.slots as u32;
            std::thread::spawn(move || announce_loop(&registry, &advertise, slots));
        }
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let shared = Arc::clone(&self.shared);
                    std::thread::spawn(move || handle_connection(shared, stream, peer));
                }
                Err(e) => {
                    // transient accept errors (EMFILE under load) must
                    // not kill the daemon
                    crate::obs::log!("agent", "accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// Serve on a background thread, returning the bound address (the
    /// in-process entry for tests and benchmarks).  The thread runs for
    /// the life of the process.
    pub fn spawn(cfg: AgentConfig, pool: Arc<WorkerPool>) -> Result<SocketAddr> {
        let agent = Agent::bind_with_pool(cfg, pool)?;
        let addr = agent.addr();
        std::thread::spawn(move || {
            if let Err(e) = agent.serve() {
                eprintln!("agent: serve loop failed: {e:#}");
            }
        });
        Ok(addr)
    }
}

/// Re-announce to the fleet registry every [`ANNOUNCE_TTL`]/3 for the
/// life of the process.  Announce failures are logged on the first
/// failure and on recovery, not every beat — a registry restart is
/// routine, and the lease machinery already tolerates missed beats.
fn announce_loop(registry: &str, advertise: &str, slots: u32) {
    let mut down = false;
    loop {
        match fleet::registry::announce(registry, advertise, slots, ANNOUNCE_TTL) {
            Ok(()) => {
                if down {
                    println!("agent: re-announced to registry {registry}");
                }
                down = false;
            }
            Err(e) => {
                if !down {
                    crate::obs::log!("agent", "announce to registry {registry} failed: {e:#}");
                }
                down = true;
            }
        }
        std::thread::sleep(ANNOUNCE_TTL / 3);
    }
}

/// Write one frame to the shared connection writer.  Encoding happens
/// outside the lock; the single `write_all` under it keeps concurrent
/// handlers' frames from interleaving mid-payload.
fn send(writer: &Mutex<TcpStream>, frame: &Frame) -> Result<()> {
    let bytes = transport::encode_frame(frame)?;
    let mut w = writer.lock().expect("agent connection writer");
    std::io::Write::write_all(&mut *w, &bytes).context("writing to client")?;
    std::io::Write::flush(&mut *w).context("flushing to client")
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream, peer: SocketAddr) {
    stream.set_nodelay(true).ok();
    // bound every write: a frozen or partitioned dispatcher must fail a
    // blocked heartbeat/terminal send (freeing the handler, its pump,
    // and the in-flight slot) instead of pinning them under the writer
    // lock until the kernel's TCP retransmission timeout — the agent
    // mirror of the dispatcher's heartbeat deadline.  A slow-but-alive
    // peer is fine: the timeout is per write syscall, each of which
    // only needs *some* buffer space to progress.
    stream
        .set_write_timeout(Some(super::HANDSHAKE_TIMEOUT.max(shared.cfg.heartbeat_timeout)))
        .ok();
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            crate::obs::log!("agent", "could not clone stream for {peer}: {e}");
            return;
        }
    };
    let mut reader = std::io::BufReader::new(stream);

    // -- handshake: challenge out, exactly one proof back, HelloAck ----
    // the agent speaks first: a fresh nonce the client must answer with
    // the keyed digest of the shared token (auth_proof) — the token
    // itself never travels, and a proof captured off the wire is bound
    // to this nonce and useless against the next connection
    if let Err(e) = reader.get_ref().set_read_timeout(Some(super::HANDSHAKE_TIMEOUT)) {
        crate::obs::log!("agent", "could not arm handshake timeout for {peer}: {e}");
        return;
    }
    let nonce = fresh_nonce(&peer);
    if send(&writer, &Frame::Challenge { nonce: nonce.clone() }).is_err() {
        return;
    }
    match transport::read_frame(&mut reader) {
        Ok(Some(Frame::Hello { proof })) => {
            let want = auth_proof(&nonce, shared.cfg.token.as_deref().unwrap_or(""));
            if proof != want {
                let _ = send(
                    &writer,
                    &Frame::Error {
                        id: 0,
                        message: "agent: authentication failed (invalid or missing \
                                  shared-secret token)"
                            .into(),
                    },
                );
                println!("agent: rejected {peer} (bad token)");
                return;
            }
            if send(&writer, &Frame::HelloAck { slots: shared.cfg.slots as u32 }).is_err() {
                return;
            }
        }
        Ok(Some(other)) => {
            let _ = send(
                &writer,
                &Frame::Error {
                    id: 0,
                    message: format!(
                        "agent: expected a hello proof to open the session, got a {} frame",
                        other.kind()
                    ),
                },
            );
            println!("agent: rejected {peer} (no hello)");
            return;
        }
        Ok(None) => return,
        Err(e) => {
            // includes the typed version-skew diagnosis: the client
            // sees exactly why it was turned away
            let _ = send(
                &writer,
                &Frame::Error { id: 0, message: format!("agent: rejecting connection: {e:#}") },
            );
            println!("agent: rejected {peer} ({e:#})");
            return;
        }
    }
    if reader.get_ref().set_read_timeout(None).is_err() {
        return;
    }
    println!("agent: session with {peer} open");

    // -- session: serve run requests until the client disconnects ------
    // a well-behaved dispatcher keeps at most `slots` requests in
    // flight per connection (that is exactly what HelloAck advertised);
    // bounding it here keeps a defective or abusive client from
    // pinning an unbounded number of handler+pump threads
    let session = Arc::new(Session::new(Arc::clone(&writer)));
    let in_flight = Arc::new(AtomicUsize::new(0));
    loop {
        match transport::read_frame(&mut reader) {
            Ok(Some(Frame::RunRequest { id, cfg, trace, stream })) => {
                if in_flight.fetch_add(1, Ordering::SeqCst) >= shared.cfg.slots {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    let _ = send(
                        &writer,
                        &Frame::Error {
                            id,
                            message: format!(
                                "agent: too many concurrent requests on this connection \
                                 (advertised capacity is {} slots)",
                                shared.cfg.slots
                            ),
                        },
                    );
                    continue;
                }
                let shared = Arc::clone(&shared);
                let session = Arc::clone(&session);
                let in_flight = Arc::clone(&in_flight);
                std::thread::spawn(move || {
                    serve_run(shared, session, peer, id, cfg, trace, stream, in_flight)
                });
            }
            Ok(Some(Frame::StatsRequest { id })) => {
                // `adpsgd status`: answer from the shared snapshot;
                // interleaves freely with in-flight runs and never
                // consumes a run slot
                let _ = send(&writer, &Frame::Stats { id, stats: shared.stats_snapshot() });
            }
            Ok(Some(Frame::Cancel { id })) => {
                // the dispatcher no longer wants this run (its campaign
                // aborted): remember the id for handlers still queued on
                // the slot semaphore, and kill any worker child already
                // executing it
                println!("agent: cancel received for run id {id} ({peer})");
                session.cancelled.lock().expect("agent cancelled").insert(id);
                session.kill_child_of(id);
            }
            Ok(Some(frame @ (Frame::Blob { .. } | Frame::Error { .. }))) => {
                // an answer to a handler's BlobRequest: route it by id
                let id = frame.id();
                let tx = session.blob_waits.lock().expect("agent blob waits").remove(&id);
                match tx {
                    Some(tx) => {
                        let _ = tx.send(frame);
                    }
                    None => crate::obs::log!(
                        "agent",
                        "unsolicited {} frame (id {id}) from {peer}",
                        frame.kind()
                    ),
                }
            }
            Ok(Some(other)) => {
                let _ = send(
                    &writer,
                    &Frame::Error {
                        id: other.id(),
                        message: format!(
                            "agent: expected a run_request, got a {} frame",
                            other.kind()
                        ),
                    },
                );
            }
            Ok(None) => break,
            Err(e) => {
                // length-delimited framing survives a bad payload, but a
                // client sending one is defective: answer and hang up
                let _ = send(
                    &writer,
                    &Frame::Error { id: 0, message: format!("agent: malformed frame: {e:#}") },
                );
                crate::obs::log!("agent", "closing session with {peer}: {e:#}");
                break;
            }
        }
    }
    // unstick any handler blocked in a send to this session: the
    // client is gone, so fail their writes now rather than at the
    // write timeout
    reader.get_ref().shutdown(std::net::Shutdown::Both).ok();
    println!("agent: session with {peer} closed");
    // campaign boundary for a long-lived agent: bound the cache and the
    // blob store it just grew
    shared.run_gc("session close");
}

/// One run request end to end: heartbeat pump from the moment the
/// request exists, blob staging, slot acquisition, agent-cache probe,
/// execution in a warm worker child, terminal frame.  With `stream`
/// set the child's proto-v6 `events` frames are relayed up the session
/// writer on this request's id.
#[allow(clippy::too_many_arguments)]
fn serve_run(
    shared: Arc<Shared>,
    session: Arc<Session>,
    peer: SocketAddr,
    id: u64,
    cfg: crate::config::ExperimentConfig,
    trace: Option<String>,
    stream: bool,
    in_flight: Arc<AtomicUsize>,
) {
    let label = cfg.name.clone();
    // the driver-minted trace id lands on the agent's own stdout, so
    // one grep follows the run driver journal → agent → worker child
    match &trace {
        Some(t) => println!("agent: run {label:?} started (id {id}, {peer}, trace {t})"),
        None => println!("agent: run {label:?} started (id {id}, {peer})"),
    }
    let started = Instant::now();
    // when a heartbeat write fails the client is gone (disconnected,
    // lease killed): handlers still queued on the slot semaphore skip
    // execution instead of computing for nobody, and a child already
    // executing is killed — nobody will ever read its result
    let client_gone = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (frame, note) = {
        // prove liveness from request receipt: slot waits and cache
        // parses re-arm the dispatcher's deadline too, exactly like a
        // busy child (the shared pump stops+joins when the guard drops,
        // or early if the client is gone)
        let pump_session = Arc::clone(&session);
        let gone = Arc::clone(&client_gone);
        let _pump = crate::dispatch::proto::heartbeat_pump(move || {
            let ok = send(&pump_session.writer, &Frame::Heartbeat { id }).is_ok();
            if !ok {
                gone.store(true, Ordering::SeqCst);
                pump_session.kill_child_of(id);
            }
            ok
        });
        execute(&shared, &session, id, cfg, trace.as_deref(), stream, &client_gone)
    };
    shared.served.fetch_add(1, Ordering::Relaxed);
    crate::obs::metrics().counter("agent.runs_served").inc();
    // release the connection's in-flight slot BEFORE the terminal frame
    // goes out: the dispatcher reuses its slot the moment it receives
    // the result, and its next request must never race the decrement
    // into a spurious over-capacity rejection
    in_flight.fetch_sub(1, Ordering::SeqCst);
    match send(&session.writer, &frame) {
        Ok(()) => println!(
            "agent: run {label:?} {note} in {:.2}s (id {id})",
            started.elapsed().as_secs_f64()
        ),
        Err(e) => crate::obs::log!(
            "agent",
            "could not answer run {label:?} (client gone?): {e:#}"
        ),
    }
}

/// Resolve a `blob:<digest>` reference to a staged local path: the
/// store answers immediately when warm; otherwise the handler asks the
/// dispatcher over `BlobRequest` and blocks (bounded by the heartbeat
/// timeout — the pump keeps the dispatcher's own deadline armed
/// throughout) until the session loop routes the `Blob` answer back.
/// On failure, the terminal frame to answer the run with.
fn stage_blob(
    shared: &Shared,
    session: &Session,
    id: u64,
    digest: &str,
) -> std::result::Result<PathBuf, (Frame, &'static str)> {
    if let Some(path) = shared.blobs.get(digest) {
        return Ok(path);
    }
    let (tx, rx) = mpsc::channel();
    session.blob_waits.lock().expect("agent blob waits").insert(id, tx);
    if let Err(e) = send(&session.writer, &Frame::BlobRequest { id, digest: digest.into() }) {
        session.blob_waits.lock().expect("agent blob waits").remove(&id);
        return Err((
            Frame::Crashed { id, message: format!("agent: requesting blob {digest}: {e:#}") },
            "crashed (blob request)",
        ));
    }
    let answer = rx.recv_timeout(shared.cfg.heartbeat_timeout);
    session.blob_waits.lock().expect("agent blob waits").remove(&id);
    match answer {
        Ok(Frame::Blob { bytes, .. }) => match shared.blobs.put(digest, &bytes) {
            Ok(path) => {
                println!("agent: staged blob {digest} ({} bytes, run id {id})", bytes.len());
                crate::obs::metrics()
                    .counter("agent.blob_bytes_staged")
                    .add(bytes.len() as u64);
                Ok(path)
            }
            // a digest mismatch here means the dispatcher shipped the
            // wrong bytes — deterministic, not retryable
            Err(e) => Err((
                Frame::Error { id, message: format!("agent: storing blob {digest}: {e:#}") },
                "failed (blob store)",
            )),
        },
        Ok(Frame::Error { message, .. }) => Err((
            Frame::Error {
                id,
                message: format!("agent: dispatcher could not supply blob {digest}: {message}"),
            },
            "failed (blob refused)",
        )),
        Ok(other) => Err((
            Frame::Error {
                id,
                message: format!(
                    "agent: unexpected {} frame answering blob request {digest}",
                    other.kind()
                ),
            },
            "failed (blob protocol)",
        )),
        Err(_) => Err((
            Frame::Crashed {
                id,
                message: format!("agent: timed out waiting for blob {digest} from the dispatcher"),
            },
            "crashed (blob timeout)",
        )),
    }
}

/// Probe the agent cache, stage any `blob:` warm-start reference, else
/// execute in a warm worker child; map the outcome onto its terminal
/// frame (plus a log tag).  The cache probe comes *first* — the `blob:`
/// scheme hashes by digest, so a warm agent answers without pulling a
/// byte — and staging comes *before* the slot acquire, because the pull
/// is network-bound and must not hold compute capacity.  A run whose
/// client vanished (or that was cancelled) while it waited for a slot
/// is abandoned without executing; a child already executing when its
/// run is orphaned or cancelled is killed by the session/pump paths and
/// surfaces here as `Crashed`.
fn execute(
    shared: &Shared,
    session: &Session,
    id: u64,
    mut cfg: crate::config::ExperimentConfig,
    trace: Option<&str>,
    stream: bool,
    client_gone: &std::sync::atomic::AtomicBool,
) -> (Frame, &'static str) {
    let mut key: Option<(String, String)> = None;
    if let Some(cache) = &shared.cache {
        // the same RunCache::probe the dispatcher's slots use, so the
        // key/restamp semantics cannot diverge between the two sites
        match cache.probe(&cfg) {
            Ok((_, _, Some(report))) => {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics().counter("agent.cache_hits").inc();
                return (Frame::RunResult { id, report }, "answered from cache");
            }
            Ok((digest, canonical, None)) => key = Some((digest, canonical)),
            Err(e) => {
                return (
                    Frame::Error { id, message: format!("agent: hashing run config: {e:#}") },
                    "failed (unhashable config)",
                )
            }
        }
    }
    let blob_ref =
        cfg.init_from.strip_prefix(fleet::blobs::BLOB_SCHEME).map(str::to_string);
    if let Some(digest) = blob_ref {
        match stage_blob(shared, session, id, &digest) {
            Ok(path) => cfg.init_from = path.display().to_string(),
            Err(terminal) => return terminal,
        }
    }
    let _permit = shared.slots.acquire();
    if client_gone.load(Ordering::SeqCst) {
        // the slot wait outlived the session: don't burn a worker on a
        // result nobody will read (the terminal send would fail anyway)
        return (
            Frame::Crashed { id, message: "agent: client disconnected before the run started".into() },
            "abandoned (client gone)",
        );
    }
    if session.cancelled.lock().expect("agent cancelled").contains(&id) {
        return (
            Frame::Crashed { id, message: "agent: run cancelled by the dispatcher".into() },
            "abandoned (cancelled)",
        );
    }
    let mut client = match shared.pool.checkout(shared.cfg.worker_exe.as_deref()) {
        Ok(c) => c,
        Err(e) => {
            return (
                Frame::Crashed { id, message: format!("agent: spawning worker: {e:#}") },
                "crashed (no worker)",
            )
        }
    };
    // register the child for Cancel / orphan kill while it executes
    session.children.lock().expect("agent children").insert(id, client.pid());
    // the trace rides into the worker child's run request too (the
    // third leg of driver → agent → worker tracing); with streaming on,
    // the child's event batches are relayed up the session on this
    // request's id — best-effort: a failed relay write only counts a
    // drop, it never fails the run (the terminal send will notice a
    // truly dead client on its own)
    let mut relay;
    let events: Option<&mut dyn FnMut(Vec<String>)> = if stream {
        relay = |lines: Vec<String>| {
            let n = lines.len() as u64;
            if send(&session.writer, &Frame::Events { id, lines }).is_err() {
                crate::obs::metrics().counter("obs.event_drops").add(n);
            }
        };
        Some(&mut relay)
    } else {
        None
    };
    let outcome = client.run(&cfg, trace, shared.cfg.heartbeat_timeout, events);
    session.children.lock().expect("agent children").remove(&id);
    match outcome {
        Outcome::Done(report) => {
            if let (Some(cache), Some((digest, canonical))) = (&shared.cache, &key) {
                if let Err(e) = cache.put(digest, canonical, &report) {
                    crate::obs::log!("agent", "cache write failed for {:?}: {e:#}", report.name);
                }
            }
            shared.pool.checkin(client);
            (Frame::RunResult { id, report }, "executed")
        }
        Outcome::RunFailed(e) => {
            // the child is healthy (it *reported* the failure): park it
            shared.pool.checkin(client);
            (Frame::Error { id, message: format!("{e:#}") }, "failed")
        }
        Outcome::Crashed(e) => {
            // dropping the client reaps the dead/hung child and prunes
            // its pid; the dispatcher decides whether to retry (a child
            // we killed for a Cancel lands here too — harmless, the
            // cancelling dispatcher has already forgotten the id)
            drop(client);
            (Frame::Crashed { id, message: format!("{e:#}") }, "crashed (worker lost)")
        }
    }
}
