//! Dispatcher side of the remote worker fabric: one authenticated TCP
//! connection per `adpsgd agent`, multiplexed across that agent's
//! advertised slots.
//!
//! A [`RemoteAgentClient`] owns the connection: a single reader thread
//! demultiplexes incoming frames by request id into per-slot channels,
//! and slot threads wait on their channel with the same heartbeat
//! deadline as a local subprocess client — so a silent agent (network
//! partition, frozen daemon) is handled exactly like a hung child: the
//! lease is killed (the socket is shut down, which also unsticks every
//! sibling slot on the same connection), the in-flight runs come back
//! as crashes, and the dispatcher requeues them onto surviving slots.
//! Terminal frames that surface for an id no slot is waiting on are
//! discarded as stale, never misclassified as protocol violations.

use crate::dispatch::fleet::BlobCatalog;
use crate::dispatch::net::transport;
use crate::dispatch::pool::Outcome;
use crate::dispatch::proto::{auth_proof, Frame};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One live, handshaken connection to an `adpsgd agent`.
pub struct RemoteAgentClient {
    addr: String,
    /// concurrent-run capacity the agent advertised in its `HelloAck`
    slots: usize,
    /// kept for `shutdown` on lease kill; the writer is a clone
    stream: TcpStream,
    writer: Mutex<TcpStream>,
    /// request id → the slot waiting for that id's frames
    pending: Arc<Mutex<HashMap<u64, Sender<Frame>>>>,
    next_id: AtomicU64,
    dead: Arc<AtomicBool>,
    /// bumped by the reader on every successful read syscall: byte
    /// progress *inside* a large frame (a multi-MB RunResult on a slow
    /// link) proves liveness even though no complete frame has arrived
    /// to re-arm a slot's deadline yet
    rx_tick: Arc<AtomicU64>,
}

/// Read adapter that ticks a counter on every successful read, so
/// deadline checks can distinguish a silent connection from one slowly
/// delivering a large frame.
struct TickingReader<R> {
    inner: R,
    tick: Arc<AtomicU64>,
}

impl<R: std::io::Read> std::io::Read for TickingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        if n > 0 {
            self.tick.fetch_add(1, Ordering::Relaxed);
        }
        Ok(n)
    }
}

/// Removes a slot's id from the demux table on every exit path, so
/// late frames for an abandoned request are discarded as stale.
struct PendingGuard<'a> {
    pending: &'a Mutex<HashMap<u64, Sender<Frame>>>,
    id: u64,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.pending.lock().expect("remote pending map").remove(&self.id);
    }
}

impl RemoteAgentClient {
    /// Connect to `addr` and perform the challenge-response handshake:
    /// the agent opens with a nonce [`Frame::Challenge`], the client
    /// answers [`Frame::Hello`] with the keyed digest of the shared
    /// token over that nonce ([`auth_proof`] — the secret itself never
    /// travels the wire), and the agent acknowledges with its slot
    /// capacity.  Failures here are loud configuration errors with the
    /// cause spelled out: unreachable host, rejected token, version
    /// skew, or a peer that is not an adpsgd agent.
    pub fn connect(
        addr: &str,
        token: Option<&str>,
        handshake_timeout: Duration,
    ) -> Result<Arc<RemoteAgentClient>> {
        // connect under the same deadline as the handshake: a host that
        // silently drops SYNs (firewall sinkhole, powered-off machine)
        // must not stall campaign startup for the OS connect timeout
        use std::net::ToSocketAddrs;
        let resolved: Vec<std::net::SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving agent address {addr}"))?
            .collect();
        // split the budget across the resolved addresses (a sinkholed
        // AAAA record must not consume the whole deadline before the A
        // record gets a try), with a floor so many addresses still each
        // get a usable slice
        let per_addr = handshake_timeout
            .checked_div(resolved.len().max(1) as u32)
            .unwrap_or(handshake_timeout)
            .max(Duration::from_millis(500));
        let mut stream: Option<TcpStream> = None;
        let mut last_err: Option<std::io::Error> = None;
        for a in &resolved {
            match TcpStream::connect_timeout(a, per_addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = stream.ok_or_else(|| match last_err {
            Some(e) => anyhow!("connecting to agent {addr}: {e}"),
            None => anyhow!("agent address {addr} resolved to no usable address"),
        })?;
        stream.set_nodelay(true).ok();
        // the deadline applies to the handshake only; run waits are
        // deadline-aware through the demux channels instead
        stream
            .set_read_timeout(Some(handshake_timeout))
            .context("arming handshake timeout")?;
        let mut reader = stream.try_clone().context("cloning agent stream")?;
        // the agent speaks first: a fresh nonce the token is proved
        // against (an eavesdropper sees only a nonce-bound digest,
        // useless for any later connection)
        let challenge = transport::read_frame(&mut reader)
            .with_context(|| format!("handshake with agent {addr}"))?;
        let nonce = match challenge {
            Some(Frame::Challenge { nonce }) => nonce,
            Some(Frame::Error { message, .. }) => {
                bail!("agent {addr} rejected the connection: {message}")
            }
            Some(other) => bail!(
                "agent {addr} opened the handshake with an unexpected {} frame \
                 (expected a challenge)",
                other.kind()
            ),
            None => bail!("agent {addr} closed the connection during the handshake"),
        };
        let mut writer = stream.try_clone().context("cloning agent stream")?;
        transport::write_frame(
            &mut writer,
            &Frame::Hello { proof: auth_proof(&nonce, token.unwrap_or("")) },
        )
        .with_context(|| format!("greeting agent {addr}"))?;
        let ack = transport::read_frame(&mut reader)
            .with_context(|| format!("handshake with agent {addr}"))?;
        let slots = match ack {
            Some(Frame::HelloAck { slots }) => slots.max(1) as usize,
            Some(Frame::Error { message, .. }) => {
                bail!("agent {addr} rejected the connection: {message}")
            }
            Some(other) => bail!(
                "agent {addr} answered the handshake with an unexpected {} frame",
                other.kind()
            ),
            None => bail!("agent {addr} closed the connection during the handshake"),
        };
        stream.set_read_timeout(None).context("disarming handshake timeout")?;

        let pending: Arc<Mutex<HashMap<u64, Sender<Frame>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let rx_tick = Arc::new(AtomicU64::new(0));
        {
            // the reader thread: demultiplex frames by id.  On EOF or a
            // transport error it marks the connection dead and clears
            // the demux table — dropping the senders disconnects every
            // waiting slot, which surfaces as a crash (requeue).
            let pending = Arc::clone(&pending);
            let dead = Arc::clone(&dead);
            let rx_tick = Arc::clone(&rx_tick);
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut reader =
                    std::io::BufReader::new(TickingReader { inner: reader, tick: rx_tick });
                loop {
                    match transport::read_frame(&mut reader) {
                        Ok(Some(frame)) => {
                            let sender = pending
                                .lock()
                                .expect("remote pending map")
                                .get(&frame.id())
                                .cloned();
                            match sender {
                                Some(tx) => {
                                    let _ = tx.send(frame);
                                }
                                None => match &frame {
                                    Frame::Heartbeat { .. } => {}
                                    Frame::RunResult { .. }
                                    | Frame::Error { .. }
                                    | Frame::Crashed { .. } => crate::obs::log!(
                                        "remote",
                                        "discarding stale {} frame for abandoned \
                                         request {} from agent {addr}",
                                        frame.kind(),
                                        frame.id()
                                    ),
                                    _ => {}
                                },
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            if !dead.load(Ordering::SeqCst) {
                                crate::obs::log!("remote", "agent {addr} connection error: {e:#}");
                            }
                            break;
                        }
                    }
                }
                dead.store(true, Ordering::SeqCst);
                pending.lock().expect("remote pending map").clear();
            });
        }
        Ok(Arc::new(RemoteAgentClient {
            addr: addr.to_string(),
            slots,
            stream,
            writer: Mutex::new(writer),
            pending,
            next_id: AtomicU64::new(0),
            dead,
            rx_tick,
        }))
    }

    /// The concurrent-run capacity the agent advertised.
    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Whether the connection has been lost or its lease killed.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Kill the lease on this agent: mark it dead and shut the socket
    /// down, so the reader thread exits and every sibling slot waiting
    /// on this connection crashes out (and requeues) instead of waiting
    /// for its own deadline.
    fn kill(&self, why: &str) {
        if !self.dead.swap(true, Ordering::SeqCst) {
            crate::obs::log!("remote", "killing lease on agent {} ({why})", self.addr);
        }
        self.stream.shutdown(Shutdown::Both).ok();
    }

    /// Write one frame under the writer lock (encoding outside it, so
    /// concurrent slots' frames never interleave mid-payload).
    fn send_frame(&self, frame: &Frame) -> Result<()> {
        let bytes = transport::encode_frame(frame)?;
        let mut w = self.writer.lock().expect("remote writer");
        w.write_all(&bytes)
            .and_then(|()| w.flush())
            .with_context(|| format!("writing to agent {}", self.addr))
    }

    /// Submit one run and wait for its terminal frame under the
    /// heartbeat deadline — the remote mirror of the subprocess
    /// client's supervision.  Heartbeats (and raw byte progress on the
    /// shared connection, for large frames in transit) re-arm the
    /// deadline; `Error` is a deterministic run failure; `Crashed`
    /// (the agent's executor died) and every transport defect are
    /// retryable crashes; total silence past the deadline kills the
    /// lease.
    ///
    /// Two fleet duties ride the same wait loop: a `BlobRequest` from
    /// the agent (it lacks a staged artifact this run references) is
    /// answered from `blobs` on the same id, and when `aborted` flips
    /// the slot sends [`Frame::Cancel`] so the agent kills the orphaned
    /// worker child instead of letting it train to completion for a
    /// campaign that no longer exists.
    ///
    /// With `journal` set, blob staging lands as `blob.request` /
    /// `blob.staged` journal events, and `stream` additionally asks the
    /// agent to relay its worker child's observer event lines back as
    /// proto-v6 `events` frames — merged into the journal tagged
    /// `origin:"agent:<addr>"`.  Both are best-effort observers: they
    /// never change the outcome.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run(
        &self,
        cfg: &crate::config::ExperimentConfig,
        trace: Option<&str>,
        heartbeat_timeout: Duration,
        blobs: &BlobCatalog,
        aborted: &AtomicBool,
        journal: Option<&crate::obs::Journal>,
        stream: bool,
    ) -> Outcome {
        if self.is_dead() {
            return Outcome::Crashed(anyhow!("agent {} connection already lost", self.addr));
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let frame = Frame::RunRequest {
            id,
            cfg: cfg.clone(),
            trace: trace.map(str::to_string),
            stream: stream && journal.is_some(),
        };
        let bytes = match transport::encode_frame(&frame) {
            Ok(b) => b,
            // an unserializable config is the run's fault, not the agent's
            Err(e) => return Outcome::RunFailed(e),
        };
        let (tx, rx) = mpsc::channel();
        self.pending.lock().expect("remote pending map").insert(id, tx);
        let _guard = PendingGuard { pending: &*self.pending, id };
        {
            let mut w = self.writer.lock().expect("remote writer");
            if let Err(e) = w.write_all(&bytes).and_then(|()| w.flush()) {
                self.kill("write failed");
                return Outcome::Crashed(anyhow!(
                    "agent {} connection lost while submitting run: {e}",
                    self.addr
                ));
            }
        }
        // re-check after registering: if the reader died between the
        // entry check and our insert, it already cleared the demux map
        // (dead is stored *before* the clear), and a write to the
        // half-closed socket can still "succeed" — without this check
        // the slot would stall a full heartbeat_timeout before
        // requeueing a run the connection can never answer
        if self.is_dead() {
            return Outcome::Crashed(anyhow!(
                "agent {} connection lost while submitting run",
                self.addr
            ));
        }
        let mut deadline = Instant::now() + heartbeat_timeout;
        let mut seen_tick = self.rx_tick.load(Ordering::Relaxed);
        loop {
            // wake at least every 250ms so a campaign abort turns into
            // a prompt Cancel instead of waiting out the deadline
            let wait = deadline
                .saturating_duration_since(Instant::now())
                .min(Duration::from_millis(250));
            let frame = match rx.recv_timeout(wait) {
                Ok(frame) => frame,
                Err(RecvTimeoutError::Timeout) => {
                    if aborted.load(Ordering::SeqCst) {
                        // the campaign is over: tell the agent to kill
                        // the orphaned worker child — nobody will ever
                        // read its result
                        let _ = self.send_frame(&Frame::Cancel { id });
                        return Outcome::Crashed(anyhow!(
                            "run id {id} abandoned (campaign aborted); \
                             cancel sent to agent {}",
                            self.addr
                        ));
                    }
                    if Instant::now() < deadline {
                        continue;
                    }
                    // no complete frame — but byte progress counts as
                    // liveness too: a multi-MB terminal frame crossing a
                    // slow link (which also blocks sibling heartbeats
                    // behind the agent's writer lock) must not be
                    // mistaken for a hung agent
                    let tick = self.rx_tick.load(Ordering::Relaxed);
                    if tick != seen_tick {
                        seen_tick = tick;
                        deadline = Instant::now() + heartbeat_timeout;
                        continue;
                    }
                    self.kill("missed heartbeat deadline");
                    return Outcome::Crashed(anyhow!(
                        "agent {} silent for {:.1}s during run id {id} \
                         (missed heartbeat deadline); lease killed, run requeued",
                        self.addr,
                        heartbeat_timeout.as_secs_f64()
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Outcome::Crashed(anyhow!(
                        "agent {} connection lost mid-run",
                        self.addr
                    ))
                }
            };
            // any frame for our id proves the agent is making progress
            deadline = Instant::now() + heartbeat_timeout;
            match frame {
                Frame::Heartbeat { .. } => continue,
                Frame::Events { lines, .. } => {
                    // relayed observer lines from the agent's worker
                    // child: merge into the journal with the agent as
                    // origin; with no journal attached the batch is
                    // counted as dropped (we asked for nothing, the
                    // agent streamed anyway)
                    match journal {
                        Some(j) => {
                            j.merge_lines(&lines, &format!("agent:{}", self.addr));
                        }
                        None => crate::obs::metrics()
                            .counter("obs.event_drops")
                            .add(lines.len() as u64),
                    }
                    continue;
                }
                Frame::BlobRequest { digest, .. } => {
                    // the agent lacks an artifact this run references:
                    // answer on the same id from the catalog (a digest
                    // we never staged gets an Error the agent surfaces
                    // as the run's own failure)
                    if let Some(j) = journal {
                        j.emit(
                            "blob.request",
                            trace,
                            vec![
                                ("digest", crate::util::json::Json::str(digest.clone())),
                                ("agent", crate::util::json::Json::str(self.addr.clone())),
                            ],
                        );
                    }
                    let answer = match blobs.read(&digest) {
                        Ok(bytes) => {
                            println!(
                                "dispatch: staging blob {digest} ({} bytes) to agent {}",
                                bytes.len(),
                                self.addr
                            );
                            crate::obs::metrics()
                                .counter("dispatch.blob_bytes_staged")
                                .add(bytes.len() as u64);
                            if let Some(j) = journal {
                                j.emit(
                                    "blob.staged",
                                    trace,
                                    vec![
                                        ("digest", crate::util::json::Json::str(digest.clone())),
                                        ("bytes", crate::util::json::Json::num(bytes.len() as f64)),
                                        (
                                            "agent",
                                            crate::util::json::Json::str(self.addr.clone()),
                                        ),
                                    ],
                                );
                            }
                            Frame::Blob { id, tag: digest.clone(), bytes }
                        }
                        Err(e) => Frame::Error { id, message: format!("{e:#}") },
                    };
                    if let Err(e) = self.send_frame(&answer) {
                        self.kill("write failed");
                        return Outcome::Crashed(anyhow!(
                            "agent {} connection lost while staging blob {digest}: {e:#}",
                            self.addr
                        ));
                    }
                    continue;
                }
                Frame::RunResult { report, .. } => return Outcome::Done(report),
                Frame::Error { message, .. } => {
                    return Outcome::RunFailed(anyhow!("{message}"))
                }
                Frame::Crashed { message, .. } => {
                    return Outcome::Crashed(anyhow!(
                        "agent {} reported an executor crash: {message}",
                        self.addr
                    ))
                }
                other => {
                    return Outcome::Crashed(anyhow!(
                        "agent {} protocol violation: unexpected {} frame for request {id}",
                        self.addr,
                        other.kind()
                    ))
                }
            }
        }
    }

    /// Ask the agent for its live stats snapshot (`adpsgd status`): a
    /// proto-v5 [`Frame::StatsRequest`] answered by [`Frame::Stats`]
    /// carrying an opaque JSON object — advertised slots, in-flight
    /// runs, cache hit counters, and the agent's full
    /// [`crate::obs::metrics`] snapshot.  Rides the same demux table as
    /// run frames, so it can interleave with in-flight runs on the
    /// shared connection.
    pub fn stats(&self, timeout: Duration) -> Result<crate::util::json::Json> {
        if self.is_dead() {
            bail!("agent {} connection already lost", self.addr);
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let (tx, rx) = mpsc::channel();
        self.pending.lock().expect("remote pending map").insert(id, tx);
        let _guard = PendingGuard { pending: &*self.pending, id };
        self.send_frame(&Frame::StatsRequest { id })?;
        loop {
            match rx.recv_timeout(timeout) {
                Ok(Frame::Stats { stats, .. }) => return Ok(stats),
                Ok(Frame::Heartbeat { .. }) => continue,
                Ok(Frame::Error { message, .. }) => {
                    bail!("agent {} refused the stats request: {message}", self.addr)
                }
                Ok(other) => bail!(
                    "agent {} protocol violation: unexpected {} frame for stats request {id}",
                    self.addr,
                    other.kind()
                ),
                Err(RecvTimeoutError::Timeout) => {
                    bail!("agent {} did not answer the stats request within {:.1}s",
                        self.addr, timeout.as_secs_f64())
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("agent {} connection lost awaiting stats", self.addr)
                }
            }
        }
    }
}

impl Drop for RemoteAgentClient {
    fn drop(&mut self) {
        // normal end-of-dispatch teardown: closing the underlying
        // socket (shared by the reader thread's clone) unblocks and
        // exits the reader and ends the agent-side session — without
        // this, every dispatch would leak a parked thread and an open
        // connection per agent
        self.dead.store(true, Ordering::SeqCst);
        self.stream.shutdown(Shutdown::Both).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::proto::VersionSkew;
    use std::net::TcpListener;

    fn raw_frame(json: &str) -> Vec<u8> {
        let mut buf = (json.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(json.as_bytes());
        buf
    }

    /// A fake agent that opens with a well-formed challenge, drains the
    /// client's proof, then answers the handshake with raw bytes.
    fn fake_agent(response: &'static [u8]) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let challenge =
                    (Frame::Challenge { nonce: "fake-nonce".into() }).to_line().unwrap();
                let _ = s.write_all(&raw_frame(&challenge));
                let _ = s.flush();
                // drain the hello proof so the client's write cannot
                // fail before it sees our response
                let _ = transport::read_frame(&mut s.try_clone().unwrap());
                let _ = s.write_all(response);
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(200));
            }
        });
        addr
    }

    /// A fake peer that writes raw bytes the moment the connection
    /// opens (the client reads the challenge first now, so a skewed or
    /// defective peer surfaces on that very first frame).
    fn fake_raw_peer(first: &'static [u8]) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let _ = s.write_all(first);
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(200));
            }
        });
        addr
    }

    #[test]
    fn handshake_accepts_ack_and_reports_capacity() {
        let line = (Frame::HelloAck { slots: 5 }).to_line().unwrap();
        let bytes: &'static [u8] = Box::leak(raw_frame(&line).into_boxed_slice());
        let addr = fake_agent(bytes);
        let client =
            RemoteAgentClient::connect(&addr, None, Duration::from_secs(5)).unwrap();
        assert_eq!(client.slots(), 5);
        assert!(!client.is_dead());
    }

    #[test]
    fn handshake_version_skew_is_a_clear_error() {
        let bytes: &'static [u8] = Box::leak(
            raw_frame("{\"type\":\"challenge\",\"nonce\":\"n\",\"v\":1}").into_boxed_slice(),
        );
        let addr = fake_raw_peer(bytes);
        let err = RemoteAgentClient::connect(&addr, None, Duration::from_secs(5))
            .err()
            .expect("a version-skewed peer must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("protocol version skew"), "{msg}");
        assert!(err.is::<VersionSkew>(), "{msg}");
    }

    #[test]
    fn handshake_answers_the_challenge_without_leaking_the_token() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let challenge =
                    (Frame::Challenge { nonce: "nonce-xyz".into() }).to_line().unwrap();
                let _ = s.write_all(&raw_frame(&challenge));
                let _ = s.flush();
                // capture the client's answer as raw wire bytes
                use std::io::Read;
                let mut len = [0u8; 4];
                if s.read_exact(&mut len).is_ok() {
                    let mut body = vec![0u8; u32::from_be_bytes(len) as usize];
                    if s.read_exact(&mut body).is_ok() {
                        let _ = tx.send(body);
                    }
                }
                let ack = (Frame::HelloAck { slots: 1 }).to_line().unwrap();
                let _ = s.write_all(&raw_frame(&ack));
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(100));
            }
        });
        let secret = "hunter2-super-secret";
        let client =
            RemoteAgentClient::connect(&addr, Some(secret), Duration::from_secs(5)).unwrap();
        assert_eq!(client.slots(), 1);
        let hello = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let text = String::from_utf8_lossy(&hello).into_owned();
        assert!(text.contains("hello"), "{text}");
        assert!(
            !text.contains(secret),
            "the shared secret must never travel the wire: {text}"
        );
        // and the answer is exactly the keyed digest over the nonce
        assert!(text.contains(&auth_proof("nonce-xyz", secret)), "{text}");
    }

    #[test]
    fn handshake_rejection_carries_the_agents_message() {
        let line =
            (Frame::Error { id: 0, message: "agent: invalid shared-secret token".into() })
                .to_line()
                .unwrap();
        let bytes: &'static [u8] = Box::leak(raw_frame(&line).into_boxed_slice());
        let addr = fake_agent(bytes);
        let err = RemoteAgentClient::connect(&addr, Some("wrong"), Duration::from_secs(5))
            .err()
            .expect("a rejected handshake must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("token"), "{msg}");
        assert!(msg.contains("rejected"), "{msg}");
    }

    #[test]
    fn unreachable_agent_is_a_connect_error() {
        // a port from the ephemeral range with nothing bound: connect
        // must fail with the address in the message
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let err = RemoteAgentClient::connect(&addr, None, Duration::from_millis(500))
            .err()
            .expect("nothing is listening");
        assert!(format!("{err:#}").contains(&addr), "{err:#}");
    }
}
