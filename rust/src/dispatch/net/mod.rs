//! The remote worker fabric: `adpsgd agent` daemons serving campaign
//! runs over TCP.
//!
//! The stdin/stdout `adpsgd worker` protocol ([`super::proto`]) is
//! process-agnostic by design; this module carries the same frames over
//! a length-delimited TCP transport ([`transport`]) so dispatch
//! capacity can live on other machines:
//!
//! * [`agent`] — the `adpsgd agent --listen ADDR --slots N` daemon.  It
//!   accepts connections, authenticates them with a `Hello`/`HelloAck`
//!   handshake (protocol version, optional shared-secret token,
//!   advertised slot capacity), and serves many concurrent runs per
//!   connection (frames are tagged by request id).  Runs execute in
//!   warm `adpsgd worker` children checked out of a [`super::pool::WorkerPool`]
//!   — the same supervision as local subprocess dispatch — and the
//!   agent probes its own [`super::runcache::RunCache`] first, so a
//!   warm agent answers repeats without recomputation.
//! * [`client`] — the dispatcher side: [`client::RemoteAgentClient`]
//!   multiplexes one connection across that agent's advertised slots,
//!   with the same deadline-aware supervision as a local child (a
//!   silent or disconnected agent is treated exactly like a hung
//!   worker: the lease is killed and in-flight runs requeue onto the
//!   surviving slots; stale terminal frames are discarded).
//!
//! Remote slots plug into [`super::pool::Dispatcher`]'s work-stealing
//! queue next to thread/subprocess slots (`--workers remote`,
//! `--remote host:port[,host:port...]`; listing agents while keeping
//! local workers gives the mixed pool).  Because the merge is the same
//! deterministic declaration-order merge, a remote campaign's stable
//! summary is byte-identical to a local one.

pub mod agent;
pub mod client;
pub mod transport;

pub use agent::{Agent, AgentConfig};
pub use client::RemoteAgentClient;

/// How long connection setup (TCP connect + `Hello`/`HelloAck`) may
/// take before an agent is declared unreachable.  Generous: handshakes
/// are two small frames; only a dead host or a firewall sinkhole gets
/// near this.
pub const HANDSHAKE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);
