//! Length-delimited framing of [`proto::Frame`]s for stream transports.
//!
//! The stdin/stdout worker protocol is newline-delimited; over TCP the
//! same JSON frames travel length-delimited instead — a 4-byte
//! big-endian length prefix followed by the frame's JSON bytes — so a
//! reader never has to scan for a delimiter and a parse error never
//! loses framing (the next frame boundary is always known, which is why
//! an agent can answer a malformed frame instead of dropping the
//! connection).  [`MAX_FRAME_BYTES`] bounds the prefix so a stray
//! non-adpsgd peer cannot make the reader allocate gigabytes.

use crate::dispatch::proto::Frame;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Upper bound on one frame's payload.  A full `RunResult` report with
/// every recorded series is a few MB at paper scale; 256 MiB is a
/// sanity bound against garbage length prefixes, not a real limit.
pub const MAX_FRAME_BYTES: u32 = 256 * 1024 * 1024;

/// Encode one frame as its wire bytes (length prefix + JSON payload),
/// ready for a single `write_all`.  Writers that share a stream across
/// threads encode first and write the returned buffer under their lock,
/// so frames can never interleave mid-payload.
pub fn encode_frame(frame: &Frame) -> Result<Vec<u8>> {
    let line = frame.to_line()?;
    let payload = line.as_bytes();
    if payload.len() as u64 > MAX_FRAME_BYTES as u64 {
        bail!("frame too large to encode: {} bytes (max {MAX_FRAME_BYTES})", payload.len());
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    Ok(buf)
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let buf = encode_frame(frame)?;
    w.write_all(&buf).context("writing frame")?;
    w.flush().context("flushing frame")
}

/// Read the 4-byte length header; `None` on a clean EOF at a frame
/// boundary, an error on EOF mid-header.
fn read_header(r: &mut impl Read) -> Result<Option<[u8; 4]>> {
    let mut buf = [0u8; 4];
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..]).context("reading frame header")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-frame header");
        }
        got += n;
    }
    Ok(Some(buf))
}

/// Read one frame; `Ok(None)` on clean EOF.  An implausible length
/// prefix (zero, or past [`MAX_FRAME_BYTES`]) is diagnosed as a
/// non-adpsgd peer instead of an allocation attempt; a payload that
/// fails [`Frame::parse`] carries the parser's error (including the
/// typed version-skew diagnosis) without losing stream framing.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let Some(header) = read_header(r)? else {
        return Ok(None);
    };
    let len = u32::from_be_bytes(header);
    if len == 0 || len > MAX_FRAME_BYTES {
        bail!("implausible frame length {len} (is the peer an adpsgd agent/client?)");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let line = std::str::from_utf8(&payload).context("frame payload is not UTF-8")?;
    Frame::parse(line).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_length_delimited() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Frame::Heartbeat { id: 5 }).unwrap();
        write_frame(&mut buf, &Frame::Hello { token: "t".into() }).unwrap();
        write_frame(&mut buf, &Frame::HelloAck { slots: 3 }).unwrap();
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Heartbeat { id: 5 })));
        match read_frame(&mut r).unwrap() {
            Some(Frame::Hello { token }) => assert_eq!(token, "t"),
            other => panic!("wrong frame {other:?}"),
        }
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::HelloAck { slots: 3 })));
        // clean EOF at a boundary
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncation_and_garbage_lengths_are_errors() {
        // EOF mid-header
        let mut r = Cursor::new(vec![0u8, 0]);
        assert!(read_frame(&mut r).unwrap_err().to_string().contains("mid-frame header"));
        // EOF mid-payload
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Frame::Heartbeat { id: 1 }).unwrap();
        buf.truncate(buf.len() - 2);
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // an implausible length prefix must not allocate
        let mut r = Cursor::new(u32::MAX.to_be_bytes().to_vec());
        let err = format!("{:#}", read_frame(&mut r).unwrap_err());
        assert!(err.contains("implausible frame length"), "{err}");
        // zero length is equally implausible
        let mut r = Cursor::new(0u32.to_be_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn version_skew_survives_the_framing() {
        let payload = b"{\"type\":\"heartbeat\",\"id\":1,\"v\":999}";
        let mut buf = (payload.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(payload);
        let mut r = Cursor::new(buf);
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.is::<crate::dispatch::proto::VersionSkew>(), "{err:#}");
    }
}
