//! Length-delimited framing of [`proto::Frame`]s for stream transports.
//!
//! The stdin/stdout worker protocol is newline-delimited; over TCP the
//! same frames travel length-delimited instead — a 4-byte big-endian
//! length prefix followed by the frame's payload bytes — so a reader
//! never has to scan for a delimiter and a parse error never loses
//! framing (the next frame boundary is always known, which is why an
//! agent can answer a malformed frame instead of dropping the
//! connection).  [`MAX_FRAME_BYTES`] bounds the prefix so a stray
//! non-adpsgd peer cannot make the reader allocate gigabytes.
//!
//! ## Payload forms (proto v3)
//!
//! Control frames (requests, heartbeats, errors, handshakes) stay JSON,
//! byte-for-byte the same line the stdio path would emit.  The two bulk
//! frames — [`Frame::RunResult`] and [`Frame::Blob`] — are encoded
//! *binary* instead: a leading `0x00` marker byte (a JSON payload always
//! starts with `{`, so the two forms can never be confused), a kind
//! byte, the protocol version, the request id, then the raw bytes (the
//! report's [`report_to_bytes`] form, or the blob's bytes verbatim).
//! This skips JSON float formatting and parsing for multi-MB metric
//! series entirely.  The version travels inside the binary payload too,
//! and is checked *before* the kind byte, so cross-version peers still
//! get the typed [`VersionSkew`] "rebuild both ends" diagnosis.

use crate::dispatch::proto::{Frame, VersionSkew, PROTO_VERSION};
use crate::dispatch::runcache::{report_from_bytes, report_to_bytes};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// First payload byte of a binary frame.  JSON payloads always begin
/// with `'{'` (0x7b), so 0x00 unambiguously marks the binary form.
const BIN_MARKER: u8 = 0x00;
/// Kind byte: the payload after the header is a [`report_to_bytes`]
/// run report.
const BIN_RUN_RESULT: u8 = 1;
/// Kind byte: the payload after the header is a tagged byte blob
/// (u16 BE tag length, tag UTF-8, then the bytes verbatim).
const BIN_BLOB: u8 = 2;
/// Bytes before the kind-specific body: marker, kind, u32 version,
/// u64 id.
const BIN_HEADER_BYTES: usize = 1 + 1 + 4 + 8;

/// Upper bound on one frame's payload.  A full `RunResult` report with
/// every recorded series is a few MB at paper scale; 256 MiB is a
/// sanity bound against garbage length prefixes, not a real limit.
pub const MAX_FRAME_BYTES: u32 = 256 * 1024 * 1024;

/// Encode one frame as its wire bytes (length prefix + payload), ready
/// for a single `write_all`.  Bulk frames get the binary payload form,
/// everything else its JSON line.  Writers that share a stream across
/// threads encode first and write the returned buffer under their lock,
/// so frames can never interleave mid-payload.
pub fn encode_frame(frame: &Frame) -> Result<Vec<u8>> {
    let payload = match frame {
        Frame::RunResult { id, report } => {
            binary_payload(BIN_RUN_RESULT, *id, &[], &report_to_bytes(report)?)
        }
        Frame::Blob { id, tag, bytes } => {
            let tag_len = u16::try_from(tag.len())
                .with_context(|| format!("blob tag too long: {} bytes", tag.len()))?;
            let mut head = tag_len.to_be_bytes().to_vec();
            head.extend_from_slice(tag.as_bytes());
            binary_payload(BIN_BLOB, *id, &head, bytes)
        }
        other => other.to_line()?.into_bytes(),
    };
    if payload.len() as u64 > MAX_FRAME_BYTES as u64 {
        bail!("frame too large to encode: {} bytes (max {MAX_FRAME_BYTES})", payload.len());
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// Assemble a binary payload: marker, kind, version, id, then the
/// kind-specific head and body.
fn binary_payload(kind: u8, id: u64, head: &[u8], body: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(BIN_HEADER_BYTES + head.len() + body.len());
    buf.push(BIN_MARKER);
    buf.push(kind);
    buf.extend_from_slice(&(PROTO_VERSION as u32).to_be_bytes());
    buf.extend_from_slice(&id.to_be_bytes());
    buf.extend_from_slice(head);
    buf.extend_from_slice(body);
    buf
}

/// Decode a binary payload (first byte [`BIN_MARKER`]) back into a
/// frame.  The version field is checked before the kind byte so a
/// cross-version peer always gets the typed skew error, even if the
/// other end grew kinds we don't know.
fn parse_binary(payload: &[u8]) -> Result<Frame> {
    if payload.len() < BIN_HEADER_BYTES {
        bail!("binary frame truncated: {} bytes (header is {BIN_HEADER_BYTES})", payload.len());
    }
    let version = u32::from_be_bytes(payload[2..6].try_into().expect("4 bytes")) as u64;
    if version != PROTO_VERSION {
        return Err(anyhow::Error::new(VersionSkew { got: Some(version) }));
    }
    let id = u64::from_be_bytes(payload[6..14].try_into().expect("8 bytes"));
    let body = &payload[BIN_HEADER_BYTES..];
    match payload[1] {
        BIN_RUN_RESULT => {
            let report = report_from_bytes(body).context("binary run_result payload")?;
            Ok(Frame::RunResult { id, report })
        }
        BIN_BLOB => {
            if body.len() < 2 {
                bail!("binary blob frame truncated: missing tag length");
            }
            let tag_len = u16::from_be_bytes(body[..2].try_into().expect("2 bytes")) as usize;
            let Some(tag_bytes) = body.get(2..2 + tag_len) else {
                bail!("binary blob frame truncated: tag length {tag_len} exceeds payload");
            };
            let tag = std::str::from_utf8(tag_bytes).context("blob tag is not UTF-8")?;
            Ok(Frame::Blob {
                id,
                tag: tag.to_string(),
                bytes: body[2 + tag_len..].to_vec(),
            })
        }
        other => bail!("binary frame: unknown kind byte {other}"),
    }
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let buf = encode_frame(frame)?;
    w.write_all(&buf).context("writing frame")?;
    w.flush().context("flushing frame")
}

/// Read the 4-byte length header; `None` on a clean EOF at a frame
/// boundary, an error on EOF mid-header.
fn read_header(r: &mut impl Read) -> Result<Option<[u8; 4]>> {
    let mut buf = [0u8; 4];
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..]).context("reading frame header")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-frame header");
        }
        got += n;
    }
    Ok(Some(buf))
}

/// Read one frame; `Ok(None)` on clean EOF.  An implausible length
/// prefix (zero, or past [`MAX_FRAME_BYTES`]) is diagnosed as a
/// non-adpsgd peer instead of an allocation attempt; a payload that
/// fails to parse carries the parser's error (including the typed
/// version-skew diagnosis) without losing stream framing.  The first
/// payload byte dispatches between the binary bulk form ([`BIN_MARKER`])
/// and a JSON control frame.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let Some(header) = read_header(r)? else {
        return Ok(None);
    };
    let len = u32::from_be_bytes(header);
    if len == 0 || len > MAX_FRAME_BYTES {
        bail!("implausible frame length {len} (is the peer an adpsgd agent/client?)");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).context("reading frame payload")?;
    if payload.first() == Some(&BIN_MARKER) {
        return parse_binary(&payload).map(Some);
    }
    let line = std::str::from_utf8(&payload).context("frame payload is not UTF-8")?;
    Frame::parse(line).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_length_delimited() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Frame::Heartbeat { id: 5 }).unwrap();
        write_frame(&mut buf, &Frame::Hello { proof: "p".into() }).unwrap();
        write_frame(&mut buf, &Frame::HelloAck { slots: 3 }).unwrap();
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::Heartbeat { id: 5 })));
        match read_frame(&mut r).unwrap() {
            Some(Frame::Hello { proof }) => assert_eq!(proof, "p"),
            other => panic!("wrong frame {other:?}"),
        }
        assert!(matches!(read_frame(&mut r).unwrap(), Some(Frame::HelloAck { slots: 3 })));
        // clean EOF at a boundary
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncation_and_garbage_lengths_are_errors() {
        // EOF mid-header
        let mut r = Cursor::new(vec![0u8, 0]);
        assert!(read_frame(&mut r).unwrap_err().to_string().contains("mid-frame header"));
        // EOF mid-payload
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Frame::Heartbeat { id: 1 }).unwrap();
        buf.truncate(buf.len() - 2);
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // an implausible length prefix must not allocate
        let mut r = Cursor::new(u32::MAX.to_be_bytes().to_vec());
        let err = format!("{:#}", read_frame(&mut r).unwrap_err());
        assert!(err.contains("implausible frame length"), "{err}");
        // zero length is equally implausible
        let mut r = Cursor::new(0u32.to_be_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn version_skew_survives_the_framing() {
        let payload = b"{\"type\":\"heartbeat\",\"id\":1,\"v\":999}";
        let mut buf = (payload.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(payload);
        let mut r = Cursor::new(buf);
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.is::<crate::dispatch::proto::VersionSkew>(), "{err:#}");
    }

    fn sample_report() -> crate::coordinator::RunReport {
        let mut recorder = crate::metrics::Recorder::new();
        for i in 0..200 {
            recorder.push("train_loss", i as f64, 1.0 / (i + 1) as f64);
        }
        recorder.push("eval_acc", 50.0, 0.75);
        crate::coordinator::RunReport {
            name: "wire".into(),
            strategy: crate::period::Strategy::Constant,
            nodes: 4,
            iters: 200,
            n_params: 1000,
            final_train_loss: 0.1,
            min_train_loss: 0.05,
            best_eval_acc: 0.9,
            final_eval_acc: 0.85,
            final_eval_loss: 0.3,
            syncs: 20,
            avg_period: 10.0,
            compute_secs: 1.0,
            wall_secs: 1.5,
            ledger: crate::netsim::CommLedger::new(4),
            recorder,
        }
    }

    #[test]
    fn bulk_frames_roundtrip_binary() {
        use crate::dispatch::runcache::report_to_json;
        let report = sample_report();
        let canonical = report_to_json(&report).to_string_compact();
        let blob_bytes: Vec<u8> = (0u16..=255).map(|b| b as u8).collect();
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Frame::RunResult { id: 21, report }).unwrap();
        write_frame(
            &mut buf,
            &Frame::Blob { id: 22, tag: "snapshot".into(), bytes: blob_bytes.clone() },
        )
        .unwrap();
        // both payloads took the binary form (marker right after the prefix)
        assert_eq!(buf[4], BIN_MARKER);

        let mut r = Cursor::new(buf);
        match read_frame(&mut r).unwrap() {
            Some(Frame::RunResult { id, report: back }) => {
                assert_eq!(id, 21);
                assert_eq!(
                    report_to_json(&back).to_string_compact(),
                    canonical,
                    "binary transit must reproduce the exact canonical report"
                );
                // and the binary payload beats the JSON line on the wire
                let frame = Frame::RunResult { id, report: back };
                let bin = encode_frame(&frame).unwrap();
                let json = frame.to_line().unwrap();
                assert!(
                    bin.len() < json.len(),
                    "binary ({}) should be smaller than JSON ({})",
                    bin.len(),
                    json.len()
                );
            }
            other => panic!("wrong frame {other:?}"),
        }
        match read_frame(&mut r).unwrap() {
            Some(Frame::Blob { id, tag, bytes }) => {
                assert_eq!((id, tag.as_str()), (22, "snapshot"));
                assert_eq!(bytes, blob_bytes);
            }
            other => panic!("wrong frame {other:?}"),
        }
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn binary_truncation_and_unknown_kinds_are_errors() {
        let frame = Frame::Blob { id: 7, tag: "t".into(), bytes: vec![1, 2, 3] };
        let buf = encode_frame(&frame).unwrap();
        let payload = &buf[4..];
        // every strict prefix of the payload fails cleanly
        for cut in [0, 1, 5, BIN_HEADER_BYTES - 1, BIN_HEADER_BYTES, BIN_HEADER_BYTES + 1] {
            assert!(parse_binary(&payload[..cut]).is_err(), "prefix of {cut} bytes");
        }
        // a tag length pointing past the payload is caught, not a panic
        let mut bad = payload.to_vec();
        bad[BIN_HEADER_BYTES] = 0xff;
        bad[BIN_HEADER_BYTES + 1] = 0xff;
        let err = parse_binary(&bad).unwrap_err().to_string();
        assert!(err.contains("exceeds payload"), "{err}");
        // an unknown kind byte is a clear error
        let mut unknown = payload.to_vec();
        unknown[1] = 99;
        let err = parse_binary(&unknown).unwrap_err().to_string();
        assert!(err.contains("unknown kind byte"), "{err}");
    }

    #[test]
    fn binary_version_skew_is_the_same_typed_error() {
        let frame = Frame::Blob { id: 7, tag: "t".into(), bytes: vec![9] };
        let mut buf = encode_frame(&frame).unwrap();
        // rewrite the version field (payload bytes 2..6, after the prefix)
        buf[4 + 2..4 + 6].copy_from_slice(&999u32.to_be_bytes());
        let mut r = Cursor::new(buf);
        let err = read_frame(&mut r).unwrap_err();
        let skew = err.downcast_ref::<crate::dispatch::proto::VersionSkew>();
        assert_eq!(skew.map(|s| s.got), Some(Some(999)), "{err:#}");
    }
}
