//! The worker pool: a work-stealing run queue drained by in-process
//! thread slots or `adpsgd worker` subprocess slots, with cache
//! short-circuiting, crashed-worker retry, and a deterministic merge.
//!
//! Scheduling is a shared queue: every slot pops the next pending run,
//! so a slow run never blocks the others (work stealing without
//! per-slot queues).  Results land in per-run slots indexed by
//! declaration order, so the merged output is identical for any `jobs`
//! level and any completion order.  A *deterministic* run failure
//! aborts the dispatch (queued runs are not started; in-flight runs
//! finish) — exactly the historical campaign semantics.  A *crashed*
//! subprocess worker (pipe EOF, spawn failure) is not a run failure:
//! the run is re-queued for any free slot (the crashing slot respawns a
//! fresh child) up to [`DispatchOptions::max_attempts`] attempts.

use super::runcache::{self, RunCache};
use crate::coordinator::RunReport;
use crate::experiment::{Experiment, RunSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Where a pending run executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerKind {
    /// In-process: each slot runs the experiment on its own thread (the
    /// run itself still spawns its `nodes`-thread cluster).
    Thread,
    /// Out-of-process: each slot owns an `adpsgd worker` child speaking
    /// the line-delimited JSON protocol of [`super::proto`].
    Subprocess,
}

/// How a dispatch executes: slot count, worker kind, cache, retries.
#[derive(Debug, Clone)]
pub struct DispatchOptions {
    /// Concurrent run slots; `None` = `min(available cores, runs)`.
    pub jobs: Option<usize>,
    pub workers: WorkerKind,
    /// Run-cache directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Attempts per run before a crashing worker fails the dispatch.
    pub max_attempts: usize,
    /// Binary for subprocess workers; `None` = this executable.
    pub worker_exe: Option<PathBuf>,
}

impl Default for DispatchOptions {
    fn default() -> Self {
        DispatchOptions {
            jobs: None,
            workers: WorkerKind::Thread,
            cache_dir: super::default_cache_dir(),
            max_attempts: 3,
            worker_exe: None,
        }
    }
}

impl DispatchOptions {
    /// The conservative in-process profile [`crate::experiment::Campaign::run`]
    /// uses: a fixed slot count, thread workers, the process-default
    /// cache (usually disabled).
    pub fn in_process(jobs: usize) -> DispatchOptions {
        DispatchOptions { jobs: Some(jobs.max(1)), ..DispatchOptions::default() }
    }
}

/// One finished run out of the dispatcher.
pub struct DispatchedRun {
    pub report: RunReport,
    /// whether the report came from the run cache (no training executed)
    pub from_cache: bool,
}

/// Executes batches of [`RunSpec`]s under one [`DispatchOptions`]
/// profile.  Reusable across batches; exposes live worker pids and the
/// crash-retry count for observability (and the kill-a-worker tests).
pub struct Dispatcher {
    opts: DispatchOptions,
    pids: Arc<Mutex<Vec<u32>>>,
    retries: Arc<AtomicUsize>,
}

enum Outcome {
    Done(RunReport),
    RunFailed(anyhow::Error),
    Crashed(anyhow::Error),
}

impl Dispatcher {
    pub fn new(opts: DispatchOptions) -> Dispatcher {
        Dispatcher { opts, pids: Arc::new(Mutex::new(Vec::new())), retries: Arc::new(AtomicUsize::new(0)) }
    }

    /// Live subprocess-worker pids (empty in thread mode).
    pub fn worker_pids(&self) -> Arc<Mutex<Vec<u32>>> {
        Arc::clone(&self.pids)
    }

    /// Crashed-worker retries performed so far.
    pub fn retries(&self) -> usize {
        self.retries.load(Ordering::Relaxed)
    }

    /// Execute every run, returning reports in declaration order
    /// regardless of completion order or parallelism.
    pub fn execute(&self, runs: &[RunSpec]) -> Result<Vec<DispatchedRun>> {
        let n = runs.len();
        if n == 0 {
            bail!("dispatch of zero runs");
        }
        let cache = self.opts.cache_dir.as_ref().map(RunCache::new);
        let slots: Vec<Mutex<Option<Result<DispatchedRun>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        // (digest, canonical text) per run — probed up front so hits
        // skip the queue entirely
        let mut keys: Vec<Option<(String, String)>> = (0..n).map(|_| None).collect();
        let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
        for (i, spec) in runs.iter().enumerate() {
            if let Some(cache) = &cache {
                let canonical = runcache::cfg_canonical_text(&spec.cfg)
                    .with_context(|| format!("hashing run {:?}", spec.label))?;
                let key = runcache::content_digest(canonical.as_bytes());
                if let Some(mut report) = cache.get(&key) {
                    // the name is excluded from the key (incidental):
                    // restamp it so cross-campaign hits report under the
                    // requesting label
                    report.name = spec.cfg.name.clone();
                    *slots[i].lock().expect("dispatch slot") =
                        Some(Ok(DispatchedRun { report, from_cache: true }));
                    continue;
                }
                keys[i] = Some((key, canonical));
            }
            pending.push_back((i, 1));
        }

        if !pending.is_empty() {
            let jobs = self
                .opts
                .jobs
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(usize::from).unwrap_or(2)
                })
                .clamp(1, pending.len());
            let queue = Mutex::new(pending);
            let aborted = AtomicBool::new(false);
            std::thread::scope(|scope| {
                for _ in 0..jobs {
                    scope.spawn(|| self.slot_loop(runs, &keys, cache.as_ref(), &queue, &aborted, &slots));
                }
            });
        }

        // deterministic merge: declaration order; the lowest-index real
        // failure wins over "skipped" noise
        let mut merged: Vec<Option<DispatchedRun>> = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        let mut skipped: Option<usize> = None;
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().expect("dispatch slot") {
                Some(Ok(run)) => merged.push(Some(run)),
                Some(Err(e)) => {
                    first_err.get_or_insert(e);
                    merged.push(None);
                }
                None => {
                    skipped.get_or_insert(i);
                    merged.push(None);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if let Some(i) = skipped {
            bail!("run {:?} was skipped after an earlier failure", runs[i].label);
        }
        Ok(merged.into_iter().map(|r| r.expect("all slots filled")).collect())
    }

    /// One slot: pop runs until the queue drains or the dispatch aborts.
    fn slot_loop(
        &self,
        runs: &[RunSpec],
        keys: &[Option<(String, String)>],
        cache: Option<&RunCache>,
        queue: &Mutex<VecDeque<(usize, usize)>>,
        aborted: &AtomicBool,
        slots: &[Mutex<Option<Result<DispatchedRun>>>],
    ) {
        let mut client: Option<WorkerClient> = None;
        loop {
            if aborted.load(Ordering::Relaxed) {
                break;
            }
            let Some((i, attempt)) = queue.lock().expect("dispatch queue").pop_front() else {
                break;
            };
            let spec = &runs[i];
            let outcome = match self.opts.workers {
                WorkerKind::Thread => {
                    match Experiment::from_config(spec.cfg.clone()).and_then(Experiment::run)
                    {
                        Ok(report) => Outcome::Done(report),
                        Err(e) => Outcome::RunFailed(e),
                    }
                }
                WorkerKind::Subprocess => {
                    self.subprocess_run(&mut client, &spec.cfg)
                }
            };
            match outcome {
                Outcome::Done(report) => {
                    if let (Some(cache), Some((key, canonical))) = (cache, &keys[i]) {
                        if let Err(e) = cache.put(key, canonical, &report) {
                            eprintln!("note: run cache write failed for {:?}: {e:#}", spec.label);
                        }
                    }
                    *slots[i].lock().expect("dispatch slot") =
                        Some(Ok(DispatchedRun { report, from_cache: false }));
                }
                Outcome::RunFailed(e) => {
                    aborted.store(true, Ordering::Relaxed);
                    *slots[i].lock().expect("dispatch slot") =
                        Some(Err(e.context(format!("run {:?}", spec.label))));
                }
                Outcome::Crashed(e) => {
                    // the child is gone: drop it and respawn lazily on
                    // the next pop; the run goes back to *any* slot
                    client = None;
                    if attempt < self.opts.max_attempts {
                        self.retries.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "note: worker crashed during run {:?} (attempt {attempt}); retrying: {e:#}",
                            spec.label
                        );
                        queue.lock().expect("dispatch queue").push_back((i, attempt + 1));
                    } else {
                        aborted.store(true, Ordering::Relaxed);
                        *slots[i].lock().expect("dispatch slot") = Some(Err(e.context(format!(
                            "run {:?} crashed its worker {} times",
                            spec.label, attempt
                        ))));
                    }
                }
            }
        }
    }

    fn subprocess_run(
        &self,
        client: &mut Option<WorkerClient>,
        cfg: &crate::config::ExperimentConfig,
    ) -> Outcome {
        if client.is_none() {
            match WorkerClient::spawn(self.opts.worker_exe.clone(), &self.pids) {
                Ok(c) => *client = Some(c),
                Err(e) => return Outcome::Crashed(e.context("spawning worker")),
            }
        }
        let c = client.as_mut().expect("worker client just ensured");
        c.run(cfg)
    }
}

/// One `adpsgd worker` child and its protocol channel.
struct WorkerClient {
    child: std::process::Child,
    stdin: std::process::ChildStdin,
    stdout: std::io::BufReader<std::process::ChildStdout>,
    next_id: u64,
    pids: Arc<Mutex<Vec<u32>>>,
}

impl WorkerClient {
    fn spawn(exe: Option<PathBuf>, pids: &Arc<Mutex<Vec<u32>>>) -> Result<WorkerClient> {
        let exe = match exe {
            Some(p) => p,
            None => std::env::current_exe().context("resolving worker executable")?,
        };
        let mut child = std::process::Command::new(&exe)
            .arg("worker")
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning {} worker", exe.display()))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = std::io::BufReader::new(child.stdout.take().expect("piped stdout"));
        pids.lock().expect("pid registry").push(child.id());
        Ok(WorkerClient { child, stdin, stdout, next_id: 0, pids: Arc::clone(pids) })
    }

    /// Submit one run and block for its terminal frame, tolerating
    /// heartbeats.  Any transport defect is a crash (retryable); an
    /// `Error` frame is a deterministic run failure (fatal).
    fn run(&mut self, cfg: &crate::config::ExperimentConfig) -> Outcome {
        self.next_id += 1;
        let id = self.next_id;
        let line = match (super::proto::Frame::RunRequest { id, cfg: cfg.clone() }).to_line() {
            Ok(l) => l,
            // an unserializable config is the run's fault, not the worker's
            Err(e) => return Outcome::RunFailed(e),
        };
        if let Err(e) = self.stdin.write_all(line.as_bytes()).and_then(|()| self.stdin.flush())
        {
            return Outcome::Crashed(anyhow!("worker pipe closed: {e}"));
        }
        loop {
            let mut reply = String::new();
            match self.stdout.read_line(&mut reply) {
                Ok(0) => return Outcome::Crashed(anyhow!("worker exited mid-run (pipe EOF)")),
                Ok(_) => {}
                Err(e) => return Outcome::Crashed(anyhow!("reading worker reply: {e}")),
            }
            match super::proto::Frame::parse(&reply) {
                Ok(super::proto::Frame::Heartbeat { .. }) => continue,
                Ok(super::proto::Frame::RunResult { id: rid, report }) if rid == id => {
                    return Outcome::Done(report)
                }
                Ok(super::proto::Frame::Error { id: rid, message }) if rid == id => {
                    return Outcome::RunFailed(anyhow!("{message}"))
                }
                Ok(other) => {
                    return Outcome::Crashed(anyhow!("worker protocol violation: {other:?}"))
                }
                Err(e) => return Outcome::Crashed(e.context("malformed worker reply")),
            }
        }
    }
}

impl Drop for WorkerClient {
    fn drop(&mut self) {
        let pid = self.child.id();
        self.child.kill().ok();
        self.child.wait().ok();
        self.pids.lock().expect("pid registry").retain(|p| *p != pid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, LrSchedule, StrategySpec};

    fn quick_cfg(name: &str, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = name.into();
        cfg.seed = seed;
        cfg.nodes = 2;
        cfg.iters = 30;
        cfg.batch_per_node = 8;
        cfg.eval_every = 15;
        cfg.workload.input_dim = 16;
        cfg.workload.hidden = 8;
        cfg.workload.eval_batches = 2;
        cfg.optim.schedule = LrSchedule::Const;
        StrategySpec::Constant { period: 3 }.apply_to(&mut cfg.sync);
        cfg
    }

    fn specs(n: usize) -> Vec<RunSpec> {
        (0..n)
            .map(|i| {
                let cfg = quick_cfg(&format!("r{i}"), 100 + i as u64);
                RunSpec { label: format!("r{i}"), cfg }
            })
            .collect()
    }

    #[test]
    fn thread_pool_merges_deterministically_across_jobs() {
        let run = |jobs: usize| {
            Dispatcher::new(DispatchOptions {
                jobs: Some(jobs),
                cache_dir: None,
                ..DispatchOptions::default()
            })
            .execute(&specs(6))
            .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.len(), 6);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.report.name, b.report.name);
            assert_eq!(a.report.final_train_loss, b.report.final_train_loss);
            assert_eq!(a.report.syncs, b.report.syncs);
            assert!(!a.from_cache && !b.from_cache);
        }
    }

    #[test]
    fn cache_hit_skips_execution_and_is_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("adpsgd_pool_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = DispatchOptions {
            jobs: Some(2),
            cache_dir: Some(dir.clone()),
            ..DispatchOptions::default()
        };
        let cold = Dispatcher::new(opts.clone()).execute(&specs(3)).unwrap();
        assert!(cold.iter().all(|r| !r.from_cache));
        let warm = Dispatcher::new(opts).execute(&specs(3)).unwrap();
        assert!(warm.iter().all(|r| r.from_cache), "second dispatch must be all hits");
        for (a, b) in cold.iter().zip(&warm) {
            let aj = runcache::report_to_json(&a.report).to_string_compact();
            let bj = runcache::report_to_json(&b.report).to_string_compact();
            assert_eq!(aj, bj, "cached report must be bit-identical");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failing_run_aborts_and_names_the_label() {
        let mut runs = specs(2);
        runs[1].cfg.workload.backend =
            crate::config::Backend::Native("failing:0:5".into());
        runs[1].label = "boom".into();
        runs[1].cfg.name = "boom".into();
        let err = Dispatcher::new(DispatchOptions {
            jobs: Some(1),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .execute(&runs)
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("injected failure"), "{msg}");
    }
}
