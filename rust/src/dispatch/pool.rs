//! The worker pool: a work-stealing run queue drained by in-process
//! thread slots, `adpsgd worker` subprocess slots, and/or remote
//! `adpsgd agent` slots, with cache short-circuiting, hang detection,
//! crashed-worker retry, and a deterministic merge.
//!
//! Scheduling is a shared queue: every slot pops the next pending run,
//! so a slow run never blocks the others (work stealing without
//! per-slot queues).  Cache probing happens on the slots themselves —
//! a fully-warm campaign parses its entries with `jobs`-way
//! parallelism instead of a serial pre-pass.  Results land in per-run
//! slots indexed by declaration order, so the merged output is
//! identical for any `jobs` level, any worker mix (local threads,
//! subprocess children, remote agents), and any completion order.  A
//! *deterministic* run failure aborts the dispatch (queued runs are not
//! started; in-flight runs finish) — exactly the historical campaign
//! semantics.  A *crashed* worker (pipe EOF, spawn failure, a missed
//! [`DispatchOptions::heartbeat_timeout`] deadline, an agent-reported
//! executor crash, or a lost agent connection) is not a run failure:
//! the run is re-queued for any free slot up to
//! [`DispatchOptions::max_attempts`] attempts.
//!
//! ## Remote slots
//!
//! [`DispatchOptions::remote`] leases slots on `adpsgd agent` daemons
//! (see [`super::net`]): each reachable agent contributes its
//! advertised capacity as slot threads that drain the *same* queue as
//! the local ones — mixed local+remote is simply both kinds of slot
//! popping one queue.  `--workers remote` disables local slots
//! entirely.  A remote slot whose agent connection dies requeues its
//! in-flight run through the ordinary crash path (it lands on a
//! surviving slot, local or remote) and then redials the agent under
//! [`super::fleet::Backoff`] — a restarted daemon rejoins mid-campaign
//! without redriving completed runs.  [`DispatchOptions::fleet`] adds
//! *elastic* membership on top: a registry is polled and slot threads
//! appear as agents announce themselves, so capacity can join a
//! campaign that is already running.
//!
//! ## Supervision
//!
//! Each subprocess client reads its child's stdout on a dedicated
//! reader thread and waits on a channel with a deadline, so a child
//! that hangs *without* closing its pipe (SIGSTOP, livelock, a wedged
//! syscall) is detected: after `heartbeat_timeout` of silence — the
//! worker proves liveness every [`super::proto::HEARTBEAT_EVERY`]
//! while training — the child is killed and the run retried through
//! the ordinary crash path.  Terminal frames that surface later for an
//! abandoned request id are discarded as stale, never misclassified as
//! protocol violations.
//!
//! ## The shared pool
//!
//! Subprocess children are owned by a [`WorkerPool`], not by the
//! dispatch that spawned them: when a dispatch drains its queue, each
//! slot checks its warm child back in, and the next dispatch (a
//! sequential campaign, the next `adpsgd figures` sweep) checks it out
//! again instead of respawning.  [`Dispatcher::new`] borrows the
//! process-wide [`super::shared_worker_pool`]; tests and benchmarks
//! can inject a private pool via [`Dispatcher::with_pool`].  Pool
//! teardown is graceful — stdin closes (the worker's serve loop exits
//! on EOF), then a bounded wait, then kill — instead of the historical
//! unconditional kill.

use super::fleet::{self, Backoff, BlobCatalog};
use super::net::client::RemoteAgentClient;
use super::runcache::RunCache;
use crate::coordinator::RunReport;
use crate::experiment::{Experiment, RunSpec};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::{HashSet, VecDeque};
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Where a pending run executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerKind {
    /// In-process: each slot runs the experiment on its own thread (the
    /// run itself still spawns its `nodes`-thread cluster).
    Thread,
    /// Out-of-process: each slot borrows an `adpsgd worker` child from
    /// the [`WorkerPool`], speaking the line-delimited JSON protocol of
    /// [`super::proto`].
    Subprocess,
    /// Off-machine only: no local slots; every run executes on an
    /// `adpsgd agent` listed in [`DispatchOptions::remote`].  (Listing
    /// agents while keeping `Thread`/`Subprocess` gives the *mixed*
    /// pool — local and remote slots drain the same queue.)
    Remote,
}

/// How many [`super::proto::HEARTBEAT_EVERY`] intervals a silent worker
/// may miss before the default deadline declares it hung.
const DEFAULT_MISSED_HEARTBEATS: u32 = 20;

/// How a dispatch executes: slot count, worker kind, cache, retries,
/// hang deadline.
#[derive(Debug, Clone)]
pub struct DispatchOptions {
    /// Concurrent run slots; `None` = `min(available cores, runs)`.
    pub jobs: Option<usize>,
    pub workers: WorkerKind,
    /// Run-cache directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Attempts per run before a crashing worker fails the dispatch.
    pub max_attempts: usize,
    /// Binary for subprocess workers; `None` = this executable.
    pub worker_exe: Option<PathBuf>,
    /// How long a subprocess worker (or a remote agent connection) may
    /// stay silent mid-run before it is declared hung, killed, and its
    /// run retried (the worker heartbeats every
    /// [`super::proto::HEARTBEAT_EVERY`]; the default allows
    /// [`DEFAULT_MISSED_HEARTBEATS`] missed intervals).
    /// `adpsgd campaign --hang-timeout SECS` sets it.
    pub heartbeat_timeout: Duration,
    /// `adpsgd agent` endpoints (`host:port`) to lease remote slots
    /// from.  Empty = local-only.  With `workers` = `Thread` or
    /// `Subprocess` this is the *mixed* pool; with
    /// [`WorkerKind::Remote`] it is the only capacity.  CLI:
    /// `--remote host:port[,host:port...]`.
    pub remote: Vec<String>,
    /// Shared secret proved in the challenge-response handshake (must
    /// match each agent's `--token`; the token itself never travels
    /// the wire — see [`super::proto::auth_proof`].  `None` proves an
    /// empty token, which only tokenless agents accept).  CLI:
    /// `--remote-token`.
    pub remote_token: Option<String>,
    /// Fleet registry (`host:port`) to resolve agent membership from,
    /// alongside any static [`DispatchOptions::remote`] list: members
    /// joining mid-campaign contribute slot threads as they announce,
    /// expired members stop being dialed.  CLI: `--fleet host:port`.
    pub fleet: Option<String>,
    /// Structured event journal ([`crate::obs::Journal`]) the dispatch
    /// appends to: per-run trace ids are minted when set, and every
    /// queue/cache/crash event lands as one JSONL line.  `None`
    /// disables journaling; results are byte-identical either way —
    /// the journal is a pure observer.  CLI: on by default for
    /// `campaign` (`<name>.campaign.jsonl`), off with `--no-journal`.
    pub journal: Option<crate::obs::Journal>,
    /// Bridge the coordinator's typed observer event stream into the
    /// journal: thread slots attach a [`crate::obs::JournalObserver`]
    /// directly, and subprocess/remote executors ship the *same* lines
    /// back as batched proto-v6 `events` frames, merged with an
    /// `origin` tag — so the journal is identically shaped across
    /// local, subprocess, remote, and fleet execution.  Streaming is
    /// best-effort and never result-affecting: stable summaries are
    /// byte-identical with it on or off, and dropped batches count in
    /// the `obs.event_drops` counter.  No-op without
    /// [`DispatchOptions::journal`].  CLI: on by default for
    /// `campaign`, off with `--no-stream`.
    pub stream_events: bool,
}

impl Default for DispatchOptions {
    fn default() -> Self {
        DispatchOptions {
            jobs: None,
            workers: WorkerKind::Thread,
            cache_dir: super::default_cache_dir(),
            max_attempts: 3,
            worker_exe: None,
            heartbeat_timeout: super::proto::HEARTBEAT_EVERY * DEFAULT_MISSED_HEARTBEATS,
            remote: Vec::new(),
            remote_token: None,
            fleet: None,
            journal: None,
            stream_events: true,
        }
    }
}

/// How often the fleet membership poller asks the registry who is
/// alive.
const FLEET_POLL_EVERY: Duration = Duration::from_secs(1);

/// With a fleet registry as the *only* possible capacity, how long the
/// dispatch waits for a first member to join before aborting with a
/// clear error instead of idling forever.
const FLEET_JOIN_TIMEOUT: Duration = Duration::from_secs(30);

/// One finished run out of the dispatcher.
pub struct DispatchedRun {
    pub report: RunReport,
    /// whether the report came from the run cache (no training executed)
    pub from_cache: bool,
}

// ------------------------------------------------------------------- pool

/// A registry of warm `adpsgd worker` children shared across
/// dispatches.  Slots check a child out for the duration of a dispatch
/// and check it back in when their queue drains, so sequential
/// campaigns in one process reuse children instead of paying a
/// respawn per campaign.  Children are tagged with the executable they
/// were spawned from, so dispatchers with different `worker_exe`
/// settings never receive each other's workers.
pub struct WorkerPool {
    idle: Mutex<Vec<WorkerClient>>,
    pids: Arc<Mutex<Vec<u32>>>,
    warm_checkouts: AtomicUsize,
}

impl WorkerPool {
    pub fn new() -> WorkerPool {
        WorkerPool {
            idle: Mutex::new(Vec::new()),
            pids: Arc::new(Mutex::new(Vec::new())),
            warm_checkouts: AtomicUsize::new(0),
        }
    }

    /// Live subprocess-worker pids (checked-out and idle alike).
    pub fn worker_pids(&self) -> Arc<Mutex<Vec<u32>>> {
        Arc::clone(&self.pids)
    }

    /// Idle warm children currently parked in the pool.
    pub fn idle_workers(&self) -> usize {
        self.idle.lock().expect("worker pool").len()
    }

    /// How many checkouts were answered by a warm child instead of a
    /// spawn (observability; the pool-reuse benchmark reads it).
    pub fn warm_checkouts(&self) -> usize {
        self.warm_checkouts.load(Ordering::Relaxed)
    }

    /// Borrow a live child spawned from `exe`, reusing a warm one when
    /// possible.  A child that died while idle is discarded on the spot
    /// — dropping it reaps the process and prunes its pid from the
    /// registry, so observers never target a dead pid.  (`pub(crate)`:
    /// the `adpsgd agent` daemon checks its worker children out of the
    /// same pool type.)
    pub(crate) fn checkout(&self, exe: Option<&Path>) -> Result<WorkerClient> {
        let exe = match exe {
            Some(p) => p.to_path_buf(),
            None => std::env::current_exe().context("resolving worker executable")?,
        };
        loop {
            let candidate = {
                let mut idle = self.idle.lock().expect("worker pool");
                idle.iter().position(|c| c.exe == exe).map(|i| idle.swap_remove(i))
            };
            match candidate {
                Some(mut client) => {
                    if client.is_alive() {
                        self.warm_checkouts.fetch_add(1, Ordering::Relaxed);
                        return Ok(client);
                    }
                    // died between runs: drop reaps it and prunes the
                    // stale pid; keep looking for a live sibling
                }
                None => return WorkerClient::spawn(exe, &self.pids),
            }
        }
    }

    /// Park a child for the next dispatch.  Dead children are dropped
    /// (reaped, pid pruned) instead of parked.
    pub(crate) fn checkin(&self, mut client: WorkerClient) {
        if client.is_alive() && client.stdin.is_some() {
            self.idle.lock().expect("worker pool").push(client);
        }
    }

    /// Gracefully retire every idle child: close stdin (the worker's
    /// serve loop exits on EOF), wait up to `timeout` each, then kill.
    /// Checked-out children are unaffected.
    pub fn shutdown(&self, timeout: Duration) {
        let clients = std::mem::take(&mut *self.idle.lock().expect("worker pool"));
        for mut client in clients {
            client.shutdown(timeout);
        }
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown(Duration::from_secs(2));
    }
}

// -------------------------------------------------------------- dispatcher

/// Executes batches of [`RunSpec`]s under one [`DispatchOptions`]
/// profile.  Reusable across batches; exposes live worker pids and the
/// crash-retry count for observability (and the kill-a-worker tests).
pub struct Dispatcher {
    opts: DispatchOptions,
    pool: Arc<WorkerPool>,
    retries: Arc<AtomicUsize>,
}

/// How one execution attempt ended (shared with [`super::net`]: the
/// agent daemon maps its own child outcomes onto terminal frames, and
/// the remote client maps frames back onto outcomes).
pub(crate) enum Outcome {
    Done(RunReport),
    /// Deterministic failure: aborts the dispatch.
    RunFailed(anyhow::Error),
    /// The executor died or went silent: the run is retryable.
    Crashed(anyhow::Error),
}

/// What drains the queue in one slot thread.
enum SlotRunner {
    /// A local slot: in-process thread or subprocess child per
    /// [`DispatchOptions::workers`].
    Local,
    /// A leased slot on one remote agent connection, remembering the
    /// endpoint so a dropped connection can be redialed under backoff.
    Remote { agent: Arc<RemoteAgentClient>, addr: String },
}

impl SlotRunner {
    /// A dead agent connection stops popping (until redialed); local
    /// slots never die.
    fn available(&self) -> bool {
        match self {
            SlotRunner::Local => true,
            SlotRunner::Remote { agent, .. } => !agent.is_dead(),
        }
    }
}

impl Dispatcher {
    /// A dispatcher over the process-wide [`super::shared_worker_pool`]:
    /// sequential dispatches reuse each other's warm children.
    pub fn new(opts: DispatchOptions) -> Dispatcher {
        Dispatcher::with_pool(opts, super::shared_worker_pool())
    }

    /// A dispatcher over an explicit pool (private pools isolate tests
    /// and let benchmarks compare reuse against respawn).
    pub fn with_pool(opts: DispatchOptions, pool: Arc<WorkerPool>) -> Dispatcher {
        Dispatcher { opts, pool, retries: Arc::new(AtomicUsize::new(0)) }
    }

    /// Live subprocess-worker pids of the underlying pool (empty in
    /// thread mode).
    pub fn worker_pids(&self) -> Arc<Mutex<Vec<u32>>> {
        self.pool.worker_pids()
    }

    /// The pool this dispatcher borrows children from.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Crashed-worker retries performed so far.
    pub fn retries(&self) -> usize {
        self.retries.load(Ordering::Relaxed)
    }

    /// Connect and handshake with every configured remote agent, in
    /// parallel (connects are independent; dialing serially would make
    /// startup latency O(agents × timeout) when hosts sinkhole SYNs).
    /// A rejected handshake (unreachable host, bad token, version skew)
    /// is a loud configuration error, not a silent capacity loss — a
    /// dead agent *mid-dispatch* is what the crash/requeue path covers.
    fn connect_remote_agents(&self) -> Result<Vec<Arc<RemoteAgentClient>>> {
        fleet::validate_endpoints(&self.opts.remote)?;
        if self.opts.remote.is_empty() {
            if matches!(self.opts.workers, WorkerKind::Remote) && self.opts.fleet.is_none() {
                anyhow::bail!(
                    "--workers remote needs at least one agent endpoint \
                     (--remote host:port[,host:port...]) or a fleet registry \
                     (--fleet host:port)"
                );
            }
            return Ok(Vec::new());
        }
        let token = self.opts.remote_token.as_deref();
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .opts
                .remote
                .iter()
                .map(|addr| {
                    scope.spawn(move || {
                        RemoteAgentClient::connect(addr, token, super::net::HANDSHAKE_TIMEOUT)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("agent connect thread"))
                .collect()
        })
    }

    /// Execute every run, returning reports in declaration order
    /// regardless of completion order, parallelism, or worker mix
    /// (local threads, subprocess children, remote agents).  An empty
    /// batch is a valid (empty) result — a campaign whose sweep
    /// resolves to zero runs reports cleanly instead of erroring.
    pub fn execute(&self, runs: &[RunSpec]) -> Result<Vec<DispatchedRun>> {
        let n = runs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let remote = self.connect_remote_agents()?;
        let cache = self.opts.cache_dir.as_ref().map(RunCache::new);
        // digest → local path for every warm-start artifact the runs
        // reference; remote-bound configs are rewritten to `blob:`
        // references (same cache key either way), so agents probe their
        // caches first and pull bytes only on a miss
        let blobs = if remote.is_empty() && self.opts.fleet.is_none() {
            BlobCatalog::empty()
        } else {
            BlobCatalog::for_runs(runs.iter().map(|r| &r.cfg))
        };
        let slots: Vec<Mutex<Option<Result<DispatchedRun>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        // one driver-minted trace id per run: it follows the run
        // through journal lines, agent sessions, and worker children
        // (proto v5), but never enters the config or the cache digest
        let traces: Vec<String> = (0..n).map(|_| crate::obs::mint_trace_id()).collect();
        // the gauge is bumped *per enqueue* (not set to `n` after the
        // loop) so every `run.queued` line can stamp the queue depth
        // that was current when its run entered the queue
        let depth = crate::obs::metrics().gauge("dispatch.queue_depth");
        for (i, spec) in runs.iter().enumerate() {
            depth.set((i + 1) as i64);
            if let Some(journal) = &self.opts.journal {
                journal.emit(
                    "run.queued",
                    Some(&traces[i]),
                    vec![
                        ("run", Json::str(spec.label.clone())),
                        ("queue_depth", Json::num((i + 1) as f64)),
                    ],
                );
            }
        }
        // every run enters the queue; the slots themselves probe the
        // cache, so warm campaigns parse entries in parallel instead of
        // serially before the pool starts
        let pending: VecDeque<(usize, usize)> = (0..n).map(|i| (i, 1)).collect();
        let local_jobs = match self.opts.workers {
            WorkerKind::Remote => 0,
            _ => self
                .opts
                .jobs
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(usize::from).unwrap_or(2)
                })
                .clamp(1, n),
        };
        let queue = Mutex::new(pending);
        let aborted = AtomicBool::new(false);
        // runs not yet terminally resolved (result or fatal error
        // recorded).  An idle slot must NOT exit while this is nonzero:
        // a run in flight on a dying remote slot can still be requeued,
        // and the requeue needs a surviving slot to pop it.
        let remaining = AtomicUsize::new(n);
        // live slot threads of any kind; the fleet poller watches it to
        // notice when every slot has exited with work still pending
        let active_slots = AtomicUsize::new(0);
        {
            // plain references for the spawned closures: `move` must
            // copy these borrows, never capture the owners
            let cache = cache.as_ref();
            let blobs = &blobs;
            let queue = &queue;
            let aborted = &aborted;
            let slots = &slots[..];
            let remaining = &remaining;
            let active = &active_slots;
            let traces = &traces[..];
            std::thread::scope(|scope| {
                for _ in 0..local_jobs {
                    active.fetch_add(1, Ordering::SeqCst);
                    scope.spawn(move || {
                        self.slot_loop(
                            SlotRunner::Local,
                            runs,
                            traces,
                            cache,
                            blobs,
                            queue,
                            aborted,
                            slots,
                            remaining,
                        );
                        active.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                for agent in &remote {
                    // one slot thread per advertised unit of capacity,
                    // all multiplexed over the agent's single connection
                    for _ in 0..agent.slots().min(n) {
                        let agent = Arc::clone(agent);
                        active.fetch_add(1, Ordering::SeqCst);
                        scope.spawn(move || {
                            let addr = agent.addr().to_string();
                            self.slot_loop(
                                SlotRunner::Remote { agent, addr },
                                runs,
                                traces,
                                cache,
                                blobs,
                                queue,
                                aborted,
                                slots,
                                remaining,
                            );
                            active.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                }
                if let Some(registry) = self.opts.fleet.as_deref() {
                    // elastic membership: poll the registry and add slot
                    // threads for members as they announce themselves
                    let static_slots = local_jobs > 0 || !remote.is_empty();
                    let known: HashSet<String> =
                        self.opts.remote.iter().map(|a| a.trim().to_string()).collect();
                    scope.spawn(move || {
                        self.fleet_poller(
                            scope,
                            registry,
                            static_slots,
                            known,
                            runs,
                            traces,
                            cache,
                            blobs,
                            queue,
                            aborted,
                            slots,
                            remaining,
                            active,
                        )
                    });
                }
            });
        }
        crate::obs::metrics().gauge("dispatch.queue_depth").set(0);

        // deterministic merge: declaration order; the lowest-index real
        // failure wins over "skipped" noise
        let mut merged: Vec<Option<DispatchedRun>> = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        let mut skipped: Option<usize> = None;
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().expect("dispatch slot") {
                Some(Ok(run)) => merged.push(Some(run)),
                Some(Err(e)) => {
                    first_err.get_or_insert(e);
                    merged.push(None);
                }
                None => {
                    skipped.get_or_insert(i);
                    merged.push(None);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if let Some(i) = skipped {
            // no recorded error means no abort: every slot exited with
            // work still queued (e.g. all remote agents disconnected in
            // a remote-only dispatch)
            if aborted.load(Ordering::Relaxed) {
                anyhow::bail!("run {:?} was skipped after an earlier failure", runs[i].label);
            }
            anyhow::bail!(
                "run {:?} was never executed: every worker slot exited before it could run \
                 (all remote agents disconnected?)",
                runs[i].label
            );
        }
        Ok(merged.into_iter().map(|r| r.expect("all slots filled")).collect())
    }

    /// The fleet membership poller: ask the registry who is alive every
    /// [`FLEET_POLL_EVERY`], dial members not seen before, and add one
    /// slot thread per advertised unit of their capacity — mid-campaign
    /// joins contribute immediately, because every slot drains the same
    /// queue.  A member that cannot be dialed is retried on later polls
    /// (it may still be starting); one whose lease expired simply stops
    /// appearing.  A *restarted* agent needs nothing from this thread:
    /// its surviving slot threads redial it under backoff, and the run
    /// cache guarantees completed runs are never redriven.
    #[allow(clippy::too_many_arguments)]
    fn fleet_poller<'scope, 'env>(
        &'scope self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        registry: &'scope str,
        static_slots: bool,
        mut known: HashSet<String>,
        runs: &'scope [RunSpec],
        traces: &'scope [String],
        cache: Option<&'scope RunCache>,
        blobs: &'scope BlobCatalog,
        queue: &'scope Mutex<VecDeque<(usize, usize)>>,
        aborted: &'scope AtomicBool,
        slots: &'scope [Mutex<Option<Result<DispatchedRun>>>],
        remaining: &'scope AtomicUsize,
        active: &'scope AtomicUsize,
    ) {
        let token = self.opts.remote_token.as_deref();
        let started = Instant::now();
        let mut ever_any = static_slots;
        let mut registry_down = false;
        loop {
            if aborted.load(Ordering::Relaxed) || remaining.load(Ordering::SeqCst) == 0 {
                break;
            }
            match fleet::registry::members(registry) {
                Ok(members) => {
                    if registry_down {
                        crate::obs::log!("fleet", "registry {registry} reachable again");
                    }
                    registry_down = false;
                    for m in members {
                        if known.contains(&m.addr) {
                            continue;
                        }
                        match RemoteAgentClient::connect(
                            &m.addr,
                            token,
                            super::net::HANDSHAKE_TIMEOUT,
                        ) {
                            Ok(agent) => {
                                println!(
                                    "dispatch: fleet member {} joined ({} slots)",
                                    m.addr,
                                    agent.slots()
                                );
                                crate::obs::metrics().counter("fleet.members_joined").inc();
                                known.insert(m.addr.clone());
                                ever_any = true;
                                for _ in 0..agent.slots().min(runs.len()) {
                                    let agent = Arc::clone(&agent);
                                    let addr = m.addr.clone();
                                    active.fetch_add(1, Ordering::SeqCst);
                                    scope.spawn(move || {
                                        self.slot_loop(
                                            SlotRunner::Remote { agent, addr },
                                            runs,
                                            traces,
                                            cache,
                                            blobs,
                                            queue,
                                            aborted,
                                            slots,
                                            remaining,
                                        );
                                        active.fetch_sub(1, Ordering::SeqCst);
                                    });
                                }
                            }
                            Err(e) => {
                                // not marked known: a member still
                                // starting up (or wrongly advertised)
                                // gets another dial on the next poll
                                crate::obs::log!(
                                    "fleet",
                                    "member {} not usable yet: {e:#}",
                                    m.addr
                                );
                            }
                        }
                    }
                }
                Err(e) => {
                    if !registry_down {
                        crate::obs::log!("fleet", "registry {registry} poll failed: {e:#}");
                    }
                    registry_down = true;
                }
            }
            if !ever_any && started.elapsed() >= FLEET_JOIN_TIMEOUT {
                // fleet-only capacity and nobody ever joined: abort
                // loudly instead of idling forever on an empty registry
                aborted.store(true, Ordering::Relaxed);
                *slots[0].lock().expect("dispatch slot") = Some(Err(anyhow!(
                    "no fleet member joined registry {registry} within {}s \
                     (and no local or static remote slots are configured)",
                    FLEET_JOIN_TIMEOUT.as_secs()
                )));
                remaining.fetch_sub(1, Ordering::SeqCst);
                break;
            }
            if ever_any
                && active.load(Ordering::SeqCst) == 0
                && remaining.load(Ordering::SeqCst) > 0
            {
                // every slot thread exited (members gone past their
                // redial budgets) with work still pending: stop polling
                // so the dispatch reports instead of waiting forever
                break;
            }
            std::thread::sleep(FLEET_POLL_EVERY);
        }
    }

    /// One slot: pop runs until every run is resolved, the dispatch
    /// aborts, or (for a remote slot) the agent connection dies and its
    /// redial budget is exhausted; then park the warm child back in the
    /// pool.
    ///
    /// An *empty queue* alone is not an exit condition: while other
    /// slots still have runs in flight, this slot idles — one of those
    /// runs may yet crash (a dying agent requeues everything it held)
    /// and the requeue needs a live slot to pop it.  Exiting on the
    /// first empty pop would orphan such runs and fail the dispatch
    /// despite surviving healthy capacity.
    #[allow(clippy::too_many_arguments)]
    fn slot_loop(
        &self,
        mut runner: SlotRunner,
        runs: &[RunSpec],
        traces: &[String],
        cache: Option<&RunCache>,
        blobs: &BlobCatalog,
        queue: &Mutex<VecDeque<(usize, usize)>>,
        aborted: &AtomicBool,
        slots: &[Mutex<Option<Result<DispatchedRun>>>],
        remaining: &AtomicUsize,
    ) {
        let mut client: Option<WorkerClient> = None;
        loop {
            if aborted.load(Ordering::Relaxed) {
                break;
            }
            if !runner.available() {
                match &mut runner {
                    SlotRunner::Local => break,
                    SlotRunner::Remote { agent, addr } => {
                        // the agent connection died (daemon restarted,
                        // network blip): redial it under capped backoff
                        // with jitter.  Completed runs are never
                        // redriven — their results are already merged
                        // (and memoized in the run cache) — and this
                        // slot's own in-flight run was already requeued
                        // through the crash path; a reconnect simply
                        // restores capacity for what is still pending.
                        let token = self.opts.remote_token.as_deref();
                        let what = format!("agent {addr}");
                        let redial = Backoff::default().retry(
                            &what,
                            || {
                                !aborted.load(Ordering::Relaxed)
                                    && remaining.load(Ordering::SeqCst) > 0
                            },
                            || {
                                RemoteAgentClient::connect(
                                    addr,
                                    token,
                                    super::net::HANDSHAKE_TIMEOUT,
                                )
                            },
                        );
                        match redial {
                            Ok(fresh) => {
                                println!("dispatch: reconnected to agent {addr}");
                                *agent = fresh;
                                continue;
                            }
                            Err(e) => {
                                // budget exhausted (or the work is done):
                                // this slot retires; surviving slots —
                                // and fleet joins — drain the queue
                                crate::obs::log!(
                                    "dispatch",
                                    "slot giving up on agent {addr}: {e:#}"
                                );
                                break;
                            }
                        }
                    }
                }
            }
            let popped = queue.lock().expect("dispatch queue").pop_front();
            let Some((i, attempt)) = popped else {
                if remaining.load(Ordering::SeqCst) == 0 {
                    break;
                }
                // runs are in flight on other slots: idle, don't exit
                std::thread::sleep(Duration::from_millis(25));
                continue;
            };
            let spec = &runs[i];
            let trace = &traces[i];
            let journal = self.opts.journal.as_ref();
            let metrics = crate::obs::metrics();
            metrics.gauge("dispatch.queue_depth").add(-1);
            // probe the cache on this slot's own thread: a hit fills
            // the result without touching a worker (RunCache::probe
            // restamps the hit under this run's label)
            let mut key: Option<(String, String)> = None;
            if let Some(cache) = cache {
                match cache.probe(&spec.cfg) {
                    Ok((digest, _, Some(report))) => {
                        metrics.counter("dispatch.cache_hits").inc();
                        if let Some(j) = journal {
                            j.emit(
                                "run.cache_hit",
                                Some(trace),
                                vec![
                                    ("run", Json::str(spec.label.clone())),
                                    ("digest", Json::str(digest)),
                                ],
                            );
                        }
                        *slots[i].lock().expect("dispatch slot") =
                            Some(Ok(DispatchedRun { report, from_cache: true }));
                        remaining.fetch_sub(1, Ordering::SeqCst);
                        continue;
                    }
                    Ok((digest, canonical, None)) => {
                        metrics.counter("dispatch.cache_misses").inc();
                        key = Some((digest, canonical));
                    }
                    Err(e) => {
                        aborted.store(true, Ordering::Relaxed);
                        *slots[i].lock().expect("dispatch slot") =
                            Some(Err(e.context(format!("hashing run {:?}", spec.label))));
                        remaining.fetch_sub(1, Ordering::SeqCst);
                        continue;
                    }
                }
            }
            let slot_kind = match &runner {
                SlotRunner::Local => match self.opts.workers {
                    WorkerKind::Subprocess => "subprocess".to_string(),
                    _ => "thread".to_string(),
                },
                SlotRunner::Remote { addr, .. } => format!("remote:{addr}"),
            };
            if let Some(j) = journal {
                j.emit(
                    "run.start",
                    Some(trace),
                    vec![
                        ("run", Json::str(spec.label.clone())),
                        ("slot", Json::str(slot_kind)),
                        ("attempt", Json::num(attempt as f64)),
                    ],
                );
            }
            metrics.gauge("dispatch.slots_busy").add(1);
            // one flag for every worker kind: bridge the typed observer
            // stream into the journal (directly for thread slots, as
            // merged proto-v6 `events` frames for subprocess/remote)
            let stream = journal.is_some() && self.opts.stream_events;
            let outcome = match &runner {
                SlotRunner::Local => match self.opts.workers {
                    WorkerKind::Thread => {
                        match Experiment::from_config(spec.cfg.clone()).and_then(|mut exp| {
                            if let (Some(j), true) = (journal, stream) {
                                exp.observe(Box::new(crate::obs::JournalObserver::new(
                                    j.clone(),
                                    trace.clone(),
                                    spec.label.clone(),
                                )));
                            }
                            exp.run()
                        }) {
                            Ok(report) => Outcome::Done(report),
                            Err(e) => Outcome::RunFailed(e),
                        }
                    }
                    WorkerKind::Subprocess => {
                        // the child renders the same journal-shaped
                        // lines the thread path writes directly; they
                        // arrive as `events` frames and merge here
                        // tagged `origin:"node"`
                        let mut sink = journal.filter(|_| stream).map(|j| {
                            move |lines: Vec<String>| {
                                j.merge_lines(&lines, "node");
                            }
                        });
                        self.subprocess_run(
                            &mut client,
                            &spec.cfg,
                            Some(trace),
                            sink.as_mut().map(|f| f as &mut dyn FnMut(Vec<String>)),
                        )
                    }
                    WorkerKind::Remote => {
                        unreachable!("remote-only dispatch spawns no local slots")
                    }
                },
                SlotRunner::Remote { agent, .. } => {
                    // the wire copy carries `blob:` references; the
                    // local config (and the cache key) are untouched
                    agent.run(
                        &blobs.wire_cfg(&spec.cfg),
                        Some(trace),
                        self.opts.heartbeat_timeout,
                        blobs,
                        aborted,
                        journal,
                        stream,
                    )
                }
            };
            metrics.gauge("dispatch.slots_busy").add(-1);
            match outcome {
                Outcome::Done(report) => {
                    if let (Some(cache), Some((digest, canonical))) = (cache, &key) {
                        match cache.put(digest, canonical, &report) {
                            Ok(()) => {
                                if let Some(j) = journal {
                                    j.emit(
                                        "cache.store",
                                        Some(trace),
                                        vec![
                                            ("run", Json::str(spec.label.clone())),
                                            ("digest", Json::str(digest.clone())),
                                        ],
                                    );
                                }
                            }
                            Err(e) => crate::obs::log!(
                                "dispatch",
                                "run cache write failed for {:?}: {e:#}",
                                spec.label
                            ),
                        }
                    }
                    if let Some(j) = journal {
                        j.emit(
                            "run.done",
                            Some(trace),
                            vec![
                                ("run", Json::str(spec.label.clone())),
                                ("modeled_wall_secs", Json::num(report.modeled_wall_secs)),
                                ("syncs", Json::num(report.syncs as f64)),
                            ],
                        );
                    }
                    *slots[i].lock().expect("dispatch slot") =
                        Some(Ok(DispatchedRun { report, from_cache: false }));
                    remaining.fetch_sub(1, Ordering::SeqCst);
                }
                Outcome::RunFailed(e) => {
                    if let Some(j) = journal {
                        j.emit(
                            "run.failed",
                            Some(trace),
                            vec![
                                ("run", Json::str(spec.label.clone())),
                                ("error", Json::str(format!("{e:#}"))),
                            ],
                        );
                    }
                    aborted.store(true, Ordering::Relaxed);
                    *slots[i].lock().expect("dispatch slot") =
                        Some(Err(e.context(format!("run {:?}", spec.label))));
                    remaining.fetch_sub(1, Ordering::SeqCst);
                }
                Outcome::Crashed(e) => {
                    // the child is gone: dropping it reaps the process
                    // and prunes its pid from the registry right here on
                    // the crash path (not at some later Drop), then the
                    // run goes back to *any* slot and a fresh child is
                    // checked out lazily on the next pop
                    client = None;
                    let retrying = attempt < self.opts.max_attempts;
                    if let Some(j) = journal {
                        j.emit(
                            "run.crashed",
                            Some(trace),
                            vec![
                                ("run", Json::str(spec.label.clone())),
                                ("attempt", Json::num(attempt as f64)),
                                ("retrying", Json::Bool(retrying)),
                                // the crash cause rides in the journal so
                                // fault-injected failures are diagnosable
                                // from the JSONL alone
                                ("error", Json::str(format!("{e:#}"))),
                            ],
                        );
                    }
                    if retrying {
                        self.retries.fetch_add(1, Ordering::Relaxed);
                        metrics.counter("dispatch.crash_requeues").inc();
                        crate::obs::log!(
                            "dispatch",
                            "worker crashed during run {:?} (attempt {attempt}); retrying: {e:#}",
                            spec.label
                        );
                        // requeued, not resolved: `remaining` stays up,
                        // so idle slots keep waiting for this run
                        metrics.gauge("dispatch.queue_depth").add(1);
                        queue.lock().expect("dispatch queue").push_back((i, attempt + 1));
                    } else {
                        aborted.store(true, Ordering::Relaxed);
                        *slots[i].lock().expect("dispatch slot") = Some(Err(e.context(format!(
                            "run {:?} crashed its worker {} times",
                            spec.label, attempt
                        ))));
                        remaining.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
        }
        // queue drained or dispatch aborted: park the warm child for
        // the next dispatch instead of killing it
        if let Some(c) = client {
            self.pool.checkin(c);
        }
    }

    fn subprocess_run(
        &self,
        client: &mut Option<WorkerClient>,
        cfg: &crate::config::ExperimentConfig,
        trace: Option<&str>,
        events: Option<&mut dyn FnMut(Vec<String>)>,
    ) -> Outcome {
        if client.is_none() {
            match self.pool.checkout(self.opts.worker_exe.as_deref()) {
                Ok(c) => *client = Some(c),
                Err(e) => return Outcome::Crashed(e.context("spawning worker")),
            }
        }
        let c = client.as_mut().expect("worker client just ensured");
        c.run(cfg, trace, self.opts.heartbeat_timeout, events)
    }
}

// ----------------------------------------------------------------- client

/// One `adpsgd worker` child and its protocol channel.  Reads arrive
/// through a dedicated reader thread, so waits carry a deadline.
/// (`pub(crate)`: the `adpsgd agent` daemon drives the same client
/// against its own warm children.)
pub(crate) struct WorkerClient {
    /// the executable this child was spawned from (pool-matching tag)
    exe: PathBuf,
    child: std::process::Child,
    /// `None` after a graceful [`WorkerClient::shutdown`] closed it
    stdin: Option<std::process::ChildStdin>,
    /// lines from the reader thread; disconnects on pipe EOF
    lines: Receiver<std::io::Result<String>>,
    next_id: u64,
    pids: Arc<Mutex<Vec<u32>>>,
}

impl WorkerClient {
    fn spawn(exe: PathBuf, pids: &Arc<Mutex<Vec<u32>>>) -> Result<WorkerClient> {
        let mut child = std::process::Command::new(&exe)
            .arg("worker")
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning {} worker", exe.display()))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        // the reader thread owns the blocking pipe; the client waits on
        // the channel with a deadline.  On EOF the sender drops and the
        // channel disconnects; the thread also exits if the client side
        // goes away first.
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let mut reader = std::io::BufReader::new(stdout);
            loop {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(0) => break,
                    Ok(_) => {
                        if tx.send(Ok(line)).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                }
            }
        });
        pids.lock().expect("pid registry").push(child.id());
        Ok(WorkerClient {
            exe,
            child,
            stdin: Some(stdin),
            lines: rx,
            next_id: 0,
            pids: Arc::clone(pids),
        })
    }

    fn is_alive(&mut self) -> bool {
        matches!(self.child.try_wait(), Ok(None))
    }

    /// The child's pid (the agent registers it per request so a
    /// `Cancel` — or an orphaned-run kill — can reach the process even
    /// while a handler thread is blocked reading from it).
    pub(crate) fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Submit one run and wait for its terminal frame under the
    /// heartbeat deadline.  Any received frame — heartbeat, stale or
    /// current — proves liveness and re-arms the deadline; terminal
    /// frames for an older (abandoned) request id are discarded as
    /// stale.  A transport defect or a missed deadline is a crash
    /// (retryable); an `Error` frame for the current id is a
    /// deterministic run failure (fatal), and so is a version-skewed
    /// reply (retrying against the same binary cannot succeed).
    ///
    /// `events` opts the request into proto-v6 event streaming: the
    /// child ships its journal-shaped observer lines back as batched
    /// `events` frames and every current-id batch is handed to the
    /// sink (the pool merges into the driver journal; the agent daemon
    /// relays up its session).  `None` leaves the `stream` flag off —
    /// the child emits no `events` frames at all.
    pub(crate) fn run(
        &mut self,
        cfg: &crate::config::ExperimentConfig,
        trace: Option<&str>,
        heartbeat_timeout: Duration,
        mut events: Option<&mut dyn FnMut(Vec<String>)>,
    ) -> Outcome {
        self.next_id += 1;
        let id = self.next_id;
        let frame = super::proto::Frame::RunRequest {
            id,
            cfg: cfg.clone(),
            trace: trace.map(str::to_string),
            stream: events.is_some(),
        };
        let line = match frame.to_line() {
            Ok(l) => l,
            // an unserializable config is the run's fault, not the worker's
            Err(e) => return Outcome::RunFailed(e),
        };
        let Some(stdin) = self.stdin.as_mut() else {
            return Outcome::Crashed(anyhow!("worker stdin already closed"));
        };
        if let Err(e) = stdin.write_all(line.as_bytes()).and_then(|()| stdin.flush()) {
            return Outcome::Crashed(anyhow!("worker pipe closed: {e}"));
        }
        let mut deadline = Instant::now() + heartbeat_timeout;
        loop {
            let wait = deadline.saturating_duration_since(Instant::now());
            let msg = match self.lines.recv_timeout(wait) {
                Ok(msg) => msg,
                Err(RecvTimeoutError::Timeout) => {
                    // the deadline spans many HEARTBEAT_EVERY intervals:
                    // total silence means the child is hung (stopped,
                    // livelocked), not slow.  Kill it; the crash path
                    // requeues the run on another slot.
                    self.child.kill().ok();
                    return Outcome::Crashed(anyhow!(
                        "worker {} silent for {:.1}s during run id {id} \
                         (missed heartbeat deadline); killed",
                        self.child.id(),
                        heartbeat_timeout.as_secs_f64()
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Outcome::Crashed(anyhow!("worker exited mid-run (pipe EOF)"))
                }
            };
            let reply = match msg {
                Ok(line) => line,
                Err(e) => return Outcome::Crashed(anyhow!("reading worker reply: {e}")),
            };
            // any frame proves the child is alive
            deadline = Instant::now() + heartbeat_timeout;
            match super::proto::Frame::parse(&reply) {
                Ok(super::proto::Frame::Heartbeat { .. }) => continue,
                Ok(super::proto::Frame::Events { id: rid, lines }) => {
                    // streamed observer lines are best-effort cargo,
                    // never protocol state: current-id batches go to
                    // the sink, anything else (a stale batch, or a
                    // batch we never asked for) is counted and dropped
                    match events.as_mut() {
                        Some(sink) if rid == id => sink(lines),
                        _ => crate::obs::metrics()
                            .counter("obs.event_drops")
                            .add(lines.len() as u64),
                    }
                    continue;
                }
                Ok(super::proto::Frame::RunResult { id: rid, report }) if rid == id => {
                    return Outcome::Done(report)
                }
                Ok(super::proto::Frame::Error { id: rid, message }) if rid == id => {
                    return Outcome::RunFailed(anyhow!("{message}"))
                }
                Ok(super::proto::Frame::Crashed { id: rid, message }) if rid == id => {
                    // the peer's executor died: retryable, like a local
                    // child crash (the local serve loop never sends
                    // this, but agents relaying child crashes do)
                    return Outcome::Crashed(anyhow!("worker reported executor crash: {message}"))
                }
                Ok(super::proto::Frame::RunResult { id: rid, .. })
                | Ok(super::proto::Frame::Error { id: rid, .. })
                | Ok(super::proto::Frame::Crashed { id: rid, .. })
                    if rid < id =>
                {
                    // a terminal frame for an abandoned request (e.g.
                    // one that hit the heartbeat deadline before this
                    // client was reused): stale, not a protocol
                    // violation — discard and keep waiting
                    crate::obs::log!(
                        "dispatch",
                        "discarding stale terminal frame for request {rid} (current {id})"
                    );
                    continue;
                }
                Ok(other) => {
                    return Outcome::Crashed(anyhow!(
                        "worker protocol violation: unexpected {} frame for request {}",
                        other.kind(),
                        other.id()
                    ))
                }
                Err(e) => {
                    if e.is::<super::proto::VersionSkew>() {
                        // deterministic: a respawned child is the same
                        // binary, so burning crash retries cannot help
                        return Outcome::RunFailed(
                            e.context("worker replied with a version-skewed frame"),
                        );
                    }
                    return Outcome::Crashed(e.context("malformed worker reply"));
                }
            }
        }
    }

    /// Graceful retirement: close stdin (the worker's serve loop exits
    /// on EOF), wait up to `timeout` for a clean exit, then kill.
    fn shutdown(&mut self, timeout: Duration) {
        drop(self.stdin.take());
        let deadline = Instant::now() + timeout;
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) | Err(_) => break,
                Ok(None) if Instant::now() >= deadline => {
                    self.child.kill().ok();
                    break;
                }
                Ok(None) => std::thread::sleep(Duration::from_millis(20)),
            }
        }
    }
}

impl Drop for WorkerClient {
    fn drop(&mut self) {
        let pid = self.child.id();
        // still running means a crash path or process teardown reached
        // us without a graceful shutdown: hard kill is the last resort
        if matches!(self.child.try_wait(), Ok(None)) {
            self.child.kill().ok();
        }
        self.child.wait().ok();
        self.pids.lock().expect("pid registry").retain(|p| *p != pid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, LrSchedule, StrategySpec};
    use crate::dispatch::runcache;

    fn quick_cfg(name: &str, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = name.into();
        cfg.seed = seed;
        cfg.nodes = 2;
        cfg.iters = 30;
        cfg.batch_per_node = 8;
        cfg.eval_every = 15;
        cfg.workload.input_dim = 16;
        cfg.workload.hidden = 8;
        cfg.workload.eval_batches = 2;
        cfg.optim.schedule = LrSchedule::Const;
        StrategySpec::Constant { period: 3 }.apply_to(&mut cfg.sync);
        cfg
    }

    fn specs(n: usize) -> Vec<RunSpec> {
        (0..n)
            .map(|i| {
                let cfg = quick_cfg(&format!("r{i}"), 100 + i as u64);
                RunSpec { label: format!("r{i}"), cfg }
            })
            .collect()
    }

    #[test]
    fn thread_pool_merges_deterministically_across_jobs() {
        let run = |jobs: usize| {
            Dispatcher::new(DispatchOptions {
                jobs: Some(jobs),
                cache_dir: None,
                ..DispatchOptions::default()
            })
            .execute(&specs(6))
            .unwrap()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.len(), 6);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.report.name, b.report.name);
            assert_eq!(a.report.final_train_loss, b.report.final_train_loss);
            assert_eq!(a.report.syncs, b.report.syncs);
            assert!(!a.from_cache && !b.from_cache);
        }
    }

    #[test]
    fn empty_dispatch_is_ok_and_empty() {
        // zero runs is a valid dispatch (a campaign sweep can resolve
        // to nothing), not an error
        let out = Dispatcher::new(DispatchOptions {
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .execute(&[])
        .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn cache_hit_skips_execution_and_is_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("adpsgd_pool_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = DispatchOptions {
            jobs: Some(2),
            cache_dir: Some(dir.clone()),
            ..DispatchOptions::default()
        };
        let cold = Dispatcher::new(opts.clone()).execute(&specs(3)).unwrap();
        assert!(cold.iter().all(|r| !r.from_cache));
        let warm = Dispatcher::new(opts).execute(&specs(3)).unwrap();
        assert!(warm.iter().all(|r| r.from_cache), "second dispatch must be all hits");
        for (a, b) in cold.iter().zip(&warm) {
            let aj = runcache::report_to_json(&a.report).to_string_compact();
            let bj = runcache::report_to_json(&b.report).to_string_compact();
            assert_eq!(aj, bj, "cached report must be bit-identical");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failing_run_aborts_and_names_the_label() {
        let mut runs = specs(2);
        runs[1].cfg.workload.backend =
            crate::config::Backend::Native("failing:0:5".into());
        runs[1].label = "boom".into();
        runs[1].cfg.name = "boom".into();
        let err = Dispatcher::new(DispatchOptions {
            jobs: Some(1),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .execute(&runs)
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("injected failure"), "{msg}");
    }

    /// A stand-in worker executable: stays alive until its stdin
    /// closes (like the real serve loop), ignores its `worker` arg.
    /// Checkout only needs a live process — protocol traffic is not
    /// required to exercise the park/reuse/prune bookkeeping.
    fn stub_worker(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("adpsgd_pool_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("stub_{tag}.sh"));
        std::fs::write(&path, "#!/bin/sh\ncat >/dev/null\n").unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            std::fs::set_permissions(&path, std::fs::Permissions::from_mode(0o755)).unwrap();
        }
        path
    }

    #[test]
    fn private_pool_parks_and_reuses_warm_children() {
        let exe = stub_worker("reuse");
        let pool = WorkerPool::new();
        let a = pool.checkout(Some(exe.as_path())).unwrap();
        let pid = a.child.id();
        assert_eq!(pool.warm_checkouts(), 0);
        assert!(pool.worker_pids().lock().unwrap().contains(&pid));
        pool.checkin(a);
        assert_eq!(pool.idle_workers(), 1);
        let b = pool.checkout(Some(exe.as_path())).unwrap();
        assert_eq!(b.child.id(), pid, "warm child must be reused");
        assert_eq!(pool.warm_checkouts(), 1);
        pool.checkin(b);
        // a different exe never receives someone else's child
        let other_exe = stub_worker("other");
        let other = pool.checkout(Some(other_exe.as_path())).unwrap();
        assert_ne!(other.child.id(), pid);
        drop(other);
        pool.shutdown(Duration::from_secs(2));
        assert_eq!(pool.idle_workers(), 0);
        assert!(
            pool.worker_pids().lock().unwrap().is_empty(),
            "shutdown must prune every pid"
        );
    }

    #[test]
    fn dead_idle_child_is_pruned_at_checkout() {
        let exe = stub_worker("dead");
        let pool = WorkerPool::new();
        let mut a = pool.checkout(Some(exe.as_path())).unwrap();
        let pid = a.child.id();
        // kill it behind the pool's back, then park the corpse the way
        // a between-runs crash would leave it
        a.child.kill().ok();
        a.child.wait().ok();
        pool.idle.lock().unwrap().push(a);
        let b = pool.checkout(Some(exe.as_path())).unwrap();
        assert_ne!(b.child.id(), pid, "a dead child must not be handed out");
        assert!(
            !pool.worker_pids().lock().unwrap().contains(&pid),
            "the dead child's pid must be pruned from the registry"
        );
        drop(b);
    }
}
