//! The `adpsgd worker` wire protocol: line-delimited JSON frames over
//! stdin/stdout.
//!
//! The dispatcher sends [`Frame::RunRequest`] lines (the config rides as
//! its canonical TOML text, so the worker rebuilds it through the exact
//! same parser/validator as a `--config` file); the worker answers with
//! periodic [`Frame::Heartbeat`]s while training and exactly one
//! terminal [`Frame::RunResult`] (the full report — summary, ledger,
//! series) or [`Frame::Error`] per request.  A deterministic run failure
//! travels as an `Error` frame; a *crash* (the child dying) is visible
//! to the dispatcher as EOF on the pipe, which is what triggers a retry
//! on another slot.  One worker processes requests sequentially and
//! exits cleanly on stdin EOF.

use crate::config::{toml::TomlDoc, ExperimentConfig};
use crate::coordinator::RunReport;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// How often a busy worker proves liveness.
pub const HEARTBEAT_EVERY: std::time::Duration = std::time::Duration::from_millis(500);

/// One protocol frame.
#[derive(Debug)]
pub enum Frame {
    /// Dispatcher → worker: execute this config.
    RunRequest { id: u64, cfg: ExperimentConfig },
    /// Worker → dispatcher: the run finished.
    RunResult { id: u64, report: RunReport },
    /// Worker → dispatcher: still alive, still training `id`.
    Heartbeat { id: u64 },
    /// Worker → dispatcher: the run failed deterministically.
    Error { id: u64, message: String },
}

impl Frame {
    /// Encode as one newline-terminated JSON line.
    pub fn to_line(&self) -> Result<String> {
        let json = match self {
            Frame::RunRequest { id, cfg } => Json::obj(vec![
                ("type", Json::str("run_request")),
                ("id", Json::num(*id as f64)),
                ("cfg", Json::str(cfg.to_toml_string()?)),
            ]),
            Frame::RunResult { id, report } => Json::obj(vec![
                ("type", Json::str("run_result")),
                ("id", Json::num(*id as f64)),
                ("report", super::runcache::report_to_json(report)),
            ]),
            Frame::Heartbeat { id } => Json::obj(vec![
                ("type", Json::str("heartbeat")),
                ("id", Json::num(*id as f64)),
            ]),
            Frame::Error { id, message } => Json::obj(vec![
                ("type", Json::str("error")),
                ("id", Json::num(*id as f64)),
                ("message", Json::str(message.clone())),
            ]),
        };
        Ok(format!("{}\n", json.to_string_compact()))
    }

    /// Decode one line.
    pub fn parse(line: &str) -> Result<Frame> {
        let v = Json::parse(line.trim()).map_err(|e| anyhow!("protocol frame: {e}"))?;
        let id = v
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("protocol frame: missing \"id\""))? as u64;
        let kind = v
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("protocol frame: missing \"type\""))?;
        Ok(match kind {
            "run_request" => {
                let text = v
                    .get("cfg")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("run_request: missing \"cfg\""))?;
                let doc = TomlDoc::parse(text).map_err(|e| anyhow!("run_request cfg: {e}"))?;
                Frame::RunRequest { id, cfg: ExperimentConfig::from_doc(&doc)? }
            }
            "run_result" => Frame::RunResult {
                id,
                report: super::runcache::report_from_json(
                    v.get("report").ok_or_else(|| anyhow!("run_result: missing report"))?,
                )?,
            },
            "heartbeat" => Frame::Heartbeat { id },
            "error" => Frame::Error {
                id,
                message: v
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("<no message>")
                    .to_string(),
            },
            other => bail!("protocol frame: unknown type {other:?}"),
        })
    }
}

/// The `adpsgd worker` loop: serve run requests from `input` until EOF,
/// writing heartbeats and terminal frames to `output`.  Frames are
/// written whole-line under a lock, so the heartbeat thread can never
/// interleave mid-line with a result.
pub fn serve(input: impl BufRead, output: impl Write + Send + 'static) -> Result<()> {
    let out = Arc::new(Mutex::new(output));
    let write_frame = |frame: &Frame| -> Result<()> {
        let line = frame.to_line()?;
        let mut o = out.lock().expect("worker stdout lock");
        o.write_all(line.as_bytes()).context("writing frame")?;
        o.flush().context("flushing frame")
    };
    for line in input.lines() {
        let line = line.context("reading request")?;
        if line.trim().is_empty() {
            continue;
        }
        let (id, cfg) = match Frame::parse(&line) {
            Ok(Frame::RunRequest { id, cfg }) => (id, cfg),
            Ok(other) => {
                bail!("worker: expected a run_request, got {other:?}")
            }
            Err(e) => return Err(e.context("worker: malformed request")),
        };
        // prove liveness while the (possibly long) run executes
        let stop = Arc::new(AtomicBool::new(false));
        let beat = {
            let stop = Arc::clone(&stop);
            let out = Arc::clone(&out);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::park_timeout(HEARTBEAT_EVERY);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(line) = (Frame::Heartbeat { id }).to_line() {
                        let mut o = out.lock().expect("worker stdout lock");
                        let _ = o.write_all(line.as_bytes());
                        let _ = o.flush();
                    }
                }
            })
        };
        let result = crate::experiment::Experiment::from_config(cfg)
            .and_then(crate::experiment::Experiment::run);
        stop.store(true, Ordering::Relaxed);
        beat.thread().unpark();
        beat.join().ok();
        match result {
            Ok(report) => write_frame(&Frame::RunResult { id, report })?,
            Err(e) => write_frame(&Frame::Error { id, message: format!("{e:#}") })?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_through_lines() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "proto_rt".into();
        cfg.nodes = 3;
        cfg.sync.qsgd_levels = 15;
        let line = (Frame::RunRequest { id: 7, cfg: cfg.clone() }).to_line().unwrap();
        assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
        match Frame::parse(&line).unwrap() {
            Frame::RunRequest { id, cfg: back } => {
                assert_eq!(id, 7);
                assert_eq!(back.name, "proto_rt");
                assert_eq!(back.nodes, 3);
                // the canonical text is the equality witness: every
                // result-affecting knob survived the wire
                assert_eq!(back.to_toml_string().unwrap(), cfg.to_toml_string().unwrap());
            }
            other => panic!("wrong frame {other:?}"),
        }

        let hb = (Frame::Heartbeat { id: 3 }).to_line().unwrap();
        assert!(matches!(Frame::parse(&hb).unwrap(), Frame::Heartbeat { id: 3 }));

        let err = (Frame::Error { id: 9, message: "boom".into() }).to_line().unwrap();
        match Frame::parse(&err).unwrap() {
            Frame::Error { id, message } => {
                assert_eq!((id, message.as_str()), (9, "boom"));
            }
            other => panic!("wrong frame {other:?}"),
        }

        assert!(Frame::parse("{\"type\":\"warp\",\"id\":1}").is_err());
        assert!(Frame::parse("not json").is_err());
    }

    #[test]
    fn serve_runs_a_request_and_reports_errors() {
        let mut quick = ExperimentConfig::default();
        quick.name = "serve_ok".into();
        quick.nodes = 2;
        quick.iters = 20;
        quick.batch_per_node = 8;
        quick.eval_every = 10;
        quick.workload.input_dim = 16;
        quick.workload.hidden = 8;
        quick.workload.eval_batches = 2;
        quick.optim.schedule = crate::config::LrSchedule::Const;
        quick.sync.strategy = crate::period::Strategy::Constant;
        quick.sync.period = 4;

        let mut bad = quick.clone();
        bad.name = "serve_bad".into();
        bad.workload.backend = crate::config::Backend::Native("failing:0:5".into());

        let input = format!(
            "{}{}",
            (Frame::RunRequest { id: 1, cfg: quick }).to_line().unwrap(),
            (Frame::RunRequest { id: 2, cfg: bad }).to_line().unwrap(),
        );
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        serve(input.as_bytes(), SharedBuf(Arc::clone(&out))).unwrap();
        let bytes = out.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let frames: Vec<Frame> =
            text.lines().map(|l| Frame::parse(l).unwrap()).collect();
        let result = frames
            .iter()
            .find_map(|f| match f {
                Frame::RunResult { id: 1, report } => Some(report),
                _ => None,
            })
            .expect("run 1 succeeds");
        assert_eq!(result.iters, 20);
        assert_eq!(result.syncs, 5);
        let msg = frames
            .iter()
            .find_map(|f| match f {
                Frame::Error { id: 2, message } => Some(message.clone()),
                _ => None,
            })
            .expect("run 2 fails deterministically");
        assert!(msg.contains("injected failure"), "{msg}");
    }
}
