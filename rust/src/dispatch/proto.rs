//! The `adpsgd worker` wire protocol: line-delimited JSON frames over
//! stdin/stdout (and, length-delimited, over the [`super::net`] TCP
//! transport).
//!
//! The dispatcher sends [`Frame::RunRequest`] lines (the config rides as
//! its canonical TOML text, so the worker rebuilds it through the exact
//! same parser/validator as a `--config` file); the worker answers with
//! periodic [`Frame::Heartbeat`]s while training and exactly one
//! terminal [`Frame::RunResult`] (the full report — summary, ledger,
//! series) or [`Frame::Error`] per request.  A deterministic run failure
//! travels as an `Error` frame; a *crash* (the child dying) is visible
//! to the dispatcher as EOF on the pipe, which is what triggers a retry
//! on another slot.  One worker processes requests sequentially and
//! exits cleanly on stdin EOF.
//!
//! Remote agents (see [`super::net`]) reuse these frames with a few
//! additions: [`Frame::Challenge`]/[`Frame::Hello`]/[`Frame::HelloAck`]
//! open a TCP session (nonce challenge, keyed-digest proof, advertised
//! slot capacity — the shared token itself never travels;
//! see [`auth_proof`]), [`Frame::Crashed`] reports an agent-side
//! executor crash as a *retryable* terminal frame — distinct from
//! `Error`, whose failure is deterministic and aborts the dispatch —
//! [`Frame::Cancel`] kills an in-flight run the dispatcher no longer
//! wants, and [`Frame::BlobRequest`]/[`Frame::Blob`] pull
//! content-addressed artifacts (warm-start snapshots, HLO manifests)
//! the agent is missing (see [`super::fleet::blobs`]).
//!
//! ## Versioning
//!
//! Every frame carries a `"v"` header set to [`PROTO_VERSION`].  Both
//! ends ([`serve`] and the dispatcher-side clients) reject a frame whose
//! version is missing or different with a typed [`VersionSkew`] error —
//! a clear "rebuild both ends" diagnosis instead of a generic parse
//! failure, covering the old-worker-binary-new-CLI corner (and its
//! inverse) for subprocess and TCP peers alike.
//!
//! ## Bulk payloads (proto v3)
//!
//! Two frames carry bulk bytes — [`Frame::RunResult`] (a report whose
//! metric series can run to multiple MB of floats) and [`Frame::Blob`]
//! (opaque tagged bytes: warm-start snapshots, staged artifacts).  On
//! the TCP transport these travel as length-delimited *binary* payloads
//! (see [`super::net::transport`]), skipping JSON float formatting and
//! parsing entirely; on the stdio JSONL path they still render as JSON
//! lines (the report as its JSON form, blob bytes hex-encoded), so the
//! subprocess worker protocol stays line-delimited and debuggable.

use crate::config::{toml::TomlDoc, ExperimentConfig};
use crate::coordinator::RunReport;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// How often a busy worker proves liveness.
pub const HEARTBEAT_EVERY: std::time::Duration = std::time::Duration::from_millis(500);

/// Wire-protocol version carried in every frame's `"v"` header.
///
/// v1 was the unversioned JSONL protocol of the first dispatch release;
/// v2 added the header itself, the `hello`/`hello_ack` TCP handshake,
/// and the retryable `crashed` terminal frame; v3 added binary bulk
/// payloads on the TCP transport (run results and `blob` frames) while
/// control frames stayed JSON; v4 replaced the plaintext hello token
/// with a `challenge`/proof handshake (the secret never travels — see
/// [`auth_proof`]) and added the `cancel` and `blob_request` control
/// frames for mid-run cancellation and content-addressed artifact
/// staging; v5 added the optional `trace` field on `run_request` (the
/// driver-minted trace id, propagated so one run is greppable driver →
/// agent → worker child — see [`crate::obs`]) and the
/// `stats_request`/`stats` frames behind `adpsgd status`; v6 added the
/// `stream` flag on `run_request` and the batched `events` frame, which
/// carries the executor's serialized [`crate::obs::JournalObserver`]
/// event lines back to the driver's journal (best-effort — dropped
/// batches are counted in `obs.event_drops`, never retried, and never
/// affect run results).
pub const PROTO_VERSION: u64 = 6;

/// Typed parse error for a frame whose `"v"` header is missing or does
/// not match [`PROTO_VERSION`].  Carried through `anyhow` so transports
/// can `downcast_ref` and treat skew as a deterministic configuration
/// error (no point respawning or retrying against the same binary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VersionSkew {
    /// The version the peer sent; `None` for an unversioned (pre-v2)
    /// frame.
    pub got: Option<u64>,
}

impl std::fmt::Display for VersionSkew {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.got {
            Some(got) => write!(
                f,
                "protocol version skew: peer speaks wire version {got}, this binary speaks \
                 v{PROTO_VERSION} — rebuild/redeploy both ends from the same adpsgd version"
            ),
            None => write!(
                f,
                "protocol version skew: peer sent an unversioned (pre-v2) frame, this binary \
                 speaks v{PROTO_VERSION} — rebuild/redeploy both ends from the same adpsgd version"
            ),
        }
    }
}

impl std::error::Error for VersionSkew {}

/// One protocol frame.
#[derive(Debug)]
pub enum Frame {
    /// Dispatcher → worker: execute this config.  `trace` is the
    /// driver-minted per-run trace id ([`crate::obs::mint_trace_id`]);
    /// it rides *beside* the config — never inside it — so it can
    /// follow the run through agents and worker children without ever
    /// touching cache digests or stable summaries.  `stream` asks the
    /// executor to ship its typed observer events back as
    /// [`Frame::Events`] batches (the driver only sets it when a
    /// journal is attached).
    RunRequest { id: u64, cfg: ExperimentConfig, trace: Option<String>, stream: bool },
    /// Worker → dispatcher: the run finished.
    RunResult { id: u64, report: RunReport },
    /// Worker → dispatcher: still alive, still training `id`.
    Heartbeat { id: u64 },
    /// Worker → dispatcher: the run failed deterministically.
    Error { id: u64, message: String },
    /// Agent → dispatcher: the run's *executor* crashed (child died,
    /// hung past the deadline).  Retryable — the dispatcher requeues the
    /// run like any local worker crash instead of aborting the dispatch.
    Crashed { id: u64, message: String },
    /// Agent → client, first frame on a TCP connection: a fresh nonce
    /// the client must answer with a keyed digest ([`auth_proof`])
    /// before the session opens.  The nonce is single-use, so a
    /// captured proof cannot be replayed against a later connection.
    Challenge { nonce: String },
    /// Client → agent, answering the [`Frame::Challenge`]: the keyed
    /// digest of (token, nonce) — never the token itself, so the shared
    /// secret does not travel the wire in either direction.
    Hello { proof: String },
    /// Agent → client: handshake accepted; the agent advertises how many
    /// concurrent runs it will serve on this connection.
    HelloAck { slots: u32 },
    /// Dispatcher → agent: abandon run `id` — kill the worker child
    /// executing it instead of letting an orphaned run train to
    /// completion.  The agent answers with its normal retryable
    /// [`Frame::Crashed`] terminal once the child is down.
    Cancel { id: u64 },
    /// Agent → dispatcher: the run `id` references a content-addressed
    /// artifact (`blob:<digest>` — a warm-start snapshot or HLO
    /// manifest) the agent does not hold; the dispatcher answers with a
    /// [`Frame::Blob`] carrying the bytes (tag = digest) or a
    /// [`Frame::Error`] if it cannot resolve the digest either.
    BlobRequest { id: u64, digest: String },
    /// Either direction: opaque bulk bytes for the request `id` — a
    /// warm-start snapshot, a staged artifact.  `tag` names what the
    /// bytes are (receiver-interpreted).  Binary on the TCP transport;
    /// hex-encoded on the JSONL path.
    Blob { id: u64, tag: String, bytes: Vec<u8> },
    /// Client → agent: report your live stats (`adpsgd status`).
    /// Rides the normal per-request id space so it multiplexes with
    /// in-flight runs on the same connection.
    StatsRequest { id: u64 },
    /// Agent → client: the answer to a [`Frame::StatsRequest`] — an
    /// opaque JSON object (advertised slots, in-flight runs, cache
    /// hit counters, and the agent's [`crate::obs`] metrics snapshot).
    /// Opaque so new metrics never need a protocol bump.
    Stats { id: u64, stats: Json },
    /// Executor → dispatcher: a batch of serialized journal event lines
    /// for run `id` — the worker child's (or agent executor's) bridged
    /// [`crate::coordinator::observer::RunEvent`] stream, each line
    /// already in the journal's self-describing JSON shape (see
    /// [`crate::obs::journal::render_line`]).  Interleaves with
    /// heartbeats; strictly best-effort and result-inert: the driver
    /// merges what arrives (tagged with an `origin`) and counts what
    /// doesn't in `obs.event_drops`.
    Events { id: u64, lines: Vec<String> },
}

/// The challenge-response proof: an HMAC-shaped keyed digest of the
/// shared token over the agent's nonce, built from the run cache's
/// [`super::runcache::content_digest`] (no new dependencies).  Two
/// nested passes with distinct framing — `digest(token ‖ digest(token ‖
/// nonce))` — so the proof is bound to both the secret and this
/// connection's nonce, and neither appears on the wire.  An agent that
/// requires no token still challenges (`token = ""`); the exchange is
/// then integrity-only.
pub fn auth_proof(nonce: &str, token: &str) -> String {
    let inner =
        super::runcache::content_digest(format!("adpsgd-auth-i\n{token}\n{nonce}").as_bytes());
    super::runcache::content_digest(format!("adpsgd-auth-o\n{token}\n{inner}").as_bytes())
}

impl Frame {
    /// The request id this frame carries (handshake frames, which are
    /// per-connection rather than per-run, report 0).
    pub fn id(&self) -> u64 {
        match self {
            Frame::RunRequest { id, .. }
            | Frame::RunResult { id, .. }
            | Frame::Heartbeat { id }
            | Frame::Error { id, .. }
            | Frame::Crashed { id, .. }
            | Frame::Cancel { id }
            | Frame::BlobRequest { id, .. }
            | Frame::Blob { id, .. }
            | Frame::StatsRequest { id }
            | Frame::Stats { id, .. }
            | Frame::Events { id, .. } => *id,
            Frame::Challenge { .. } | Frame::Hello { .. } | Frame::HelloAck { .. } => 0,
        }
    }

    /// The frame's wire-type name (for diagnostics that must not dump a
    /// whole report).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::RunRequest { .. } => "run_request",
            Frame::RunResult { .. } => "run_result",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::Error { .. } => "error",
            Frame::Crashed { .. } => "crashed",
            Frame::Challenge { .. } => "challenge",
            Frame::Hello { .. } => "hello",
            Frame::HelloAck { .. } => "hello_ack",
            Frame::Cancel { .. } => "cancel",
            Frame::BlobRequest { .. } => "blob_request",
            Frame::Blob { .. } => "blob",
            Frame::StatsRequest { .. } => "stats_request",
            Frame::Stats { .. } => "stats",
            Frame::Events { .. } => "events",
        }
    }

    /// Encode as one newline-terminated JSON line (every frame carries
    /// the [`PROTO_VERSION`] header).
    pub fn to_line(&self) -> Result<String> {
        let version = ("v", Json::num(PROTO_VERSION as f64));
        let json = match self {
            Frame::RunRequest { id, cfg, trace, stream } => {
                let mut pairs = vec![
                    ("type", Json::str("run_request")),
                    ("id", Json::num(*id as f64)),
                    ("cfg", Json::str(cfg.to_toml_string()?)),
                    version,
                ];
                if let Some(t) = trace {
                    pairs.push(("trace", Json::str(t.clone())));
                }
                // absent-when-false, so v6 requests without streaming
                // are byte-identical to v5 ones (modulo the header)
                if *stream {
                    pairs.push(("stream", Json::Bool(true)));
                }
                Json::obj(pairs)
            }
            Frame::RunResult { id, report } => Json::obj(vec![
                ("type", Json::str("run_result")),
                ("id", Json::num(*id as f64)),
                ("report", super::runcache::report_to_json(report)),
                version,
            ]),
            Frame::Heartbeat { id } => Json::obj(vec![
                ("type", Json::str("heartbeat")),
                ("id", Json::num(*id as f64)),
                version,
            ]),
            Frame::Error { id, message } => Json::obj(vec![
                ("type", Json::str("error")),
                ("id", Json::num(*id as f64)),
                ("message", Json::str(message.clone())),
                version,
            ]),
            Frame::Crashed { id, message } => Json::obj(vec![
                ("type", Json::str("crashed")),
                ("id", Json::num(*id as f64)),
                ("message", Json::str(message.clone())),
                version,
            ]),
            Frame::Challenge { nonce } => Json::obj(vec![
                ("type", Json::str("challenge")),
                ("nonce", Json::str(nonce.clone())),
                version,
            ]),
            Frame::Hello { proof } => Json::obj(vec![
                ("type", Json::str("hello")),
                ("proof", Json::str(proof.clone())),
                version,
            ]),
            Frame::HelloAck { slots } => Json::obj(vec![
                ("type", Json::str("hello_ack")),
                ("slots", Json::num(*slots as f64)),
                version,
            ]),
            Frame::Cancel { id } => Json::obj(vec![
                ("type", Json::str("cancel")),
                ("id", Json::num(*id as f64)),
                version,
            ]),
            Frame::BlobRequest { id, digest } => Json::obj(vec![
                ("type", Json::str("blob_request")),
                ("id", Json::num(*id as f64)),
                ("digest", Json::str(digest.clone())),
                version,
            ]),
            Frame::Blob { id, tag, bytes } => Json::obj(vec![
                ("type", Json::str("blob")),
                ("id", Json::num(*id as f64)),
                ("tag", Json::str(tag.clone())),
                ("hex", Json::str(hex_encode(bytes))),
                version,
            ]),
            Frame::StatsRequest { id } => Json::obj(vec![
                ("type", Json::str("stats_request")),
                ("id", Json::num(*id as f64)),
                version,
            ]),
            Frame::Stats { id, stats } => Json::obj(vec![
                ("type", Json::str("stats")),
                ("id", Json::num(*id as f64)),
                ("stats", stats.clone()),
                version,
            ]),
            Frame::Events { id, lines } => Json::obj(vec![
                ("type", Json::str("events")),
                ("id", Json::num(*id as f64)),
                (
                    "lines",
                    Json::Arr(lines.iter().map(|l| Json::str(l.clone())).collect()),
                ),
                version,
            ]),
        };
        Ok(format!("{}\n", json.to_string_compact()))
    }

    /// Decode one line.  A missing or mismatched `"v"` header fails with
    /// a typed [`VersionSkew`] (downcastable through the `anyhow`
    /// chain), never a generic parse error.
    pub fn parse(line: &str) -> Result<Frame> {
        let v = Json::parse(line.trim()).map_err(|e| anyhow!("protocol frame: {e}"))?;
        match v.get("v").and_then(Json::as_f64) {
            Some(x) if x as u64 == PROTO_VERSION => {}
            got => {
                return Err(anyhow::Error::new(VersionSkew { got: got.map(|x| x as u64) }))
            }
        }
        let kind = v
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("protocol frame: missing \"type\""))?;
        let need_id = || -> Result<u64> {
            v.get("id")
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| anyhow!("protocol frame: missing \"id\""))
        };
        let message = || {
            v.get("message").and_then(Json::as_str).unwrap_or("<no message>").to_string()
        };
        Ok(match kind {
            "run_request" => {
                let id = need_id()?;
                let text = v
                    .get("cfg")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("run_request: missing \"cfg\""))?;
                let doc = TomlDoc::parse(text).map_err(|e| anyhow!("run_request cfg: {e}"))?;
                Frame::RunRequest {
                    id,
                    cfg: ExperimentConfig::from_doc(&doc)?,
                    trace: v.get("trace").and_then(Json::as_str).map(str::to_string),
                    stream: matches!(v.get("stream"), Some(Json::Bool(true))),
                }
            }
            "run_result" => Frame::RunResult {
                id: need_id()?,
                report: super::runcache::report_from_json(
                    v.get("report").ok_or_else(|| anyhow!("run_result: missing report"))?,
                )?,
            },
            "heartbeat" => Frame::Heartbeat { id: need_id()? },
            "error" => Frame::Error { id: need_id()?, message: message() },
            "crashed" => Frame::Crashed { id: need_id()?, message: message() },
            "challenge" => Frame::Challenge {
                nonce: v.get("nonce").and_then(Json::as_str).unwrap_or_default().to_string(),
            },
            "hello" => Frame::Hello {
                proof: v.get("proof").and_then(Json::as_str).unwrap_or_default().to_string(),
            },
            "hello_ack" => Frame::HelloAck {
                slots: v.get("slots").and_then(Json::as_f64).unwrap_or(1.0) as u32,
            },
            "cancel" => Frame::Cancel { id: need_id()? },
            "blob_request" => Frame::BlobRequest {
                id: need_id()?,
                digest: v
                    .get("digest")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("blob_request: missing \"digest\""))?
                    .to_string(),
            },
            "blob" => Frame::Blob {
                id: need_id()?,
                tag: v.get("tag").and_then(Json::as_str).unwrap_or_default().to_string(),
                bytes: hex_decode(
                    v.get("hex")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("blob: missing \"hex\""))?,
                )?,
            },
            "stats_request" => Frame::StatsRequest { id: need_id()? },
            "stats" => Frame::Stats {
                id: need_id()?,
                stats: v.get("stats").cloned().unwrap_or(Json::Null),
            },
            "events" => Frame::Events {
                id: need_id()?,
                lines: v
                    .get("lines")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("events: missing \"lines\""))?
                    .iter()
                    .filter_map(|l| l.as_str().map(str::to_string))
                    .collect(),
            },
            other => bail!("protocol frame: unknown type {other:?}"),
        })
    }
}

/// Hex codec for [`Frame::Blob`] bytes on the JSONL path (the TCP
/// transport carries them raw; see [`super::net::transport`]).
fn hex_encode(bytes: &[u8]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        write!(s, "{b:02x}").expect("writing to a String cannot fail");
    }
    s
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        bail!("blob hex: odd length {}", s.len());
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            s.get(i..i + 2)
                .and_then(|pair| u8::from_str_radix(pair, 16).ok())
                .ok_or_else(|| anyhow!("blob hex: invalid digit at offset {i}"))
        })
        .collect()
}

/// A liveness pump: a background thread calling `beat` every
/// [`HEARTBEAT_EVERY`] for as long as the returned guard lives
/// (stopping early if `beat` reports the peer gone).  Dropping the
/// guard stops and joins the thread.  The subtle stop/unpark/join
/// shutdown handshake lives here once, shared by the worker serve loop
/// and the agent's run handlers.
pub(crate) struct HeartbeatPump {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

pub(crate) fn heartbeat_pump(beat: impl Fn() -> bool + Send + 'static) -> HeartbeatPump {
    let stop = Arc::new(AtomicBool::new(false));
    let thread = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::park_timeout(HEARTBEAT_EVERY);
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                if !beat() {
                    break;
                }
            }
        })
    };
    HeartbeatPump { stop, thread: Some(thread) }
}

impl Drop for HeartbeatPump {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.thread().unpark();
            t.join().ok();
        }
    }
}

/// How many journal-shaped event lines the worker-side streaming
/// bridge accumulates before shipping a [`Frame::Events`] batch.
const EVENT_BATCH: usize = 64;

/// The worker-side half of event streaming (proto v6): bridges the
/// coordinator's typed observer stream into journal-shaped lines
/// ([`crate::obs::journal::observer_line`]) and ships them to the
/// dispatcher as batched [`Frame::Events`] — on batch-full, on the
/// terminal `RunEnd`, and on drop (so an aborted run still flushes
/// what it saw).  Strictly best-effort: a batch that fails to encode
/// or write is counted in `obs.event_drops` and forgotten, and
/// `on_event` never returns an error, so streaming can never change a
/// run's result.
struct StreamObserver<W: Write + Send + 'static> {
    id: u64,
    out: Arc<Mutex<W>>,
    label: String,
    trace: Option<String>,
    buf: Vec<String>,
}

impl<W: Write + Send + 'static> StreamObserver<W> {
    fn new(id: u64, out: Arc<Mutex<W>>, label: String, trace: Option<String>) -> Self {
        StreamObserver { id, out, label, trace, buf: Vec::new() }
    }

    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let lines = std::mem::take(&mut self.buf);
        let n = lines.len() as u64;
        let shipped = (Frame::Events { id: self.id, lines }).to_line().ok().is_some_and(
            |line| {
                let mut o = self.out.lock().expect("worker stdout lock");
                o.write_all(line.as_bytes()).and_then(|()| o.flush()).is_ok()
            },
        );
        if !shipped {
            crate::obs::metrics().counter("obs.event_drops").add(n);
        }
    }
}

impl<W: Write + Send + 'static> crate::coordinator::observer::RunObserver
    for StreamObserver<W>
{
    fn on_event(&mut self, ev: &crate::coordinator::observer::RunEvent<'_>) -> Result<()> {
        let terminal =
            matches!(ev, crate::coordinator::observer::RunEvent::RunEnd { .. });
        if let Some(line) =
            crate::obs::journal::observer_line(ev, &self.label, self.trace.as_deref())
        {
            self.buf.push(line);
        }
        if terminal || self.buf.len() >= EVENT_BATCH {
            self.flush();
        }
        Ok(())
    }
}

impl<W: Write + Send + 'static> Drop for StreamObserver<W> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Best-effort request id of a line that failed [`Frame::parse`], so a
/// rejection can still be correlated with the request that caused it.
fn best_effort_id(line: &str) -> u64 {
    Json::parse(line.trim())
        .ok()
        .and_then(|v| v.get("id").and_then(Json::as_f64))
        .map(|x| x as u64)
        .unwrap_or(0)
}

/// The `adpsgd worker` loop: serve run requests from `input` until EOF,
/// writing heartbeats and terminal frames to `output`.  Frames are
/// written whole-line under a lock, so the heartbeat thread can never
/// interleave mid-line with a result.
///
/// A malformed or unexpected request frame does **not** kill the
/// worker: it is answered with a [`Frame::Error`] (best-effort id) and
/// the loop keeps serving.  Dying instead would look like a *crash* to
/// the dispatcher (pipe EOF), which would respawn fresh children
/// against the same poison input until `max_attempts` ran out — a
/// deterministic bad request must surface as a deterministic failure.
pub fn serve(input: impl BufRead, output: impl Write + Send + 'static) -> Result<()> {
    let out = Arc::new(Mutex::new(output));
    let write_frame = |frame: &Frame| -> Result<()> {
        let line = frame.to_line()?;
        let mut o = out.lock().expect("worker stdout lock");
        o.write_all(line.as_bytes()).context("writing frame")?;
        o.flush().context("flushing frame")
    };
    for line in input.lines() {
        let line = line.context("reading request")?;
        if line.trim().is_empty() {
            continue;
        }
        let (id, cfg, trace, stream) = match Frame::parse(&line) {
            Ok(Frame::RunRequest { id, cfg, trace, stream }) => (id, cfg, trace, stream),
            Ok(other) => {
                write_frame(&Frame::Error {
                    id: other.id(),
                    message: format!(
                        "worker: expected a run_request, got a {} frame",
                        other.kind()
                    ),
                })?;
                continue;
            }
            Err(e) => {
                write_frame(&Frame::Error {
                    id: best_effort_id(&line),
                    message: format!("worker: malformed request: {e:#}"),
                })?;
                continue;
            }
        };
        // the worker-child leg of the trace: the driver-minted id from
        // the request frame, timestamped on this process's stderr
        if let Some(t) = &trace {
            crate::obs::log!("worker", "run id {id} start (trace {t})");
        }
        // prove liveness while the (possibly long) run executes; the
        // guard stops and joins the pump before the terminal frame
        let result = {
            let pump_out = Arc::clone(&out);
            let _pump = heartbeat_pump(move || match (Frame::Heartbeat { id }).to_line() {
                Ok(line) => {
                    let mut o = pump_out.lock().expect("worker stdout lock");
                    o.write_all(line.as_bytes()).and_then(|()| o.flush()).is_ok()
                }
                Err(_) => true,
            });
            crate::experiment::Experiment::from_config(cfg).and_then(|mut exp| {
                if stream {
                    // bridge the typed observer stream back to the
                    // dispatcher as batched Events frames (best-effort;
                    // the run never fails on a streaming problem)
                    exp.observe(Box::new(StreamObserver::new(
                        id,
                        Arc::clone(&out),
                        exp.config().name.clone(),
                        trace.clone(),
                    )));
                }
                exp.run()
            })
        };
        match result {
            Ok(report) => write_frame(&Frame::RunResult { id, report })?,
            Err(e) => write_frame(&Frame::Error { id, message: format!("{e:#}") })?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_through_lines() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "proto_rt".into();
        cfg.nodes = 3;
        cfg.sync.qsgd_levels = 15;
        let line =
            (Frame::RunRequest { id: 7, cfg: cfg.clone(), trace: None, stream: false })
                .to_line()
                .unwrap();
        assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
        assert!(!line.contains("trace"), "an absent trace id must not serialize: {line}");
        assert!(!line.contains("stream"), "stream=false must not serialize: {line}");
        match Frame::parse(&line).unwrap() {
            Frame::RunRequest { id, cfg: back, trace, stream } => {
                assert_eq!(id, 7);
                assert_eq!(back.name, "proto_rt");
                assert_eq!(back.nodes, 3);
                assert_eq!(trace, None);
                assert!(!stream, "absent stream flag parses as off");
                // the canonical text is the equality witness: every
                // result-affecting knob survived the wire
                assert_eq!(back.to_toml_string().unwrap(), cfg.to_toml_string().unwrap());
            }
            other => panic!("wrong frame {other:?}"),
        }

        // the v5 trace id rides beside the config, never inside it; the
        // v6 stream flag asks for Events batches back
        let traced = (Frame::RunRequest {
            id: 8,
            cfg: cfg.clone(),
            trace: Some("9f2c41aa03de77b1".into()),
            stream: true,
        })
        .to_line()
        .unwrap();
        match Frame::parse(&traced).unwrap() {
            Frame::RunRequest { id, cfg: back, trace, stream } => {
                assert_eq!(id, 8);
                assert_eq!(trace.as_deref(), Some("9f2c41aa03de77b1"));
                assert!(stream, "the stream flag survives the wire");
                assert!(
                    !back.to_toml_string().unwrap().contains("9f2c41aa03de77b1"),
                    "the trace id must never leak into the config"
                );
            }
            other => panic!("wrong frame {other:?}"),
        }

        // v6 events: a batch of journal-shaped lines for one run
        let batch = vec![
            "{\"schema\":1,\"event\":\"run.sync\"}".to_string(),
            "{\"schema\":1,\"event\":\"run.end\"}".to_string(),
        ];
        let ev = (Frame::Events { id: 8, lines: batch.clone() }).to_line().unwrap();
        assert!(ev.ends_with('\n') && !ev[..ev.len() - 1].contains('\n'));
        match Frame::parse(&ev).unwrap() {
            Frame::Events { id, lines } => {
                assert_eq!(id, 8);
                assert_eq!(lines, batch, "lines survive the wire byte-for-byte");
            }
            other => panic!("wrong frame {other:?}"),
        }
        assert_eq!((Frame::Events { id: 8, lines: vec![] }).kind(), "events");
        assert_eq!((Frame::Events { id: 8, lines: vec![] }).id(), 8);
        let missing = format!("{{\"type\":\"events\",\"id\":8,\"v\":{PROTO_VERSION}}}");
        assert!(Frame::parse(&missing).unwrap_err().to_string().contains("lines"));

        let hb = (Frame::Heartbeat { id: 3 }).to_line().unwrap();
        assert!(
            hb.contains(&format!("\"v\":{PROTO_VERSION}")),
            "every frame carries the version header: {hb}"
        );
        assert!(matches!(Frame::parse(&hb).unwrap(), Frame::Heartbeat { id: 3 }));

        let err = (Frame::Error { id: 9, message: "boom".into() }).to_line().unwrap();
        match Frame::parse(&err).unwrap() {
            Frame::Error { id, message } => {
                assert_eq!((id, message.as_str()), (9, "boom"));
            }
            other => panic!("wrong frame {other:?}"),
        }

        let crashed =
            (Frame::Crashed { id: 4, message: "child died".into() }).to_line().unwrap();
        match Frame::parse(&crashed).unwrap() {
            Frame::Crashed { id, message } => {
                assert_eq!((id, message.as_str()), (4, "child died"));
            }
            other => panic!("wrong frame {other:?}"),
        }

        let challenge = (Frame::Challenge { nonce: "abc123".into() }).to_line().unwrap();
        match Frame::parse(&challenge).unwrap() {
            Frame::Challenge { nonce } => assert_eq!(nonce, "abc123"),
            other => panic!("wrong frame {other:?}"),
        }
        let hello = (Frame::Hello { proof: "deadbeef".into() }).to_line().unwrap();
        match Frame::parse(&hello).unwrap() {
            Frame::Hello { proof } => assert_eq!(proof, "deadbeef"),
            other => panic!("wrong frame {other:?}"),
        }
        let ack = (Frame::HelloAck { slots: 6 }).to_line().unwrap();
        match Frame::parse(&ack).unwrap() {
            Frame::HelloAck { slots } => assert_eq!(slots, 6),
            other => panic!("wrong frame {other:?}"),
        }
        assert_eq!((Frame::Hello { proof: String::new() }).id(), 0);
        assert_eq!((Frame::Challenge { nonce: String::new() }).id(), 0);

        let cancel = (Frame::Cancel { id: 11 }).to_line().unwrap();
        assert!(matches!(Frame::parse(&cancel).unwrap(), Frame::Cancel { id: 11 }));
        let req = (Frame::BlobRequest { id: 5, digest: "0a0b".into() }).to_line().unwrap();
        match Frame::parse(&req).unwrap() {
            Frame::BlobRequest { id, digest } => {
                assert_eq!((id, digest.as_str()), (5, "0a0b"));
            }
            other => panic!("wrong frame {other:?}"),
        }
        let missing =
            format!("{{\"type\":\"blob_request\",\"id\":5,\"v\":{PROTO_VERSION}}}");
        assert!(Frame::parse(&missing).unwrap_err().to_string().contains("digest"));

        let sreq = (Frame::StatsRequest { id: 21 }).to_line().unwrap();
        assert!(matches!(Frame::parse(&sreq).unwrap(), Frame::StatsRequest { id: 21 }));
        let stats = (Frame::Stats {
            id: 21,
            stats: Json::obj(vec![("slots", Json::num(4.0)), ("in_flight", Json::num(1.0))]),
        })
        .to_line()
        .unwrap();
        match Frame::parse(&stats).unwrap() {
            Frame::Stats { id, stats } => {
                assert_eq!(id, 21);
                assert_eq!(stats.get("slots").unwrap().as_f64(), Some(4.0));
                assert_eq!(stats.get("in_flight").unwrap().as_f64(), Some(1.0));
            }
            other => panic!("wrong frame {other:?}"),
        }

        assert!(Frame::parse(&format!("{{\"type\":\"warp\",\"id\":1,\"v\":{PROTO_VERSION}}}"))
            .is_err());
        assert!(Frame::parse("not json").is_err());
    }

    #[test]
    fn auth_proof_binds_token_and_nonce_without_leaking_either() {
        let p = auth_proof("nonce-1", "secret");
        // deterministic, hex-shaped, and bound to both inputs
        assert_eq!(p, auth_proof("nonce-1", "secret"));
        assert_eq!(p.len(), 32);
        assert!(p.chars().all(|c| c.is_ascii_hexdigit()));
        assert_ne!(p, auth_proof("nonce-2", "secret"), "proof must vary with the nonce");
        assert_ne!(p, auth_proof("nonce-1", "other"), "proof must vary with the token");
        // the proof never contains the secret or the raw nonce
        assert!(!p.contains("secret") && !p.contains("nonce-1"));
        // tokenless agents still get a nonce-bound (integrity-only) proof
        assert_ne!(auth_proof("a", ""), auth_proof("b", ""));
    }

    #[test]
    fn blob_frames_roundtrip_hex_on_the_jsonl_path() {
        // all 256 byte values, so the hex codec has no blind spots
        let bytes: Vec<u8> = (0u16..=255).map(|b| b as u8).collect();
        let line =
            (Frame::Blob { id: 12, tag: "snapshot".into(), bytes: bytes.clone() })
                .to_line()
                .unwrap();
        assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
        match Frame::parse(&line).unwrap() {
            Frame::Blob { id, tag, bytes: back } => {
                assert_eq!((id, tag.as_str()), (12, "snapshot"));
                assert_eq!(back, bytes);
            }
            other => panic!("wrong frame {other:?}"),
        }

        // empty payloads are legal (a zero-length artifact is still an answer)
        let empty = (Frame::Blob { id: 1, tag: "t".into(), bytes: vec![] }).to_line().unwrap();
        match Frame::parse(&empty).unwrap() {
            Frame::Blob { bytes, .. } => assert!(bytes.is_empty()),
            other => panic!("wrong frame {other:?}"),
        }

        // corrupt hex is a parse error, not a garbage payload
        let odd = format!("{{\"type\":\"blob\",\"id\":2,\"tag\":\"t\",\"hex\":\"abc\",\"v\":{PROTO_VERSION}}}");
        assert!(Frame::parse(&odd).unwrap_err().to_string().contains("odd length"));
        let bad = format!("{{\"type\":\"blob\",\"id\":2,\"tag\":\"t\",\"hex\":\"zz\",\"v\":{PROTO_VERSION}}}");
        assert!(Frame::parse(&bad).unwrap_err().to_string().contains("invalid digit"));
        let missing = format!("{{\"type\":\"blob\",\"id\":2,\"tag\":\"t\",\"v\":{PROTO_VERSION}}}");
        assert!(Frame::parse(&missing).unwrap_err().to_string().contains("hex"));
    }

    #[test]
    fn version_skew_is_a_typed_clear_error() {
        // unversioned (pre-v2) frame
        let err = Frame::parse("{\"type\":\"heartbeat\",\"id\":1}").unwrap_err();
        assert!(err.is::<VersionSkew>(), "{err:#}");
        assert_eq!(err.downcast_ref::<VersionSkew>(), Some(&VersionSkew { got: None }));
        let msg = format!("{err:#}");
        assert!(msg.contains("protocol version skew"), "{msg}");
        assert!(msg.contains("unversioned"), "{msg}");
        // versioned but different
        let err = Frame::parse("{\"type\":\"heartbeat\",\"id\":1,\"v\":999}").unwrap_err();
        assert_eq!(err.downcast_ref::<VersionSkew>(), Some(&VersionSkew { got: Some(999) }));
        let msg = format!("{err:#}");
        assert!(msg.contains("999") && msg.contains("protocol version skew"), "{msg}");
    }

    #[test]
    fn serve_survives_malformed_and_unexpected_frames() {
        let mut quick = ExperimentConfig::default();
        quick.name = "serve_resilient".into();
        quick.nodes = 2;
        quick.iters = 20;
        quick.batch_per_node = 8;
        quick.eval_every = 10;
        quick.workload.input_dim = 16;
        quick.workload.hidden = 8;
        quick.workload.eval_batches = 2;
        quick.optim.schedule = crate::config::LrSchedule::Const;
        quick.sync.strategy = crate::period::Strategy::Constant;
        quick.sync.period = 4;

        // five poison lines, then a valid request: the worker must
        // answer each defect with an Error frame and keep serving
        // (id 5: a run_request whose cfg is not even a string; id 7: a
        // version-skewed frame from a mismatched binary)
        let input = format!(
            "not json at all\n\
             {{\"type\":\"heartbeat\",\"id\":9,\"v\":{v}}}\n\
             {{\"type\":\"run_request\",\"id\":5,\"cfg\":42,\"v\":{v}}}\n\
             {{\"type\":\"warp\",\"id\":6,\"v\":{v}}}\n\
             {{\"type\":\"run_request\",\"id\":7,\"cfg\":\"\"}}\n\
             {}",
            (Frame::RunRequest { id: 3, cfg: quick, trace: None, stream: false })
                .to_line()
                .unwrap(),
            v = PROTO_VERSION,
        );
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        serve(input.as_bytes(), SharedBuf(Arc::clone(&out))).unwrap();
        let bytes = out.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let frames: Vec<Frame> = text.lines().map(|l| Frame::parse(l).unwrap()).collect();
        let error_for = |want: u64| {
            frames
                .iter()
                .find_map(|f| match f {
                    Frame::Error { id, message } if *id == want => Some(message.clone()),
                    _ => None,
                })
                .unwrap_or_else(|| panic!("no error frame for id {want} in {text}"))
        };
        // garbage carries no id: best-effort 0
        assert!(error_for(0).contains("malformed request"));
        // a non-request frame echoes its own id
        assert!(error_for(9).contains("expected a run_request"));
        // a request whose cfg fails to parse keeps its id, so the
        // dispatcher can fail that run deterministically
        assert!(error_for(5).contains("malformed request"));
        assert!(error_for(6).contains("malformed request"));
        // a version-skewed peer gets the clear skew diagnosis, not a
        // generic parse failure
        assert!(error_for(7).contains("protocol version skew"), "{}", error_for(7));
        // and the valid request after all that still executes
        let result = frames.iter().find_map(|f| match f {
            Frame::RunResult { id: 3, report } => Some(report),
            _ => None,
        });
        assert_eq!(result.expect("run 3 must still be served").iters, 20);
    }

    #[test]
    fn serve_runs_a_request_and_reports_errors() {
        let mut quick = ExperimentConfig::default();
        quick.name = "serve_ok".into();
        quick.nodes = 2;
        quick.iters = 20;
        quick.batch_per_node = 8;
        quick.eval_every = 10;
        quick.workload.input_dim = 16;
        quick.workload.hidden = 8;
        quick.workload.eval_batches = 2;
        quick.optim.schedule = crate::config::LrSchedule::Const;
        quick.sync.strategy = crate::period::Strategy::Constant;
        quick.sync.period = 4;

        let mut bad = quick.clone();
        bad.name = "serve_bad".into();
        bad.workload.backend = crate::config::Backend::Native("failing:0:5".into());

        let input = format!(
            "{}{}",
            (Frame::RunRequest { id: 1, cfg: quick, trace: None, stream: true })
                .to_line()
                .unwrap(),
            (Frame::RunRequest { id: 2, cfg: bad, trace: None, stream: false })
                .to_line()
                .unwrap(),
        );
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        serve(input.as_bytes(), SharedBuf(Arc::clone(&out))).unwrap();
        let bytes = out.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let frames: Vec<Frame> =
            text.lines().map(|l| Frame::parse(l).unwrap()).collect();
        let result = frames
            .iter()
            .find_map(|f| match f {
                Frame::RunResult { id: 1, report } => Some(report),
                _ => None,
            })
            .expect("run 1 succeeds");
        assert_eq!(result.iters, 20);
        assert_eq!(result.syncs, 5);
        let msg = frames
            .iter()
            .find_map(|f| match f {
                Frame::Error { id: 2, message } => Some(message.clone()),
                _ => None,
            })
            .expect("run 2 fails deterministically");
        assert!(msg.contains("injected failure"), "{msg}");

        // run 1 asked for streaming: its Events batches carry
        // journal-shaped run.* lines ending with the terminal run.end
        let streamed: Vec<crate::util::json::Json> = frames
            .iter()
            .filter_map(|f| match f {
                Frame::Events { id: 1, lines } => Some(lines.clone()),
                _ => None,
            })
            .flatten()
            .map(|l| {
                crate::obs::journal::parse_line(&l).expect("streamed lines are journal-shaped")
            })
            .collect();
        assert!(!streamed.is_empty(), "stream=true must produce Events batches");
        assert!(streamed
            .iter()
            .any(|l| l.get("event").unwrap().as_str() == Some("run.end")));
        assert!(streamed
            .iter()
            .all(|l| l.get("run").unwrap().as_str() == Some("serve_ok")));
        // run 2 left the flag off: no Events frames for it
        assert!(!frames.iter().any(|f| matches!(f, Frame::Events { id: 2, .. })));
    }
}
