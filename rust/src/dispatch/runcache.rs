//! Persistent, content-addressed run cache: canonical-config digest →
//! serialized [`RunReport`] on disk.
//!
//! The cache key is a digest of the *fully-resolved* config's canonical
//! text ([`crate::config::ExperimentConfig::to_doc`]) restricted to the
//! knobs that affect results.  Deliberate cache-busting policy:
//!
//! * **hashed** — everything that changes what a run computes or
//!   reports: seed, cluster geometry, iteration count, workload, optim
//!   schedule, every strategy knob (nested `[sync.<strategy>]` form),
//!   the collective algorithm, the network cost model, and the
//!   eval/variance cadences (they shape the recorded series);
//! * **content-addressed indirections** — a warm start hashes the
//!   *bytes* of the resolved `init_from` snapshot, and an HLO workload
//!   hashes the artifacts `manifest.json` bytes, so editing either
//!   busts the entry even though the configured path is unchanged.  A
//!   `blob:<digest>` reference (the fleet's wire form for a staged
//!   snapshot; see [`super::fleet::blobs`]) contributes the digest
//!   directly, so driver and agent agree on the key even when only one
//!   of them holds the bytes;
//! * **not hashed** — knobs that cannot change results: the run name,
//!   checkpoint cadence/paths (instrumentation), the artifacts
//!   *directory path* (its manifest content is hashed instead), the
//!   unused `threads` hint, and `perf.threads` (the tensor kernels are
//!   bit-identical at any thread count, so it cannot change results).
//!
//! A hit reproduces the run's *report*; it does not replay output side
//! effects (a cached run writes no new checkpoint files — delete the
//! entry or pass `--no-cache` if you need the snapshots themselves).
//! The digest keys *configs*, not code: entries written by an older
//! binary stay valid across rebuilds, so clear the cache directory (or
//! use a fresh one) after a change to training semantics, like any
//! content-addressed build cache.
//!
//! Entries are single JSON files (`<digest>.run.json`) carrying the
//! digest, the canonical config text (for debugging and paranoia
//! re-verification), and the full report — scalar summary, per-kind
//! communication ledger, and every recorded metric series — so a cache
//! hit reproduces the original [`RunReport`] bit-for-bit.  A corrupted
//! or version-skewed entry is discarded (and deleted best-effort), never
//! trusted.  Writes are atomic (unique temp file + rename), so
//! concurrent workers that race on the same key leave one valid entry.
//!
//! Long-lived cache directories are bounded by [`RunCache::gc`]
//! (size/age eviction oldest-first plus a sweep of orphaned `.tmp`
//! files), wired to `adpsgd cache-gc` and `adpsgd campaign
//! --cache-max-bytes`; [`RunCache::gc_plan`] is the dry-run form
//! (`adpsgd cache-gc --dry-run`) reporting the exact victims — paths,
//! bytes, ages — a real pass would delete.  Eviction is always safe: a
//! probe of an evicted key simply recomputes.

use crate::config::{spec, ExperimentConfig};
use crate::coordinator::RunReport;
use crate::metrics::Recorder;
use crate::netsim::CommLedger;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

/// Cache-entry schema version; bump on any layout change.
/// v2: reports carry `modeled_wall_secs` (the cluster-clock wall time).
const ENTRY_VERSION: f64 = 2.0;

// ----------------------------------------------------------------- digest

fn fnv64(bytes: &[u8], seed: u64) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// 128-bit content digest (two independently-seeded FNV-1a streams) as
/// 32 hex chars.
pub fn content_digest(bytes: &[u8]) -> String {
    format!(
        "{:016x}{:016x}",
        fnv64(bytes, 0xCBF2_9CE4_8422_2325),
        fnv64(bytes, 0x9E37_79B9_7F4A_7C15)
    )
}

/// The canonical result-affecting text of a config — what
/// [`cfg_digest`] hashes.  Exposed for tests and cache debugging.
pub fn cfg_canonical_text(cfg: &ExperimentConfig) -> Result<String> {
    let mut doc = cfg.to_doc();
    // incidental knobs: cannot affect the training computation or the
    // recorded series/ledger
    for key in [
        "name",
        "checkpoint_dir",
        "checkpoint_every",
        "artifacts_dir",
        "threads",
        "perf.threads",
        "init_from",
    ] {
        doc.entries.remove(key);
    }
    let mut text = doc.render().map_err(|e| anyhow!("canonicalizing config: {e}"))?;
    if !cfg.init_from.is_empty() {
        if let Some(digest) = cfg.init_from.strip_prefix(super::fleet::blobs::BLOB_SCHEME) {
            // an already content-addressed reference (`blob:<digest>`,
            // the fleet's wire form): the digest IS the content hash,
            // so the canonical text — and therefore the cache key — is
            // identical whether this end holds the bytes or not.  This
            // is what lets an agent probe its cache before pulling the
            // snapshot over a BlobRequest.
            text.push_str(&format!("init_from_digest = \"{digest}\"\n"));
        } else {
            // hash the snapshot *content*, not its path: moving the
            // file is incidental, editing it is not
            let p = Path::new(&cfg.init_from);
            let resolved = if p.is_dir() {
                crate::checkpoint::Checkpoint::latest(p).ok().flatten()
            } else {
                Some(p.to_path_buf())
            };
            match resolved.and_then(|f| std::fs::read(f).ok()) {
                Some(bytes) => text
                    .push_str(&format!("init_from_digest = \"{}\"\n", content_digest(&bytes))),
                // unreadable: fall back to the path (the run will fail
                // with its own actionable error; the key just has to be
                // distinct)
                None => text.push_str(&format!("init_from_path = \"{}\"\n", cfg.init_from)),
            }
        }
    }
    if let crate::config::Backend::Hlo(_) = &cfg.workload.backend {
        let manifest = Path::new(&cfg.artifacts_dir).join("manifest.json");
        match std::fs::read(&manifest) {
            Ok(bytes) => text
                .push_str(&format!("manifest_digest = \"{}\"\n", content_digest(&bytes))),
            Err(_) => text.push_str(&format!(
                "manifest_path = \"{}\"\n",
                manifest.to_string_lossy()
            )),
        }
    }
    Ok(text)
}

/// The run-cache key for a fully-resolved config.
pub fn cfg_digest(cfg: &ExperimentConfig) -> Result<String> {
    Ok(content_digest(cfg_canonical_text(cfg)?.as_bytes()))
}

// ---------------------------------------------------- report (de)serialize

/// Full-fidelity [`RunReport`] serialization (unlike
/// [`RunReport::to_json`], which is a human-facing summary): includes
/// the per-kind ledger and every recorded series, and round-trips
/// bit-exactly through [`report_from_json`].
pub fn report_to_json(report: &RunReport) -> Json {
    let series = Json::Obj(
        report
            .recorder
            .series
            .iter()
            .map(|(name, s)| {
                let pts = Json::Arr(
                    s.points
                        .iter()
                        .map(|(x, y)| Json::Arr(vec![Json::num(*x), Json::num(*y)]))
                        .collect(),
                );
                (name.clone(), pts)
            })
            .collect(),
    );
    let mut pairs = report_scalar_pairs(report);
    pairs.push(("series", series));
    Json::obj(pairs)
}

/// Everything [`report_to_json`] carries except the (potentially
/// multi-MB) metric series — the scalar summary plus the ledger.
/// Shared between the JSON cache-entry form and the binary proto-v3
/// bulk form, which ships the series as raw f64 pairs instead.
fn report_scalar_pairs(report: &RunReport) -> Vec<(&'static str, Json)> {
    vec![
        ("name", Json::str(report.name.clone())),
        ("strategy", Json::str(spec::canonical_name(report.strategy))),
        ("nodes", Json::num(report.nodes as f64)),
        ("iters", Json::num(report.iters as f64)),
        ("n_params", Json::num(report.n_params as f64)),
        ("final_train_loss", Json::num(report.final_train_loss)),
        ("min_train_loss", Json::num(report.min_train_loss)),
        ("best_eval_acc", Json::num(report.best_eval_acc)),
        ("final_eval_acc", Json::num(report.final_eval_acc)),
        ("final_eval_loss", Json::num(report.final_eval_loss)),
        ("syncs", Json::num(report.syncs as f64)),
        ("compute_secs", Json::num(report.compute_secs)),
        ("wall_secs", Json::num(report.wall_secs)),
        ("modeled_wall_secs", Json::num(report.modeled_wall_secs)),
        ("ledger", report.ledger.to_json()),
    ]
}

/// Rebuild a [`RunReport`] serialized by [`report_to_json`].
pub fn report_from_json(v: &Json) -> Result<RunReport> {
    let mut recorder = Recorder::new();
    let series = v
        .get("series")
        .and_then(|x| x.as_obj())
        .ok_or_else(|| anyhow!("report json: missing \"series\""))?;
    for (name, pts) in series {
        let pts =
            pts.as_arr().ok_or_else(|| anyhow!("report json: series {name:?} not an array"))?;
        for p in pts {
            let xy = p
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("report json: series {name:?} has a malformed point"))?;
            let coord = |j: &Json| -> f64 {
                match j {
                    Json::Null => f64::NAN,
                    other => other.as_f64().unwrap_or(f64::NAN),
                }
            };
            recorder.push(name, coord(&xy[0]), coord(&xy[1]));
        }
    }
    report_from_parts(v, recorder)
}

/// The scalar half of [`report_from_json`]: every field except the
/// series, which the caller has already decoded into `recorder` (from
/// JSON arrays or from the binary form's raw f64 pairs).
fn report_from_parts(v: &Json, recorder: Recorder) -> Result<RunReport> {
    // non-finite floats serialize as JSON null; they come back as the
    // canonical NaN — exactly what the coordinator's `unwrap_or(NAN)`
    // readouts produce
    let float = |key: &str| -> Result<f64> {
        match v.get(key) {
            Some(Json::Null) => Ok(f64::NAN),
            Some(x) => {
                x.as_f64().ok_or_else(|| anyhow!("report json: {key:?} is not a number"))
            }
            None => Err(anyhow!("report json: missing {key:?}")),
        }
    };
    let int = |key: &str| -> Result<u64> { float(key).map(|x| x as u64) };
    let strategy: crate::period::Strategy = v
        .get("strategy")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow!("report json: missing \"strategy\""))?
        .parse()?;
    let ledger = CommLedger::from_json(
        v.get("ledger").ok_or_else(|| anyhow!("report json: missing \"ledger\""))?,
    )?;
    let iters = int("iters")? as usize;
    let syncs = int("syncs")?;
    // recomputed, not parsed: ∞ (a run that never synchronized) has no
    // JSON representation, and recomputing keeps the hit bit-identical
    let avg_period = if syncs > 0 { iters as f64 / syncs as f64 } else { f64::INFINITY };
    Ok(RunReport {
        name: v
            .get("name")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("report json: missing \"name\""))?
            .to_string(),
        strategy,
        nodes: int("nodes")? as usize,
        iters,
        n_params: int("n_params")? as usize,
        final_train_loss: float("final_train_loss")?,
        min_train_loss: float("min_train_loss")?,
        best_eval_acc: float("best_eval_acc")?,
        final_eval_acc: float("final_eval_acc")?,
        final_eval_loss: float("final_eval_loss")?,
        syncs,
        avg_period,
        compute_secs: float("compute_secs")?,
        wall_secs: float("wall_secs")?,
        modeled_wall_secs: float("modeled_wall_secs")?,
        ledger,
        recorder,
    })
}

// --------------------------------------------------- report binary form

/// Magic + format version prefixing [`report_to_bytes`] output.
const REPORT_BYTES_MAGIC: &[u8; 4] = b"ADPB";
/// v2: the scalar header carries `modeled_wall_secs`.
const REPORT_BYTES_VERSION: u16 = 2;

/// Binary full-fidelity [`RunReport`] serialization — the proto-v3 bulk
/// payload.  The scalar summary travels as the same compact JSON header
/// [`report_to_json`] produces (minus `"series"`); every recorded
/// series follows as length-prefixed raw little-endian f64 `(x, y)`
/// pairs.  Multi-MB float series cross the wire without any decimal
/// formatting or parsing, and NaN payload bits survive exactly (the
/// JSON form maps every non-finite value to null → canonical NaN).
/// Disk cache entries stay JSON; only the agent wire path uses this.
pub fn report_to_bytes(report: &RunReport) -> Result<Vec<u8>> {
    let head = Json::obj(report_scalar_pairs(report)).to_string_compact();
    let n_points: usize = report.recorder.series.iter().map(|(_, s)| s.points.len()).sum();
    let mut buf = Vec::with_capacity(head.len() + 64 + n_points * 16);
    buf.extend_from_slice(REPORT_BYTES_MAGIC);
    buf.extend_from_slice(&REPORT_BYTES_VERSION.to_be_bytes());
    buf.extend_from_slice(&u32::try_from(head.len()).context("report header too large")?.to_be_bytes());
    buf.extend_from_slice(head.as_bytes());
    let n_series =
        u32::try_from(report.recorder.series.len()).context("too many series")?;
    buf.extend_from_slice(&n_series.to_be_bytes());
    for (name, s) in report.recorder.series.iter() {
        buf.extend_from_slice(
            &u16::try_from(name.len()).context("series name too long")?.to_be_bytes(),
        );
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(
            &u32::try_from(s.points.len()).context("series too long")?.to_be_bytes(),
        );
        for (x, y) in &s.points {
            buf.extend_from_slice(&x.to_le_bytes());
            buf.extend_from_slice(&y.to_le_bytes());
        }
    }
    Ok(buf)
}

/// Bounds-checked cursor over [`report_to_bytes`] output: every read is
/// validated so a truncated or corrupt payload is a clean error.
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("report bytes truncated at offset {}", self.pos))?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Rebuild a [`RunReport`] serialized by [`report_to_bytes`].
pub fn report_from_bytes(bytes: &[u8]) -> Result<RunReport> {
    let mut r = ByteReader { buf: bytes, pos: 0 };
    if r.take(4)? != REPORT_BYTES_MAGIC {
        return Err(anyhow!("report bytes: bad magic (not an ADPB payload)"));
    }
    let ver = r.u16()?;
    if ver != REPORT_BYTES_VERSION {
        return Err(anyhow!(
            "report bytes: format version {ver} (this build reads {REPORT_BYTES_VERSION})"
        ));
    }
    let head_len = r.u32()? as usize;
    let head = std::str::from_utf8(r.take(head_len)?)
        .context("report bytes: header is not UTF-8")?;
    let head = Json::parse(head).context("report bytes: malformed header json")?;
    let mut recorder = Recorder::new();
    let n_series = r.u32()?;
    for _ in 0..n_series {
        let name_len = r.u16()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("report bytes: series name is not UTF-8")?
            .to_string();
        let n_points = r.u32()?;
        for _ in 0..n_points {
            let x = r.f64()?;
            let y = r.f64()?;
            recorder.push(&name, x, y);
        }
    }
    if r.pos != bytes.len() {
        return Err(anyhow!("report bytes: {} trailing bytes", bytes.len() - r.pos));
    }
    report_from_parts(&head, recorder)
}

// ------------------------------------------------------------------ cache

/// Eviction policy for [`RunCache::gc`].  The digest keys *configs*,
/// not code, so long-lived cache directories accumulate entries that a
/// semantic change has silently staled — GC bounds that growth.
#[derive(Debug, Clone)]
pub struct GcPolicy {
    /// Evict oldest-first (by file mtime) until the directory's
    /// `*.run.json` total is at most this many bytes.  `None` = no
    /// size bound.
    pub max_bytes: Option<u64>,
    /// Evict every entry whose age (now − mtime) is at least this.
    /// `None` = no age bound.
    pub max_age: Option<Duration>,
    /// Orphaned `.tmp` files (left by a writer that died between write
    /// and rename) at least this old are swept.  The grace period
    /// protects temp files of concurrent in-flight writers.
    pub tmp_grace: Duration,
}

impl Default for GcPolicy {
    fn default() -> Self {
        GcPolicy { max_bytes: None, max_age: None, tmp_grace: Duration::from_secs(15 * 60) }
    }
}

/// One file a GC pass would remove (or did remove).
#[derive(Debug, Clone)]
pub struct GcVictim {
    pub path: PathBuf,
    pub bytes: u64,
    /// now − mtime at plan time (future mtimes count as age zero)
    pub age: Duration,
}

/// What a GC pass *would* do — the dry-run form ([`RunCache::gc_plan`])
/// and the execution plan [`RunCache::gc`] carries out, so
/// `adpsgd cache-gc --dry-run` prints exactly the deletions a real run
/// performs on the same directory state.
#[derive(Debug, Default)]
pub struct GcPlan {
    /// `*.run.json` entries considered.
    pub scanned: usize,
    /// Entries the age/size bounds select for eviction (age victims
    /// first, then size victims oldest-first — deletion order).
    pub evict: Vec<GcVictim>,
    /// Orphaned `.tmp` files past the grace period.
    pub tmp_sweep: Vec<GcVictim>,
    /// Entries surviving the pass.
    pub kept: usize,
    pub kept_bytes: u64,
}

impl GcPlan {
    pub fn evicted_bytes(&self) -> u64 {
        self.evict.iter().map(|v| v.bytes).sum()
    }

    pub fn is_noop(&self) -> bool {
        self.evict.is_empty() && self.tmp_sweep.is_empty()
    }
}

/// What one [`RunCache::gc`] pass did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GcStats {
    /// `*.run.json` entries considered.
    pub scanned: usize,
    /// Entries surviving the pass.
    pub kept: usize,
    pub kept_bytes: u64,
    /// Entries removed by the age or size bound.
    pub evicted: usize,
    pub evicted_bytes: u64,
    /// Orphaned `.tmp` files removed.
    pub tmp_swept: usize,
}

/// A directory of `<digest>.run.json` entries.
pub struct RunCache {
    dir: PathBuf,
}

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

impl RunCache {
    pub fn new(dir: impl Into<PathBuf>) -> RunCache {
        RunCache { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.run.json"))
    }

    /// Canonicalize `cfg`, probe for its report, and restamp a hit
    /// under the requesting run's name (the name is excluded from the
    /// key as incidental, so cross-campaign hits report under the label
    /// that asked).  Returns `(digest, canonical_text, hit)` — the
    /// first two are what [`RunCache::put`] needs after a miss
    /// executes.  This is THE probe: the dispatcher's slot threads and
    /// the remote agent both call it, so the key/restamp semantics can
    /// never diverge between the two cache sites.
    pub fn probe(
        &self,
        cfg: &ExperimentConfig,
    ) -> Result<(String, String, Option<RunReport>)> {
        let canonical = cfg_canonical_text(cfg)?;
        let digest = content_digest(canonical.as_bytes());
        let hit = self.get(&digest).map(|mut report| {
            report.name = cfg.name.clone();
            report
        });
        Ok((digest, canonical, hit))
    }

    /// Look up a cached report.  Any defect — unparseable JSON, schema
    /// version skew, a digest that does not match the file name, a
    /// report that fails to decode — discards the entry (deleting it
    /// best-effort) and returns `None`, so a corrupted cache degrades to
    /// a recompute instead of poisoned results.
    pub fn get(&self, key: &str) -> Option<RunReport> {
        let path = self.path_for(key);
        let text = std::fs::read_to_string(&path).ok()?;
        match Self::decode(key, &text) {
            Ok(report) => Some(report),
            Err(e) => {
                eprintln!(
                    "note: discarding corrupt run-cache entry {} ({e:#})",
                    path.display()
                );
                std::fs::remove_file(&path).ok();
                None
            }
        }
    }

    fn decode(key: &str, text: &str) -> Result<RunReport> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        if v.get("version").and_then(Json::as_f64) != Some(ENTRY_VERSION) {
            return Err(anyhow!("cache entry version skew"));
        }
        let stored = v
            .get("cfg_hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing cfg_hash"))?;
        if stored != key {
            return Err(anyhow!("cfg_hash {stored:?} does not match entry name"));
        }
        report_from_json(v.get("report").ok_or_else(|| anyhow!("missing report"))?)
    }

    /// Store a finished run under `key`.  `cfg_canonical` is the hashed
    /// canonical text, stored alongside for debugging and hash-collision
    /// forensics.
    pub fn put(&self, key: &str, cfg_canonical: &str, report: &RunReport) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating run cache {}", self.dir.display()))?;
        let entry = Json::obj(vec![
            ("version", Json::num(ENTRY_VERSION)),
            ("cfg_hash", Json::str(key)),
            ("cfg", Json::str(cfg_canonical)),
            ("report", report_to_json(report)),
        ]);
        let path = self.path_for(key);
        // unique temp name: concurrent writers of the same key must not
        // clobber each other's half-written files
        let tmp = self.dir.join(format!(
            ".{key}.{}.{}.tmp",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, entry.to_string_compact())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    /// Compute what [`RunCache::gc`] would do under `policy` without
    /// touching the directory — the dry-run entry
    /// (`adpsgd cache-gc --dry-run` prints this plan).
    ///
    /// Age eviction selects first (age ≥ `max_age` goes), then the size
    /// bound selects the oldest survivors (mtime order, path as the
    /// deterministic tiebreak) until the directory's `*.run.json` total
    /// fits in `max_bytes`.  Orphaned `.tmp` files past the grace
    /// period are planned for sweeping.  Foreign files are never
    /// selected; a missing directory is an empty (no-op) plan, not an
    /// error.
    pub fn gc_plan(&self, policy: &GcPolicy) -> Result<GcPlan> {
        let mut plan = GcPlan::default();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(plan),
            Err(e) => {
                return Err(anyhow!(e))
                    .with_context(|| format!("scanning run cache {}", self.dir.display()))
            }
        };
        let now = SystemTime::now();
        // mtimes in the future (clock skew) count as age zero
        let age_of = |modified: SystemTime| now.duration_since(modified).unwrap_or_default();
        let mut live: Vec<(PathBuf, u64, SystemTime)> = Vec::new();
        for entry in entries {
            let entry = entry.context("reading run cache directory")?;
            let path = entry.path();
            let Ok(meta) = entry.metadata() else { continue };
            if !meta.is_file() {
                continue;
            }
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let modified = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
            let age = age_of(modified);
            if name.starts_with('.') && name.ends_with(".tmp") {
                if age >= policy.tmp_grace {
                    plan.tmp_sweep.push(GcVictim { path, bytes: meta.len(), age });
                }
                continue;
            }
            if !name.ends_with(".run.json") {
                continue;
            }
            plan.scanned += 1;
            if let Some(max_age) = policy.max_age {
                if age >= max_age {
                    plan.evict.push(GcVictim { path, bytes: meta.len(), age });
                    continue;
                }
            }
            live.push((path, meta.len(), modified));
        }
        live.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        let mut total: u64 = live.iter().map(|(_, len, _)| len).sum();
        for (path, len, modified) in live {
            if policy.max_bytes.map(|max| total > max).unwrap_or(false) {
                total -= len;
                plan.evict.push(GcVictim { path, bytes: len, age: age_of(modified) });
            } else {
                plan.kept += 1;
                plan.kept_bytes += len;
            }
        }
        Ok(plan)
    }

    /// Evict entries per `policy` and sweep orphaned `.tmp` files —
    /// exactly the deletions [`RunCache::gc_plan`] reports for the same
    /// directory state (the dry-run/real-run parity the unit tests
    /// pin).  Eviction is always safe: a future probe of an evicted key
    /// recomputes.  A file that refuses to delete is counted as kept.
    pub fn gc(&self, policy: &GcPolicy) -> Result<GcStats> {
        let plan = self.gc_plan(policy)?;
        let mut stats = GcStats {
            scanned: plan.scanned,
            kept: plan.kept,
            kept_bytes: plan.kept_bytes,
            ..GcStats::default()
        };
        for v in &plan.tmp_sweep {
            if std::fs::remove_file(&v.path).is_ok() {
                stats.tmp_swept += 1;
            }
        }
        for v in &plan.evict {
            if std::fs::remove_file(&v.path).is_ok() {
                stats.evicted += 1;
                stats.evicted_bytes += v.bytes;
            } else {
                stats.kept += 1;
                stats.kept_bytes += v.bytes;
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml::TomlDoc;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("adpsgd_runcache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn digest_stable_across_key_ordering() {
        // the same resolved config from differently-ordered documents
        let a = TomlDoc::parse(
            "nodes = 4\nseed = 9\n\n[sync]\nstrategy = \"adaptive\"\n\n[sync.adaptive]\np_init = 3\nks_frac = 0.2",
        )
        .unwrap();
        let b = TomlDoc::parse(
            "seed = 9\nnodes = 4\n\n[sync.adaptive]\nks_frac = 0.2\np_init = 3\n\n[sync]\nstrategy = \"adaptive\"",
        )
        .unwrap();
        let ca = ExperimentConfig::from_doc(&a).unwrap();
        let cb = ExperimentConfig::from_doc(&b).unwrap();
        assert_eq!(cfg_digest(&ca).unwrap(), cfg_digest(&cb).unwrap());
    }

    #[test]
    fn digest_ignores_incidental_knobs() {
        let base = ExperimentConfig::default();
        let d0 = cfg_digest(&base).unwrap();
        let mut c = base.clone();
        c.name = "renamed".into();
        c.checkpoint_every = 500;
        c.checkpoint_dir = "/elsewhere".into();
        c.threads = 7;
        c.perf.threads = 5;
        assert_eq!(cfg_digest(&c).unwrap(), d0, "output knobs must not bust the cache");
    }

    #[test]
    fn digest_busts_on_every_result_affecting_knob() {
        let base = ExperimentConfig::default();
        let d0 = cfg_digest(&base).unwrap();
        let busts: Vec<(&str, Box<dyn Fn(&mut ExperimentConfig)>)> = vec![
            ("seed", Box::new(|c| c.seed += 1)),
            ("nodes", Box::new(|c| c.nodes += 1)),
            ("iters", Box::new(|c| c.iters += 1)),
            ("batch", Box::new(|c| c.batch_per_node += 1)),
            ("eval cadence", Box::new(|c| c.eval_every += 1)),
            ("strategy", Box::new(|c| c.sync.strategy = crate::period::Strategy::Constant)),
            ("strategy knob", Box::new(|c| c.sync.p_init += 1)),
            ("foreign table knob", Box::new(|c| c.sync.qsgd_levels = 15)),
            ("collective", Box::new(|c| c.sync.collective = crate::collective::Algo::Flat)),
            ("bandwidth", Box::new(|c| c.net.bandwidth_gbps = 10.0)),
            ("lr", Box::new(|c| c.optim.lr0 = 0.2)),
            ("workload", Box::new(|c| c.workload.hidden += 1)),
            // [cluster] knobs shape the modeled clock, which the report
            // carries — result-affecting by policy
            ("cluster skew", Box::new(|c| c.cluster.skew = "straggler:3.0".into())),
            ("cluster step", Box::new(|c| c.cluster.step_us = 2000.0)),
            ("cluster faults", Box::new(|c| c.cluster.faults.pauses = 1)),
        ];
        for (what, bust) in busts {
            let mut c = base.clone();
            bust(&mut c);
            assert_ne!(cfg_digest(&c).unwrap(), d0, "{what} must bust the cache");
        }
    }

    #[test]
    fn digest_follows_init_from_content_not_path() {
        let dir = tmpdir("init");
        let ck = |seed: f32| crate::checkpoint::Checkpoint::new(5, 0.0, vec![seed; 8]);
        let p1 = dir.join("a.adpk");
        let p2 = dir.join("b.adpk");
        ck(0.5).save(&p1).unwrap();
        ck(0.5).save(&p2).unwrap();
        let mut c1 = ExperimentConfig::default();
        c1.init_from = p1.to_str().unwrap().into();
        let mut c2 = c1.clone();
        c2.init_from = p2.to_str().unwrap().into();
        assert_eq!(
            cfg_digest(&c1).unwrap(),
            cfg_digest(&c2).unwrap(),
            "same snapshot bytes at a different path must hit"
        );
        ck(0.75).save(&p2).unwrap();
        assert_ne!(
            cfg_digest(&c1).unwrap(),
            cfg_digest(&c2).unwrap(),
            "different snapshot bytes must bust"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_missing_dir_is_an_empty_pass() {
        let cache = RunCache::new("/nonexistent/adpsgd_gc_nowhere");
        let stats = cache.gc(&GcPolicy::default()).unwrap();
        assert_eq!(stats, GcStats::default());
    }

    #[test]
    fn gc_sweeps_orphaned_tmp_but_respects_grace() {
        let dir = tmpdir("gc_tmp");
        let cache = RunCache::new(&dir);
        let orphan = dir.join(".deadbeef.12345.0.tmp");
        std::fs::write(&orphan, b"half-written").unwrap();
        // default grace (15 min): a fresh temp file belongs to a
        // possibly-live writer and must survive
        let stats = cache.gc(&GcPolicy::default()).unwrap();
        assert_eq!(stats.tmp_swept, 0);
        assert!(orphan.exists());
        // zero grace: swept
        let stats = cache
            .gc(&GcPolicy { tmp_grace: Duration::ZERO, ..GcPolicy::default() })
            .unwrap();
        assert_eq!(stats.tmp_swept, 1);
        assert!(!orphan.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_evicts_by_size_oldest_first_and_by_age() {
        let dir = tmpdir("gc_size");
        let cache = RunCache::new(&dir);
        // three fake entries with distinct sizes; a foreign file that
        // must never be touched
        let keys = ["aaa0", "bbb1", "ccc2"];
        for (i, key) in keys.iter().enumerate() {
            std::fs::write(cache.path_for(key), vec![b'x'; 100 * (i + 1)]).unwrap();
        }
        std::fs::write(dir.join("README"), b"not a cache entry").unwrap();
        let total = 100 + 200 + 300;
        // no bounds: everything survives
        let stats = cache.gc(&GcPolicy::default()).unwrap();
        assert_eq!((stats.scanned, stats.kept, stats.evicted), (3, 3, 0));
        assert_eq!(stats.kept_bytes, total);
        // size bound below total: oldest entries go until it fits
        // (same-mtime ties break by path, so eviction order is
        // deterministic here too)
        let stats = cache
            .gc(&GcPolicy { max_bytes: Some(total - 1), ..GcPolicy::default() })
            .unwrap();
        assert!(stats.evicted >= 1, "{stats:?}");
        assert!(stats.kept_bytes <= total - 1, "{stats:?}");
        assert_eq!(stats.kept + stats.evicted, 3, "{stats:?}");
        assert!(dir.join("README").exists(), "foreign files are never GC'd");
        // age bound zero: every remaining entry is at least age zero
        let stats = cache
            .gc(&GcPolicy { max_age: Some(Duration::ZERO), ..GcPolicy::default() })
            .unwrap();
        assert_eq!(stats.kept, 0, "{stats:?}");
        assert_eq!(stats.evicted, stats.scanned, "{stats:?}");
        assert!(dir.join("README").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_dry_run_plans_exactly_what_the_real_run_deletes() {
        let dir = tmpdir("gc_dry");
        let cache = RunCache::new(&dir);
        let keys = ["old0", "old1", "new2"];
        for (i, key) in keys.iter().enumerate() {
            std::fs::write(cache.path_for(key), vec![b'x'; 100 * (i + 1)]).unwrap();
        }
        let orphan = dir.join(".cafebabe.1.0.tmp");
        std::fs::write(&orphan, b"half-written").unwrap();
        std::fs::write(dir.join("README"), b"foreign").unwrap();
        let policy = GcPolicy {
            // room for the largest entry only: two must go
            max_bytes: Some(300),
            tmp_grace: Duration::ZERO,
            ..GcPolicy::default()
        };

        // the plan selects victims without touching anything (which
        // entries go depends on the oldest-first tiebreak, so pin the
        // invariants, not the victim identities)
        let plan = cache.gc_plan(&policy).unwrap();
        assert_eq!(plan.scanned, 3);
        assert!(!plan.evict.is_empty(), "{plan:?}");
        assert_eq!(plan.kept + plan.evict.len(), 3, "{plan:?}");
        assert!(plan.kept_bytes <= 300, "{plan:?}");
        assert_eq!(plan.kept_bytes + plan.evicted_bytes(), 600, "{plan:?}");
        assert_eq!(plan.tmp_sweep.len(), 1, "{plan:?}");
        assert!(!plan.is_noop());
        for key in keys {
            assert!(cache.path_for(key).exists(), "dry run must not delete {key}");
        }
        assert!(orphan.exists(), "dry run must not sweep tmp files");

        // the real run performs exactly the planned deletions
        let stats = cache.gc(&policy).unwrap();
        assert_eq!(stats.scanned, plan.scanned);
        assert_eq!(stats.evicted, plan.evict.len());
        assert_eq!(stats.evicted_bytes, plan.evicted_bytes());
        assert_eq!((stats.kept, stats.kept_bytes), (plan.kept, plan.kept_bytes));
        assert_eq!(stats.tmp_swept, plan.tmp_sweep.len());
        for v in plan.evict.iter().chain(&plan.tmp_sweep) {
            assert!(!v.path.exists(), "{} must be gone after gc", v.path.display());
        }
        let survivors = keys
            .iter()
            .filter(|k| cache.path_for(k).exists())
            .count();
        assert_eq!(survivors, plan.kept, "exactly the planned survivors remain");
        assert!(dir.join("README").exists(), "foreign files are never touched");

        // a second plan over the collected directory is a no-op
        let plan = cache.gc_plan(&policy).unwrap();
        assert!(plan.is_noop(), "{plan:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_report() -> RunReport {
        let mut recorder = Recorder::new();
        for i in 0..50 {
            recorder.push("train_loss", i as f64, 1.0 / (i + 1) as f64);
        }
        recorder.push("eval_acc", 10.0, 0.5);
        // a non-canonical NaN payload: the binary form must carry the
        // exact bits (JSON would flatten it to null -> canonical NaN)
        recorder.push("odd", 1.0, f64::from_bits(0x7ff8_dead_beef_0000));
        let mut ledger = CommLedger::new(4);
        ledger.record(
            &crate::netsim::NetModel::infiniband_100g(),
            crate::netsim::CommKind::ParamAvg,
            4,
            1 << 20,
        );
        RunReport {
            name: "bin-roundtrip".into(),
            strategy: crate::period::Strategy::Constant,
            nodes: 4,
            iters: 100,
            n_params: 1234,
            final_train_loss: 0.25,
            min_train_loss: 0.2,
            best_eval_acc: 0.9,
            final_eval_acc: 0.85,
            final_eval_loss: f64::NAN,
            syncs: 10,
            avg_period: 10.0,
            compute_secs: 1.5,
            wall_secs: 2.0,
            modeled_wall_secs: 3.25,
            ledger,
            recorder,
        }
    }

    #[test]
    fn report_bytes_roundtrip_matches_json_form() {
        let report = sample_report();
        let bytes = report_to_bytes(&report).unwrap();
        let back = report_from_bytes(&bytes).unwrap();
        assert_eq!(
            report_to_json(&back).to_string_compact(),
            report_to_json(&report).to_string_compact(),
            "binary roundtrip must reproduce the exact canonical report"
        );
        // and the series floats come back bit-exact, NaN payload included
        let original: Vec<_> = report.recorder.series.iter().collect();
        let returned: Vec<_> = back.recorder.series.iter().collect();
        assert_eq!(original.len(), returned.len());
        for ((n1, s1), (n2, s2)) in original.iter().zip(&returned) {
            assert_eq!(n1, n2);
            assert_eq!(s1.points.len(), s2.points.len(), "series {n1}");
            for ((x1, y1), (x2, y2)) in s1.points.iter().zip(&s2.points) {
                assert_eq!(x1.to_bits(), x2.to_bits(), "series {n1}");
                assert_eq!(y1.to_bits(), y2.to_bits(), "series {n1}");
            }
        }
    }

    #[test]
    fn report_bytes_rejects_truncation_and_garbage() {
        let report = sample_report();
        let bytes = report_to_bytes(&report).unwrap();
        // every strict prefix must be a clean error, never a panic
        for cut in [0, 3, 4, 6, 9, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                report_from_bytes(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must not parse"
            );
        }
        // trailing garbage is a defect too (the frame length said otherwise)
        let mut padded = bytes.clone();
        padded.extend_from_slice(b"xx");
        assert!(report_from_bytes(&padded).is_err(), "trailing bytes must not parse");
        // wrong magic
        let mut bad = bytes;
        bad[0] = b'X';
        let err = report_from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn corrupt_entries_are_discarded() {
        let dir = tmpdir("corrupt");
        let cache = RunCache::new(&dir);
        let key = "00112233445566778899aabbccddeeff";
        std::fs::write(cache.path_for(key), b"{ not json").unwrap();
        assert!(cache.get(key).is_none(), "garbage must miss");
        assert!(!cache.path_for(key).exists(), "garbage must be deleted");
        // wrong embedded hash is a defect too
        std::fs::write(
            cache.path_for(key),
            r#"{"version":1,"cfg_hash":"deadbeef","cfg":"","report":{}}"#,
        )
        .unwrap();
        assert!(cache.get(key).is_none(), "hash mismatch must miss");
        std::fs::remove_dir_all(&dir).ok();
    }
}
