//! Declarative multi-run campaigns: a cartesian sweep over strategy ×
//! nodes × network × collective (× arbitrary named variants), executed
//! with bounded-parallel scheduling.
//!
//! A campaign *describes* every run up front ([`CampaignBuilder::build`]
//! materializes the cross product into labeled, validated
//! [`RunSpec`]s), then hands them to the [`crate::dispatch`] subsystem:
//! [`Campaign::run`] uses the process-default dispatch profile
//! (conservative in-process execution unless a launcher installed one
//! via [`crate::dispatch::set_default_options`]), while
//! [`Campaign::execute`] takes an explicit
//! [`crate::dispatch::DispatchOptions`] (job count, thread vs
//! `adpsgd worker` subprocess vs remote `adpsgd agent` slots,
//! persistent run cache).  Because
//! runs are fully independent coordinator clusters, the pool can run
//! several at once — results are deterministic and ordered regardless
//! of the parallelism level or worker kind, already-cached runs are
//! answered without training, and datasets/manifests are shared across
//! in-process runs through the process-wide caches
//! ([`crate::data::cache`], [`crate::runtime::Manifest::load_cached`]).
//!
//! Every `figures/*` module is a campaign definition plus
//! post-processing; `adpsgd campaign` exposes the same axes on the
//! command line.

use crate::collective::Algo;
use crate::config::{ExperimentConfig, NetConfig, StrategySpec};
use crate::coordinator::RunReport;
use crate::dispatch::{DispatchOptions, Dispatcher};
use crate::metrics::Table;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

type Patch = Arc<dyn Fn(&mut ExperimentConfig) + Send + Sync>;

/// One materialized run of a campaign: a label and a validated config.
pub struct RunSpec {
    pub label: String,
    pub cfg: ExperimentConfig,
}

/// A fully-materialized sweep, ready to execute.
pub struct Campaign {
    pub name: String,
    runs: Vec<RunSpec>,
    parallelism: usize,
}

impl Campaign {
    pub fn builder(name: impl Into<String>, base: ExperimentConfig) -> CampaignBuilder {
        CampaignBuilder {
            name: name.into(),
            base,
            strategies: Vec::new(),
            nodes: Vec::new(),
            nets: Vec::new(),
            collectives: Vec::new(),
            variants: Vec::new(),
            post: None,
            parallelism: 1,
        }
    }

    /// Concatenate several campaigns into one (for non-cartesian unions
    /// like Table I's four run families).  Run order is the
    /// concatenation order; parallelism is the maximum of the parts.
    /// Labels must stay unique across parts (get/take are label-keyed).
    pub fn union(
        name: impl Into<String>,
        parts: impl IntoIterator<Item = Campaign>,
    ) -> Result<Campaign> {
        let name = name.into();
        let mut runs: Vec<RunSpec> = Vec::new();
        let mut parallelism = 1;
        for c in parts {
            parallelism = parallelism.max(c.parallelism);
            for run in c.runs {
                if runs.iter().any(|r| r.label == run.label) {
                    bail!(
                        "campaign union {name:?}: duplicate run label {:?} across parts",
                        run.label
                    );
                }
                runs.push(run);
            }
        }
        Ok(Campaign { name, runs, parallelism })
    }

    pub fn runs(&self) -> &[RunSpec] {
        &self.runs
    }

    pub fn len(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Override the scheduler's worker count after build.
    pub fn with_parallelism(mut self, n: usize) -> Campaign {
        self.parallelism = n.max(1);
        self
    }

    /// Execute under the process-default dispatch profile
    /// ([`crate::dispatch::default_options`]).  With no profile
    /// installed this is the historical conservative behavior: thread
    /// workers, at most `parallelism` concurrent in-process runs, the
    /// process-default run cache (usually disabled; see
    /// [`crate::dispatch::default_cache_dir`]).  A launcher-installed
    /// profile (`adpsgd figures --jobs/--workers/--remote/…`) gives
    /// every implicit campaign the full pool/supervision/remote
    /// treatment; only an explicit `--jobs` overrides the campaign's
    /// own parallelism.  Reports come back in declaration order; the
    /// first failing run aborts the campaign (remaining queued runs are
    /// not started, in-flight ones finish).
    pub fn run(&self) -> Result<CampaignReport> {
        let mut opts = crate::dispatch::default_options();
        if opts.jobs.is_none() {
            opts.jobs = Some(self.parallelism.max(1));
        }
        self.execute(&opts)
    }

    /// Execute through an explicit dispatch profile: job count, thread
    /// vs subprocess workers, run-cache directory, crash retries, hang
    /// deadline (see [`crate::dispatch`]).  Results are identical to
    /// [`Campaign::run`] for any profile — parallelism, worker kind,
    /// and cache hits change wall-clock, never reports.  A campaign
    /// whose sweep resolved to zero runs yields an empty (but stable)
    /// report rather than an error.
    pub fn execute(&self, opts: &DispatchOptions) -> Result<CampaignReport> {
        let wall = std::time::Instant::now();
        if let Some(journal) = &opts.journal {
            journal.emit(
                "campaign.start",
                None,
                vec![
                    ("campaign", Json::str(self.name.clone())),
                    ("runs", Json::num(self.runs.len() as f64)),
                ],
            );
        }
        let dispatched = Dispatcher::new(opts.clone())
            .execute(&self.runs)
            .with_context(|| format!("campaign {:?}", self.name))?;
        if let Some(journal) = &opts.journal {
            journal.emit(
                "campaign.end",
                None,
                vec![
                    ("campaign", Json::str(self.name.clone())),
                    ("wall_secs", Json::num(wall.elapsed().as_secs_f64())),
                ],
            );
        }
        let runs = self
            .runs
            .iter()
            .zip(dispatched)
            .map(|(spec, d)| CampaignRunResult {
                label: spec.label.clone(),
                report: d.report,
                from_cache: d.from_cache,
            })
            .collect();
        Ok(CampaignReport {
            name: self.name.clone(),
            wall_secs: wall.elapsed().as_secs_f64(),
            runs,
        })
    }
}

/// Axis-by-axis description of a campaign; `build()` materializes the
/// cross product.  Empty axes are skipped (they contribute neither a
/// dimension nor a label part).
pub struct CampaignBuilder {
    name: String,
    base: ExperimentConfig,
    strategies: Vec<(String, StrategySpec)>,
    nodes: Vec<usize>,
    nets: Vec<(String, NetConfig)>,
    collectives: Vec<Algo>,
    variants: Vec<(String, Patch)>,
    post: Option<Patch>,
    parallelism: usize,
}

impl CampaignBuilder {
    /// Add one strategy to the strategy axis.
    pub fn strategy(mut self, label: impl Into<String>, spec: StrategySpec) -> Self {
        self.strategies.push((label.into(), spec));
        self
    }

    /// Add many strategies at once.
    pub fn strategies(
        mut self,
        specs: impl IntoIterator<Item = (String, StrategySpec)>,
    ) -> Self {
        self.strategies.extend(specs);
        self
    }

    /// Sweep the cluster size.
    pub fn nodes(mut self, ns: &[usize]) -> Self {
        self.nodes.extend_from_slice(ns);
        self
    }

    /// Add one network preset to the bandwidth axis.
    pub fn net(mut self, label: impl Into<String>, net: NetConfig) -> Self {
        self.nets.push((label.into(), net));
        self
    }

    /// Sweep the collective algorithm.
    pub fn collectives(mut self, algos: &[Algo]) -> Self {
        self.collectives.extend_from_slice(algos);
        self
    }

    /// Add a named config patch to the variant axis (for sweeps the
    /// typed axes don't cover: learning rates, batch geometry, …).
    pub fn variant(
        mut self,
        label: impl Into<String>,
        f: impl Fn(&mut ExperimentConfig) + Send + Sync + 'static,
    ) -> Self {
        self.variants.push((label.into(), Arc::new(f)));
        self
    }

    /// A patch applied to *every* run after all axes (e.g. fixed-work
    /// scaling `iters = K/nodes`).
    pub fn post(mut self, f: impl Fn(&mut ExperimentConfig) + Send + Sync + 'static) -> Self {
        self.post = Some(Arc::new(f));
        self
    }

    /// Maximum concurrent runs (each run is itself a `nodes`-thread
    /// cluster; default 1).
    pub fn parallelism(mut self, n: usize) -> Self {
        self.parallelism = n.max(1);
        self
    }

    /// Materialize and validate every run of the cross product.
    pub fn build(self) -> Result<Campaign> {
        fn axis<T>(v: Vec<T>) -> Vec<Option<T>> {
            if v.is_empty() {
                vec![None]
            } else {
                v.into_iter().map(Some).collect()
            }
        }
        let strategies = axis(self.strategies);
        let nodes = axis(self.nodes);
        let nets = axis(self.nets);
        let collectives = axis(self.collectives);
        let variants = axis(self.variants);

        let mut runs = Vec::new();
        for strat in &strategies {
            for n in &nodes {
                for net in &nets {
                    for algo in &collectives {
                        for var in &variants {
                            let mut cfg = self.base.clone();
                            let mut parts: Vec<String> = Vec::new();
                            if let Some((label, spec)) = strat {
                                spec.validate()
                                    .with_context(|| format!("campaign run {label:?}"))?;
                                spec.apply_to(&mut cfg.sync);
                                parts.push(label.clone());
                            }
                            if let Some(n) = n {
                                cfg.nodes = *n;
                                parts.push(format!("n{n}"));
                            }
                            if let Some((label, net)) = net {
                                cfg.net = net.clone();
                                parts.push(label.clone());
                            }
                            if let Some(algo) = algo {
                                cfg.sync.collective = *algo;
                                parts.push(algo.to_string());
                            }
                            if let Some((label, patch)) = var {
                                patch(&mut cfg);
                                parts.push(label.clone());
                            }
                            if let Some(post) = &self.post {
                                post(&mut cfg);
                            }
                            let label = if parts.is_empty() {
                                self.name.clone()
                            } else {
                                parts.join("_")
                            };
                            if runs.iter().any(|r: &RunSpec| r.label == label) {
                                bail!(
                                    "campaign {:?}: duplicate run label {label:?} \
                                     (axis entries must have distinct labels)",
                                    self.name
                                );
                            }
                            if cfg.checkpoint_every > 0 {
                                // concurrent runs must not race on one
                                // snapshot directory: namespace per label
                                cfg.checkpoint_dir =
                                    std::path::Path::new(&cfg.checkpoint_dir)
                                        .join(&label)
                                        .to_string_lossy()
                                        .into_owned();
                            }
                            cfg.name = label.clone();
                            cfg.validate()
                                .with_context(|| format!("campaign run {label:?}"))?;
                            runs.push(RunSpec { label, cfg });
                        }
                    }
                }
            }
        }
        Ok(Campaign { name: self.name, runs, parallelism: self.parallelism })
    }
}

/// One finished run of a campaign.
pub struct CampaignRunResult {
    pub label: String,
    pub report: RunReport,
    /// whether the report came from the run cache (no training executed)
    pub from_cache: bool,
}

/// Everything a finished campaign reports.
pub struct CampaignReport {
    pub name: String,
    pub wall_secs: f64,
    pub runs: Vec<CampaignRunResult>,
}

impl CampaignReport {
    pub fn try_get(&self, label: &str) -> Option<&RunReport> {
        self.runs.iter().find(|r| r.label == label).map(|r| &r.report)
    }

    pub fn get(&self, label: &str) -> &RunReport {
        self.try_get(label).unwrap_or_else(|| {
            let labels: Vec<&str> = self.runs.iter().map(|r| r.label.as_str()).collect();
            panic!("campaign {:?} has no run {label:?} (runs: {labels:?})", self.name)
        })
    }

    /// Remove and return one run's report by label (for consumers that
    /// need owned reports); panics with the available labels if absent.
    pub fn take(&mut self, label: &str) -> RunReport {
        match self.runs.iter().position(|r| r.label == label) {
            Some(i) => self.runs.remove(i).report,
            None => {
                let labels: Vec<&str> = self.runs.iter().map(|r| r.label.as_str()).collect();
                panic!("campaign {:?} has no run {label:?} (runs: {labels:?})", self.name)
            }
        }
    }

    /// The reports in declaration order (each `RunReport::name` is its
    /// campaign label).
    pub fn reports(self) -> Vec<RunReport> {
        self.runs.into_iter().map(|r| r.report).collect()
    }

    pub fn runs_per_sec(&self) -> f64 {
        self.runs.len() as f64 / self.wall_secs.max(1e-12)
    }

    /// How many runs were answered by the run cache.
    pub fn cache_hits(&self) -> usize {
        self.runs.iter().filter(|r| r.from_cache).count()
    }

    /// Total modeled communication across all runs (each priced under
    /// its own configured network).
    pub fn total_modeled_comm_secs(&self) -> f64 {
        self.runs.iter().map(|r| r.report.ledger.total_secs()).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.runs.iter().map(|r| r.report.ledger.total_wire_bytes()).sum()
    }

    /// Per-run summary table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "run", "strategy", "nodes", "final loss", "best acc", "syncs", "p̄", "wire MB",
            "comm(model)", "wall(model)",
        ]);
        for r in &self.runs {
            let rep = &r.report;
            t.row(&[
                r.label.clone(),
                rep.strategy.to_string(),
                rep.nodes.to_string(),
                format!("{:.4}", rep.final_train_loss),
                format!("{:.4}", rep.best_eval_acc),
                rep.syncs.to_string(),
                format!("{:.2}", rep.avg_period),
                format!("{:.2}", rep.ledger.total_wire_bytes() as f64 / 1e6),
                crate::util::fmt::secs(rep.ledger.total_secs()),
                crate::util::fmt::secs(rep.modeled_wall_secs),
            ]);
        }
        t
    }

    /// Machine-readable campaign summary (per-run one-line summaries,
    /// no series).
    pub fn to_json(&self) -> Json {
        let runs = Json::Arr(
            self.runs
                .iter()
                .map(|r| {
                    let mut obj = match r.report.to_json(false) {
                        Json::Obj(m) => m,
                        _ => unreachable!("run summary is an object"),
                    };
                    obj.insert("label".into(), Json::str(r.label.clone()));
                    Json::Obj(obj)
                })
                .collect(),
        );
        Json::obj(vec![
            ("campaign", Json::str(self.name.clone())),
            ("runs", Json::num(self.runs.len() as f64)),
            ("cache_hits", Json::num(self.cache_hits() as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("runs_per_sec", Json::num(self.runs_per_sec())),
            ("total_modeled_comm_secs", Json::num(self.total_modeled_comm_secs())),
            ("total_wire_bytes", Json::num(self.total_wire_bytes() as f64)),
            ("run_summaries", runs),
        ])
    }

    /// [`Self::to_json`] minus every per-invocation volatile key — the
    /// campaign-level wall clock, throughput, and hit count, *and* each
    /// run summary's measured `wall_secs`/`compute_secs` — leaving only
    /// deterministic quantities (losses, sync counts, modeled
    /// communication).  The *stable* summary is therefore byte-identical
    /// across a warm-cache re-run, a fresh local re-execution, and a
    /// remote execution through `adpsgd agent` — what `adpsgd campaign`
    /// writes to `<name>.campaign.json` and what the verify script
    /// `cmp`s cold-vs-warm and local-vs-remote.
    pub fn to_json_stable(&self) -> Json {
        let mut obj = match self.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("campaign summary is an object"),
        };
        for volatile in ["wall_secs", "runs_per_sec", "cache_hits"] {
            obj.remove(volatile);
        }
        if let Some(Json::Arr(runs)) = obj.get_mut("run_summaries") {
            for run in runs {
                if let Json::Obj(ro) = run {
                    ro.remove("wall_secs");
                    ro.remove("compute_secs");
                }
            }
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LrSchedule;
    use crate::period::Strategy;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 2;
        cfg.iters = 40;
        cfg.batch_per_node = 8;
        cfg.eval_every = 20;
        cfg.workload.input_dim = 24;
        cfg.workload.hidden = 12;
        cfg.workload.eval_batches = 2;
        cfg.optim.schedule = LrSchedule::Const;
        cfg.sync.period = 4;
        cfg.sync.p_init = 2;
        cfg.sync.warmup_iters = 4;
        cfg
    }

    #[test]
    fn cartesian_product_labels_and_order() {
        let c = Campaign::builder("t", tiny_base())
            .strategy("cpsgd", StrategySpec::Constant { period: 4 })
            .strategy("full", StrategySpec::Full)
            .collectives(&[Algo::Ring, Algo::Flat])
            .build()
            .unwrap();
        let labels: Vec<&str> = c.runs().iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["cpsgd_ring", "cpsgd_flat", "full_ring", "full_flat"]);
        assert_eq!(c.runs()[3].cfg.sync.strategy, Strategy::Full);
        assert_eq!(c.runs()[1].cfg.sync.collective, Algo::Flat);
    }

    #[test]
    fn single_axis_keeps_clean_labels() {
        let c = Campaign::builder("t", tiny_base())
            .strategy("fullsgd", StrategySpec::Full)
            .strategy("adpsgd", StrategySpec::default_of(Strategy::Adaptive))
            .build()
            .unwrap();
        let labels: Vec<&str> = c.runs().iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["fullsgd", "adpsgd"]);
    }

    #[test]
    fn invalid_spec_rejected_at_build() {
        let err = Campaign::builder("t", tiny_base())
            .strategy("bad", StrategySpec::Constant { period: 0 })
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("bad"), "{err:#}");
    }

    #[test]
    fn checkpoint_dirs_are_namespaced_per_run() {
        let mut base = tiny_base();
        base.checkpoint_every = 20;
        base.checkpoint_dir = "ckpts".into();
        let c = Campaign::builder("t", base)
            .strategy("a", StrategySpec::Full)
            .strategy("b", StrategySpec::Constant { period: 4 })
            .build()
            .unwrap();
        let dirs: Vec<&str> =
            c.runs().iter().map(|r| r.cfg.checkpoint_dir.as_str()).collect();
        assert_eq!(dirs.len(), 2);
        assert_ne!(dirs[0], dirs[1], "concurrent runs must not share a snapshot dir");
        assert!(dirs[0].starts_with("ckpts"), "{dirs:?}");
    }

    #[test]
    fn duplicate_labels_rejected_at_build() {
        let err = Campaign::builder("t", tiny_base())
            .strategy("same", StrategySpec::Full)
            .strategy("same", StrategySpec::Constant { period: 4 })
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("duplicate run label"), "{err:#}");
    }

    #[test]
    fn take_extracts_owned_reports_by_label() {
        let mut rep = Campaign::builder("t", tiny_base())
            .strategy("cpsgd", StrategySpec::Constant { period: 4 })
            .strategy("full", StrategySpec::Full)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let full = rep.take("full");
        assert_eq!(full.name, "full");
        assert_eq!(rep.runs.len(), 1);
        assert!(rep.try_get("full").is_none());
    }

    #[test]
    fn no_axes_yields_single_base_run() {
        let c = Campaign::builder("t", tiny_base()).build().unwrap();
        // no axes -> exactly one base run, labeled with the campaign name
        assert_eq!(c.len(), 1);
        assert_eq!(c.runs()[0].label, "t");
    }

    #[test]
    fn empty_campaign_reports_cleanly() {
        // a sweep that resolves to zero runs (e.g. a union of nothing)
        // is a valid empty result, not an error
        let empty = Campaign::union("u", []).unwrap();
        assert!(empty.is_empty());
        let rep = empty.run().unwrap();
        assert!(rep.runs.is_empty());
        assert_eq!(rep.cache_hits(), 0);
        assert_eq!(rep.total_wire_bytes(), 0);
        // the stable summary is well-formed and names the campaign
        let stable = rep.to_json_stable().to_string_compact();
        assert!(stable.contains("\"campaign\":\"u\""), "{stable}");
        assert!(stable.contains("\"run_summaries\":[]"), "{stable}");
    }

    #[test]
    fn union_rejects_duplicate_labels_across_parts() {
        let part = |label: &str| {
            Campaign::builder("p", tiny_base())
                .strategy(label, StrategySpec::Full)
                .build()
                .unwrap()
        };
        let err = Campaign::union("u", [part("same"), part("same")]).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate run label"), "{err:#}");
        assert!(Campaign::union("u", [part("a"), part("b")]).is_ok());
    }

    #[test]
    fn campaign_runs_and_reports_in_order() {
        let rep = Campaign::builder("t", tiny_base())
            .strategy("cpsgd", StrategySpec::Constant { period: 4 })
            .strategy("full", StrategySpec::Full)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(rep.runs.len(), 2);
        assert_eq!(rep.runs[0].label, "cpsgd");
        assert_eq!(rep.get("cpsgd").syncs, 10);
        assert_eq!(rep.get("full").syncs, 40);
        assert!(rep.runs_per_sec() > 0.0);
        assert!(rep.total_wire_bytes() > 0);
        let json = rep.to_json().to_string_compact();
        assert!(json.contains("\"campaign\""), "{json}");
        assert!(json.contains("cpsgd"), "{json}");
    }

    #[test]
    fn parallel_scheduling_is_deterministic() {
        let build = |par: usize| {
            Campaign::builder("t", tiny_base())
                .strategy("cpsgd", StrategySpec::Constant { period: 4 })
                .strategy("adpsgd", StrategySpec::default_of(Strategy::Adaptive))
                .strategy("full", StrategySpec::Full)
                .strategy("qsgd", StrategySpec::default_of(Strategy::Qsgd))
                .parallelism(par)
                .build()
                .unwrap()
        };
        let serial = build(1).run().unwrap();
        let parallel = build(3).run().unwrap();
        for (a, b) in serial.runs.iter().zip(&parallel.runs) {
            assert_eq!(a.label, b.label);
            assert_eq!(
                a.report.final_train_loss, b.report.final_train_loss,
                "{}: parallel scheduling must not change results",
                a.label
            );
            assert_eq!(a.report.syncs, b.report.syncs, "{}", a.label);
        }
    }

    #[test]
    fn cached_campaign_is_all_hits_and_byte_identical() {
        let dir = std::env::temp_dir()
            .join(format!("adpsgd_campaign_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let build = || {
            Campaign::builder("t", tiny_base())
                .strategy("cpsgd", StrategySpec::Constant { period: 4 })
                .strategy("full", StrategySpec::Full)
                .build()
                .unwrap()
        };
        let opts = DispatchOptions {
            jobs: Some(2),
            cache_dir: Some(dir.clone()),
            ..DispatchOptions::default()
        };
        let cold = build().execute(&opts).unwrap();
        assert_eq!(cold.cache_hits(), 0);
        let warm = build().execute(&opts).unwrap();
        assert_eq!(warm.cache_hits(), 2, "re-execution must perform zero training");
        assert_eq!(
            cold.to_json_stable().to_string_compact(),
            warm.to_json_stable().to_string_compact(),
            "stable summary must be byte-identical across cold/warm"
        );
        // volatile keys stay out of the stable form but in the live one
        let live = warm.to_json().to_string_compact();
        assert!(live.contains("cache_hits"), "{live}");
        assert!(live.contains("wall_secs"), "{live}");
        let stable = warm.to_json_stable().to_string_compact();
        assert!(!stable.contains("runs_per_sec") && !stable.contains("cache_hits"), "{stable}");
        // per-run measured clocks are volatile too: stripping them is
        // what makes fresh local and remote re-executions byte-identical
        assert!(
            !stable.contains("wall_secs") && !stable.contains("compute_secs"),
            "{stable}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_reexecution_stable_summary_is_byte_identical() {
        // no cache involved: two fresh executions differ only in
        // measured clocks, which the stable summary excludes
        let build = || {
            Campaign::builder("t", tiny_base())
                .strategy("cpsgd", StrategySpec::Constant { period: 4 })
                .strategy("full", StrategySpec::Full)
                .build()
                .unwrap()
        };
        let opts =
            DispatchOptions { jobs: Some(2), cache_dir: None, ..DispatchOptions::default() };
        let a = build().execute(&opts).unwrap();
        let b = build().execute(&opts).unwrap();
        assert_eq!(
            a.to_json_stable().to_string_compact(),
            b.to_json_stable().to_string_compact(),
            "fresh re-executions must agree on the stable summary"
        );
    }

    #[test]
    fn failing_run_aborts_campaign_with_label() {
        let mut bad = tiny_base();
        bad.workload.backend = crate::config::Backend::Native("failing:0:5".into());
        let c = Campaign::builder("t", bad)
            .strategy("boom", StrategySpec::Constant { period: 4 })
            .build()
            .unwrap();
        let err = c.run().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("injected failure"), "{msg}");
    }

    #[test]
    fn variant_and_post_patches_apply_in_order() {
        let c = Campaign::builder("t", tiny_base())
            .strategy("full", StrategySpec::Full)
            .nodes(&[2, 4])
            .variant("lr2", |cfg| cfg.optim.lr0 = 0.2)
            .post(|cfg| cfg.iters = 80 / cfg.nodes)
            .build()
            .unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.runs()[0].label, "full_n2_lr2");
        assert_eq!(c.runs()[0].cfg.iters, 40);
        assert_eq!(c.runs()[1].cfg.iters, 20);
        assert!((c.runs()[1].cfg.optim.lr0 - 0.2).abs() < 1e-6);
    }
}
