//! The session-level experiment API: typed builders over the
//! coordinator, observer plumbing, and declarative multi-run campaigns.
//!
//! One run:
//!
//! ```no_run
//! use adpsgd::config::StrategySpec;
//! use adpsgd::experiment::Experiment;
//!
//! let report = Experiment::builder()
//!     .name("demo")
//!     .nodes(8)
//!     .iters(2_000)
//!     .strategy(StrategySpec::Adaptive {
//!         p_init: 4, warmup_iters: 25, ks_frac: 0.25, low: 0.7, high: 1.3,
//!     })
//!     .build()
//!     .unwrap()
//!     .run()
//!     .unwrap();
//! println!("final loss {:.4}", report.final_train_loss);
//! ```
//!
//! The builder validates at `build()` time: a knob that does not belong
//! to the chosen strategy (`.set("sync.qsgd_levels", …)` under an
//! adaptive spec) is rejected with the valid key list, not silently
//! absorbed.  Observers ([`RunObserver`]) receive the typed event
//! stream from the coordinator loop; a custom [`PeriodController`] can
//! be injected per session, bypassing the registry.
//!
//! Many runs: [`Campaign`] (see [`campaign`]) sweeps strategy × nodes ×
//! network × collective axes with bounded-parallel scheduling and
//! shared dataset caching.

pub mod campaign;

pub use crate::coordinator::observer::{
    CheckpointObserver, ObserverHub, RecorderObserver, RunEvent, RunObserver,
};
pub use campaign::{Campaign, CampaignBuilder, CampaignReport, CampaignRunResult, RunSpec};

use crate::collective::Algo;
use crate::config::{toml::TomlDoc, Backend, ExperimentConfig, NetConfig, StrategySpec};
use crate::coordinator::{run_experiment, ControllerFactory, RunHooks, RunReport};
use crate::period::PeriodController;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One fully-validated experiment, ready to run.
pub struct Experiment {
    cfg: ExperimentConfig,
    observers: Vec<Box<dyn RunObserver>>,
    controller: Option<Arc<ControllerFactory>>,
}

impl Experiment {
    /// Start from the default config.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::from_config(ExperimentConfig::default())
    }

    /// Start from an existing config (a TOML preset, a figure base, …).
    pub fn builder_from(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder::from_config(cfg)
    }

    /// Wrap a config directly (validating it), with no extra hooks.
    pub fn from_config(cfg: ExperimentConfig) -> Result<Experiment> {
        cfg.validate()?;
        Ok(Experiment { cfg, observers: Vec::new(), controller: None })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Attach another observer after build.
    pub fn observe(&mut self, observer: Box<dyn RunObserver>) {
        self.observers.push(observer);
    }

    /// Run to completion, streaming events to the observers.
    pub fn run(self) -> Result<RunReport> {
        run_experiment(
            &self.cfg,
            RunHooks { observers: self.observers, controller: self.controller },
        )
    }
}

/// Builder for [`Experiment`] with build-time validation.
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
    strategy: Option<StrategySpec>,
    overrides: Vec<(String, String)>,
    observers: Vec<Box<dyn RunObserver>>,
    controller: Option<Arc<ControllerFactory>>,
}

impl ExperimentBuilder {
    fn from_config(cfg: ExperimentConfig) -> Self {
        ExperimentBuilder {
            cfg,
            strategy: None,
            overrides: Vec::new(),
            observers: Vec::new(),
            controller: None,
        }
    }

    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.cfg.name = name.into();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn nodes(mut self, nodes: usize) -> Self {
        self.cfg.nodes = nodes;
        self
    }

    pub fn iters(mut self, iters: usize) -> Self {
        self.cfg.iters = iters;
        self
    }

    pub fn batch_per_node(mut self, b: usize) -> Self {
        self.cfg.batch_per_node = b;
        self
    }

    pub fn eval_every(mut self, every: usize) -> Self {
        self.cfg.eval_every = every;
        self
    }

    pub fn variance_every(mut self, every: usize) -> Self {
        self.cfg.variance_every = every;
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.cfg.workload.backend = backend;
        self
    }

    pub fn net(mut self, net: NetConfig) -> Self {
        self.cfg.net = net;
        self
    }

    pub fn collective(mut self, algo: Algo) -> Self {
        self.cfg.sync.collective = algo;
        self
    }

    /// Choose the synchronization strategy by typed spec.
    pub fn strategy(mut self, spec: StrategySpec) -> Self {
        self.strategy = Some(spec);
        self
    }

    /// Checkpoint cadence and directory.
    pub fn checkpoint(mut self, every: usize, dir: impl Into<String>) -> Self {
        self.cfg.checkpoint_every = every;
        self.cfg.checkpoint_dir = dir.into();
        self
    }

    /// Warm-start from a snapshot file or directory.
    pub fn init_from(mut self, path: impl Into<String>) -> Self {
        self.cfg.init_from = path.into();
        self
    }

    /// Set a dotted config key (`"sync.adaptive.p_init"`,
    /// `"optim.lr0"`, …).  Checked against the chosen strategy at
    /// `build()` — misplaced strategy knobs are rejected with the valid
    /// key list.
    pub fn set(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.overrides.push((key.into(), value.into()));
        self
    }

    /// Escape hatch: arbitrary config surgery before validation.
    pub fn configure(mut self, f: impl FnOnce(&mut ExperimentConfig)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Attach an observer to the run's event stream.
    pub fn observer(mut self, observer: Box<dyn RunObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Inject a custom period controller (one instance per worker rank),
    /// bypassing the registry.  Requires a parameter-averaging strategy.
    pub fn period_controller(
        mut self,
        factory: impl Fn() -> Box<dyn PeriodController> + Send + Sync + 'static,
    ) -> Self {
        self.controller = Some(Arc::new(factory));
        self
    }

    /// Validate everything and produce a runnable [`Experiment`].
    pub fn build(self) -> Result<Experiment> {
        let ExperimentBuilder { mut cfg, strategy, overrides, observers, controller } = self;
        if let Some(spec) = &strategy {
            spec.validate()?;
            spec.apply_to(&mut cfg.sync);
        }
        if !overrides.is_empty() {
            let mut doc = TomlDoc::default();
            for (k, v) in &overrides {
                doc.entries.insert(k.clone(), ExperimentConfig::parse_override_value(v));
            }
            cfg.apply_doc(&doc)?;
            ExperimentConfig::check_override_keys(&[cfg.sync.strategy], &overrides)?;
        }
        if controller.is_some() && cfg.sync.spec().is_gradient_mode() {
            bail!(
                "a custom period controller requires a parameter-averaging strategy \
                 (got {}, which exchanges gradients every iteration)",
                cfg.sync.spec().name()
            );
        }
        cfg.validate()?;
        Ok(Experiment { cfg, observers, controller })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LrSchedule;
    use crate::period::Strategy;
    use std::sync::Mutex;

    fn quick_builder() -> ExperimentBuilder {
        Experiment::builder()
            .name("exp_test")
            .nodes(2)
            .iters(60)
            .batch_per_node(8)
            .eval_every(30)
            .configure(|c| {
                c.workload.input_dim = 24;
                c.workload.hidden = 12;
                c.workload.eval_batches = 2;
                c.optim.schedule = LrSchedule::Const;
            })
    }

    #[test]
    fn builder_rejects_mismatched_strategy_knob() {
        let err = quick_builder()
            .strategy(StrategySpec::default_of(Strategy::Adaptive))
            .set("sync.qsgd_levels", "15")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("qsgd knob"), "{err}");
        assert!(err.contains("sync.adaptive"), "{err}");
    }

    #[test]
    fn builder_rejects_invalid_spec() {
        let err = quick_builder()
            .strategy(StrategySpec::Easgd { period: 8, alpha: 1.7 })
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("alpha"), "{err}");
    }

    #[test]
    fn builder_rejects_controller_on_gradient_mode() {
        let err = quick_builder()
            .strategy(StrategySpec::Full)
            .period_controller(|| Box::new(crate::period::Constant::new(3)))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("parameter-averaging"), "{err}");
    }

    #[test]
    fn custom_controller_drives_sync_schedule() {
        let report = quick_builder()
            .strategy(StrategySpec::Constant { period: 5 })
            .period_controller(|| Box::new(crate::period::Constant::new(3)))
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.syncs, 20, "injected p=3 over 60 iters");
    }

    #[test]
    fn observer_sees_typed_event_stream() {
        #[derive(Default)]
        struct Counts {
            iters: usize,
            syncs: usize,
            evals: usize,
            started: bool,
            ended: bool,
        }
        struct Counting(Arc<Mutex<Counts>>);
        impl RunObserver for Counting {
            fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
                let mut c = self.0.lock().unwrap();
                match ev {
                    RunEvent::RunStart { .. } => c.started = true,
                    RunEvent::IterEnd { .. } => c.iters += 1,
                    RunEvent::SyncDone { .. } => c.syncs += 1,
                    RunEvent::EvalDone { .. } => c.evals += 1,
                    RunEvent::RunEnd { .. } => c.ended = true,
                    _ => {}
                }
                Ok(())
            }
        }
        let counts = Arc::new(Mutex::new(Counts::default()));
        let report = quick_builder()
            .strategy(StrategySpec::Constant { period: 4 })
            .observer(Box::new(Counting(Arc::clone(&counts))))
            .build()
            .unwrap()
            .run()
            .unwrap();
        let c = counts.lock().unwrap();
        assert!(c.started && c.ended);
        assert_eq!(c.iters, 60);
        assert_eq!(c.syncs as u64, report.syncs);
        assert_eq!(c.evals, 2, "eval_every=30 over 60 iters");
    }

    #[test]
    fn failing_observer_aborts_run_cleanly() {
        struct Bomb;
        impl RunObserver for Bomb {
            fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
                if let RunEvent::IterEnd { k: 10, .. } = ev {
                    anyhow::bail!("observer bomb");
                }
                Ok(())
            }
        }
        let err = quick_builder()
            .strategy(StrategySpec::Constant { period: 4 })
            .observer(Box::new(Bomb))
            .build()
            .unwrap()
            .run()
            .unwrap_err();
        assert!(format!("{err:#}").contains("observer bomb"));
    }

    #[test]
    fn builder_and_from_config_agree() {
        let exp = quick_builder().strategy(StrategySpec::Constant { period: 4 }).build().unwrap();
        let cfg = exp.config().clone();
        let a = exp.run().unwrap();
        let b = Experiment::from_config(cfg).unwrap().run().unwrap();
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(a.syncs, b.syncs);
    }
}
