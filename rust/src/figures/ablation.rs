//! §IV-B robustness ablations — the paper's sensitivity claims:
//!
//! * "we achieve almost the same final test accuracy with p_init from 2
//!   to 5 and K_s from 500 to 1500. When p_init is set to 8, the best
//!   accuracy of ADPSGD decreases 0.5% ~ 1.0%."
//! * the 0.7/1.3 thresholds "need values slightly smaller/greater than
//!   1" — we sweep the band width as a design-choice ablation
//!   (DESIGN.md §4 calls this out).
//! * EASGD (related work [57]) vs ADPSGD at matched period — does the
//!   elastic pull change the convergence/communication trade-off?

use super::{Scale, Sink};
use crate::config::{ExperimentConfig, StrategySpec};
use crate::experiment::Campaign;
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub best_acc: f64,
    pub final_loss: f64,
    pub syncs: u64,
    pub avg_period: f64,
}

pub struct Ablation {
    pub p_init: Vec<AblationRow>,
    pub k_s: Vec<AblationRow>,
    pub band: Vec<AblationRow>,
    pub easgd: Vec<AblationRow>,
}

fn row(label: String, r: &crate::coordinator::RunReport) -> AblationRow {
    AblationRow {
        label,
        best_acc: r.best_eval_acc,
        final_loss: r.final_train_loss,
        syncs: r.syncs,
        avg_period: r.avg_period,
    }
}

fn print_rows(sink: &Sink, title: &str, rows: &[AblationRow]) {
    let mut t = Table::new(&["config", "best acc", "final loss", "syncs", "p̄"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.4}", r.best_acc),
            format!("{:.4}", r.final_loss),
            r.syncs.to_string(),
            format!("{:.2}", r.avg_period),
        ]);
    }
    sink.print(title);
    sink.print(&t.render());
}

/// Build an Adaptive spec from `base` with one knob mutated.
fn adaptive_with(
    base: &ExperimentConfig,
    f: impl FnOnce(&mut usize, &mut f64, &mut f64, &mut f64),
) -> StrategySpec {
    let mut spec = base.sync.spec_of(Strategy::Adaptive);
    if let StrategySpec::Adaptive { p_init, ks_frac, low, high, .. } = &mut spec {
        f(p_init, ks_frac, low, high);
    }
    spec
}

/// Run the full ablation suite on one base config: four campaign
/// definitions (three Adaptive-knob sweeps expressed as strategy axes,
/// plus the EASGD α sweep), executed as one union.
pub fn ablation(base: &ExperimentConfig, scale: Scale, sink: &Sink) -> Result<Ablation> {
    let p_inits: Vec<usize> = match scale {
        Scale::Quick => vec![2, 4, 8],
        Scale::Paper => vec![2, 3, 4, 5, 8],
    };
    let ks_fracs: Vec<f64> = match scale {
        Scale::Quick => vec![0.125, 0.25, 0.375],
        Scale::Paper => vec![0.125, 0.1875, 0.25, 0.3125, 0.375],
    };
    let bands: Vec<(f64, f64)> = match scale {
        Scale::Quick => vec![(0.9, 1.1), (0.7, 1.3), (0.4, 1.6)],
        Scale::Paper => vec![(0.95, 1.05), (0.9, 1.1), (0.7, 1.3), (0.5, 1.5), (0.4, 1.6)],
    };
    let alphas = [0.25, 0.5, 0.9];

    // ---- p_init sweep (paper: 2..5 equivalent, 8 degrades) ------------
    let p_init_camp = Campaign::builder("abl_pinit", base.clone())
        .strategies(p_inits.iter().map(|&p| {
            (format!("abl_pinit{p}"), adaptive_with(base, |pi, _, _, _| *pi = p))
        }))
        .build()?;

    // ---- K_s sweep (paper: 500..1500 of 4000 equivalent) --------------
    let ks_camp = Campaign::builder("abl_ks", base.clone())
        .strategies(ks_fracs.iter().map(|&f| {
            (format!("abl_ks{f}"), adaptive_with(base, |_, ks, _, _| *ks = f))
        }))
        .build()?;

    // ---- threshold-band sweep ------------------------------------------
    let band_camp = Campaign::builder("abl_band", base.clone())
        .strategies(bands.iter().map(|&(lo, hi)| {
            (
                format!("abl_band{lo}_{hi}"),
                adaptive_with(base, |_, _, l, h| {
                    *l = lo;
                    *h = hi;
                }),
            )
        }))
        .build()?;

    // ---- EASGD comparison (+ the ADPSGD reference row) -----------------
    let easgd_camp = Campaign::builder("abl_easgd", base.clone())
        .strategies(alphas.iter().map(|&alpha| {
            (format!("abl_easgd{alpha}"), StrategySpec::Easgd { period: 8, alpha })
        }))
        .strategy("abl_easgd_adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .build()?;

    let report = Campaign::union(
        "ablation",
        [p_init_camp, ks_camp, band_camp, easgd_camp],
    )?
    .run()?;

    let p_init: Vec<AblationRow> = p_inits
        .iter()
        .map(|&p| row(format!("p_init={p}"), report.get(&format!("abl_pinit{p}"))))
        .collect();
    print_rows(sink, "Ablation — ADPSGD p_init sensitivity (§IV-B)", &p_init);

    let k_s: Vec<AblationRow> = ks_fracs
        .iter()
        .map(|&f| {
            row(format!("K_s={:.0}", f * base.iters as f64), report.get(&format!("abl_ks{f}")))
        })
        .collect();
    print_rows(sink, "Ablation — ADPSGD K_s sensitivity (§IV-B)", &k_s);

    let band: Vec<AblationRow> = bands
        .iter()
        .map(|&(lo, hi)| {
            row(format!("[{lo},{hi}]"), report.get(&format!("abl_band{lo}_{hi}")))
        })
        .collect();
    print_rows(sink, "Ablation — Algorithm 2 threshold band (design choice)", &band);

    let mut easgd: Vec<AblationRow> = alphas
        .iter()
        .map(|&a| row(format!("EASGD α={a}"), report.get(&format!("abl_easgd{a}"))))
        .collect();
    easgd.push(row("ADPSGD".into(), report.get("abl_easgd_adpsgd")));
    print_rows(sink, "Ablation — EASGD (related work [57]) vs ADPSGD", &easgd);

    Ok(Ablation { p_init, k_s, band, easgd })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, googlenet_role};

    #[test]
    fn ablation_reproduces_robustness_claims() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        base.iters = 280;
        base.eval_every = 40;
        if let crate::config::LrSchedule::StepDecay { boundaries, .. } = &mut base.optim.schedule {
            *boundaries = vec![140, 210];
        }
        let a = ablation(&base, scale, &Sink::new(None, true)).unwrap();

        // p_init 2..4 nearly equivalent (paper: "almost the same")
        let accs: Vec<f64> = a.p_init.iter().map(|r| r.best_acc).collect();
        let small_spread = (accs[0] - accs[1]).abs();
        assert!(small_spread < 0.08, "p_init 2 vs 4 spread {small_spread}");

        // K_s choices all converge (robustness claim)
        for r in &a.k_s {
            assert!(r.best_acc > 0.5, "{}: {}", r.label, r.best_acc);
        }

        // wider bands adapt less aggressively (same or more syncs is not
        // required — but every band must converge)
        for r in &a.band {
            assert!(r.final_loss.is_finite(), "{}", r.label);
        }

        // EASGD variants converge; ADPSGD row exists
        assert_eq!(a.easgd.len(), 4);
        for r in &a.easgd {
            assert!(r.best_acc > 0.4, "{}: {}", r.label, r.best_acc);
        }
    }
}
