//! §IV-B robustness ablations — the paper's sensitivity claims:
//!
//! * "we achieve almost the same final test accuracy with p_init from 2
//!   to 5 and K_s from 500 to 1500. When p_init is set to 8, the best
//!   accuracy of ADPSGD decreases 0.5% ~ 1.0%."
//! * the 0.7/1.3 thresholds "need values slightly smaller/greater than
//!   1" — we sweep the band width as a design-choice ablation
//!   (DESIGN.md §4 calls this out).
//! * EASGD (related work [57]) vs ADPSGD at matched period — does the
//!   elastic pull change the convergence/communication trade-off?

use super::{run_strategy, Scale, Sink};
use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub best_acc: f64,
    pub final_loss: f64,
    pub syncs: u64,
    pub avg_period: f64,
}

pub struct Ablation {
    pub p_init: Vec<AblationRow>,
    pub k_s: Vec<AblationRow>,
    pub band: Vec<AblationRow>,
    pub easgd: Vec<AblationRow>,
}

fn row(label: String, r: &crate::coordinator::RunReport) -> AblationRow {
    AblationRow {
        label,
        best_acc: r.best_eval_acc,
        final_loss: r.final_train_loss,
        syncs: r.syncs,
        avg_period: r.avg_period,
    }
}

fn print_rows(sink: &Sink, title: &str, rows: &[AblationRow]) {
    let mut t = Table::new(&["config", "best acc", "final loss", "syncs", "p̄"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.4}", r.best_acc),
            format!("{:.4}", r.final_loss),
            r.syncs.to_string(),
            format!("{:.2}", r.avg_period),
        ]);
    }
    sink.print(title);
    sink.print(&t.render());
}

/// Run the full ablation suite on one base config.
pub fn ablation(base: &ExperimentConfig, scale: Scale, sink: &Sink) -> Result<Ablation> {
    // ---- p_init sweep (paper: 2..5 equivalent, 8 degrades) ------------
    let p_inits: Vec<usize> = match scale {
        Scale::Quick => vec![2, 4, 8],
        Scale::Paper => vec![2, 3, 4, 5, 8],
    };
    let mut p_init = Vec::new();
    for p in p_inits {
        let mut cfg = base.clone();
        cfg.sync.p_init = p;
        let r = run_strategy(&cfg, Strategy::Adaptive, &format!("abl_pinit{p}"))?;
        p_init.push(row(format!("p_init={p}"), &r));
    }
    print_rows(sink, "Ablation — ADPSGD p_init sensitivity (§IV-B)", &p_init);

    // ---- K_s sweep (paper: 500..1500 of 4000 equivalent) --------------
    let ks_fracs: Vec<f64> = match scale {
        Scale::Quick => vec![0.125, 0.25, 0.375],
        Scale::Paper => vec![0.125, 0.1875, 0.25, 0.3125, 0.375],
    };
    let mut k_s = Vec::new();
    for f in ks_fracs {
        let mut cfg = base.clone();
        cfg.sync.ks_frac = f;
        let r = run_strategy(&cfg, Strategy::Adaptive, &format!("abl_ks{f}"))?;
        k_s.push(row(format!("K_s={:.0}", f * base.iters as f64), &r));
    }
    print_rows(sink, "Ablation — ADPSGD K_s sensitivity (§IV-B)", &k_s);

    // ---- threshold-band sweep ------------------------------------------
    let bands: Vec<(f64, f64)> = match scale {
        Scale::Quick => vec![(0.9, 1.1), (0.7, 1.3), (0.4, 1.6)],
        Scale::Paper => vec![(0.95, 1.05), (0.9, 1.1), (0.7, 1.3), (0.5, 1.5), (0.4, 1.6)],
    };
    let mut band = Vec::new();
    for (lo, hi) in bands {
        let mut cfg = base.clone();
        cfg.sync.low = lo;
        cfg.sync.high = hi;
        let r = run_strategy(&cfg, Strategy::Adaptive, &format!("abl_band{lo}_{hi}"))?;
        band.push(row(format!("[{lo},{hi}]"), &r));
    }
    print_rows(sink, "Ablation — Algorithm 2 threshold band (design choice)", &band);

    // ---- EASGD comparison ----------------------------------------------
    let mut easgd = Vec::new();
    for alpha in [0.25, 0.5, 0.9] {
        let mut cfg = base.clone();
        cfg.sync.period = 8;
        cfg.sync.easgd_alpha = alpha;
        cfg.sync.warmup_iters = 0;
        let r = run_strategy(&cfg, Strategy::Easgd, &format!("abl_easgd{alpha}"))?;
        easgd.push(row(format!("EASGD α={alpha}"), &r));
    }
    {
        let r = run_strategy(base, Strategy::Adaptive, "abl_easgd_adpsgd")?;
        easgd.push(row("ADPSGD".into(), &r));
    }
    print_rows(sink, "Ablation — EASGD (related work [57]) vs ADPSGD", &easgd);

    Ok(Ablation { p_init, k_s, band, easgd })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, googlenet_role};

    #[test]
    fn ablation_reproduces_robustness_claims() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        base.iters = 280;
        base.eval_every = 40;
        if let crate::config::LrSchedule::StepDecay { boundaries, .. } = &mut base.optim.schedule {
            *boundaries = vec![140, 210];
        }
        let a = ablation(&base, scale, &Sink::new(None, true)).unwrap();

        // p_init 2..4 nearly equivalent (paper: "almost the same")
        let accs: Vec<f64> = a.p_init.iter().map(|r| r.best_acc).collect();
        let small_spread = (accs[0] - accs[1]).abs();
        assert!(small_spread < 0.08, "p_init 2 vs 4 spread {small_spread}");

        // K_s choices all converge (robustness claim)
        for r in &a.k_s {
            assert!(r.best_acc > 0.5, "{}: {}", r.label, r.best_acc);
        }

        // wider bands adapt less aggressively (same or more syncs is not
        // required — but every band must converge)
        for r in &a.band {
            assert!(r.final_loss.is_finite(), "{}", r.label);
        }

        // EASGD variants converge; ADPSGD row exists
        assert_eq!(a.easgd.len(), 4);
        for r in &a.easgd {
            assert!(r.best_acc > 0.4, "{}: {}", r.label, r.best_acc);
        }
    }
}
