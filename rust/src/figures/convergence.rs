//! Figures 4, 5, 7, 8: convergence + execution-time comparisons of
//! FULLSGD / CPSGD(p=8) / ADPSGD / QSGD.
//!
//! * Fig 4a/b, 5a/b — training loss + test accuracy on the CIFAR-geometry
//!   workloads (GoogLeNet role = compute-heavy, VGG role = comm-heavy).
//! * Fig 4c, 5c — computation/communication split at 100Gbps and 10Gbps.
//! * Fig 7, 8 — the ImageNet-geometry runs (gradual-warmup LR schedule,
//!   periodic averaging engaged only after warmup).

use super::{cifar_base, googlenet_role, run_quartet, vgg_role, Scale, Sink};
use crate::config::{ExperimentConfig, LrSchedule, NetConfig};
use crate::coordinator::RunReport;
use crate::metrics::Table;
use crate::netsim::NetModel;
use anyhow::Result;

/// Which model "role" a convergence figure exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Fig 4 (GoogLeNet): compute-heavy.
    GoogLeNet,
    /// Fig 5 (VGG16): parameter/communication-heavy.
    Vgg16,
    /// Fig 7 (ResNet50/ImageNet geometry): warmup LR schedule.
    ResNet50,
    /// Fig 8 (AlexNet/ImageNet geometry): warmup LR, comm-heavier.
    AlexNet,
}

impl Role {
    pub fn figure(self) -> &'static str {
        match self {
            Role::GoogLeNet => "Fig 4",
            Role::Vgg16 => "Fig 5",
            Role::ResNet50 => "Fig 7",
            Role::AlexNet => "Fig 8",
        }
    }

    pub fn is_imagenet(self) -> bool {
        matches!(self, Role::ResNet50 | Role::AlexNet)
    }
}

/// Build the experiment config for a role at a scale.
pub fn role_config(role: Role, scale: Scale) -> ExperimentConfig {
    let mut cfg = cifar_base(scale);
    match role {
        Role::GoogLeNet => googlenet_role(&mut cfg, scale),
        Role::Vgg16 => vgg_role(&mut cfg, scale),
        Role::ResNet50 | Role::AlexNet => {
            // ImageNet geometry: more classes, warmup+step LR (§IV-C),
            // periodic averaging only after warmup (warmup syncs as FULL
            // ≈ our p=1 warmup window covering the LR ramp).
            let k = cfg.iters;
            if role == Role::ResNet50 {
                googlenet_role(&mut cfg, scale);
            } else {
                vgg_role(&mut cfg, scale);
            }
            cfg.workload.classes = match scale {
                Scale::Quick => 20,
                Scale::Paper => 100,
            };
            let warmup = k * 8 / 90; // paper: 8 of 90 epochs
            cfg.optim.schedule = LrSchedule::Warmup {
                warmup_iters: warmup,
                warmup_factor: 8.0,
                boundaries: vec![k / 3, 2 * k / 3],
                factor: 0.1,
            };
            cfg.sync.warmup_iters = warmup;
            cfg.sync.ks_frac = 0.2; // paper: K_s = 0.2K on ImageNet
        }
    }
    cfg
}

/// Result of one convergence figure: the four strategy runs, in the
/// paper's order (FULLSGD, CPSGD, ADPSGD, QSGD).
pub struct Convergence {
    pub role: Role,
    pub runs: Vec<RunReport>,
    pub iters: usize,
    /// the base config the quartet ran under (time_split calibrates
    /// per-step compute from it)
    pub cfg: ExperimentConfig,
}

impl Convergence {
    pub fn get(&self, name: &str) -> &RunReport {
        self.runs
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("run {name} missing"))
    }

    pub fn fullsgd(&self) -> &RunReport {
        self.get("fullsgd")
    }
    pub fn cpsgd(&self) -> &RunReport {
        self.get("cpsgd")
    }
    pub fn adpsgd(&self) -> &RunReport {
        self.get("adpsgd")
    }
    pub fn qsgd(&self) -> &RunReport {
        self.get("qsgd")
    }
}

/// Run one convergence figure (4/5/7/8 a+b panels).
pub fn convergence(role: Role, scale: Scale, sink: &Sink) -> Result<Convergence> {
    let cfg = role_config(role, scale);
    let runs = run_quartet(&cfg)?;
    let tag = role.figure().replace(' ', "").to_lowercase();
    for r in &runs {
        sink.write(&format!("{tag}_{}", r.name), &r.recorder)?;
    }

    let mut t =
        Table::new(&["version", "final loss", "min loss", "best acc", "syncs", "p̄", "wire GB"]);
    for r in &runs {
        t.row(&[
            r.strategy.to_string(),
            format!("{:.4}", r.final_train_loss),
            format!("{:.4}", r.min_train_loss),
            format!("{:.4}", r.best_eval_acc),
            r.syncs.to_string(),
            format!("{:.2}", r.avg_period),
            format!("{:.3}", r.ledger.total_wire_bytes() as f64 / 1e9),
        ]);
    }
    sink.print(&format!(
        "{}a/b — {:?}-role convergence ({} nodes, K={})",
        role.figure(),
        role,
        cfg.nodes,
        cfg.iters
    ));
    sink.print(&t.render());

    Ok(Convergence { role, runs, iters: cfg.iters, cfg })
}

/// One row of the time-split panel (Fig 4c/5c/7c/8c).
pub struct TimeSplit {
    pub version: String,
    pub compute_secs: f64,
    pub comm_100g: f64,
    pub comm_10g: f64,
}

/// Fig 4c/5c/7c/8c: computation/communication split under both
/// bandwidth presets, re-priced from the run ledgers.
///
/// Per-node compute is *calibrated* (single-node, contention-free run —
/// on the paper's testbed every node computes on its own GPU in
/// parallel) rather than read from the 16-threads-on-shared-cores
/// training runs, whose per-thread timers include preemption.  The
/// paper's Fig 4c shows near-identical computation bars across versions;
/// ADPSGD's S_k overhead is <1% (§IV-B) and is charged as such.
pub fn time_split(conv: &Convergence, sink: &Sink) -> Vec<TimeSplit> {
    let fast = NetModel::new(&NetConfig::infiniband_100g());
    let slow = NetModel::new(&NetConfig::ethernet_10g());
    let per_step = crate::figures::speedup::calibrate_step_secs(&conv.cfg, 50)
        .expect("calibration run failed");
    let rows: Vec<TimeSplit> = conv
        .runs
        .iter()
        .map(|r| {
            // §IV-B: "it cost less than 1% of the original computation"
            let overhead = match r.name.as_str() {
                "adpsgd" => 1.01,
                _ => 1.0,
            };
            TimeSplit {
                version: r.strategy.to_string(),
                compute_secs: per_step * conv.iters as f64 * overhead,
                comm_100g: r.ledger.modeled_secs(&fast),
                comm_10g: r.ledger.modeled_secs(&slow),
            }
        })
        .collect();

    let full = &rows[0];
    let mut t = Table::new(&[
        "version",
        "compute",
        "comm@100G",
        "comm@10G",
        "total@100G",
        "total@10G",
        "speedup@100G",
        "speedup@10G",
    ]);
    for r in &rows {
        let t100 = r.compute_secs + r.comm_100g;
        let t10 = r.compute_secs + r.comm_10g;
        let f100 = full.compute_secs + full.comm_100g;
        let f10 = full.compute_secs + full.comm_10g;
        t.row(&[
            r.version.clone(),
            crate::util::fmt::secs(r.compute_secs),
            crate::util::fmt::secs(r.comm_100g),
            crate::util::fmt::secs(r.comm_10g),
            crate::util::fmt::secs(t100),
            crate::util::fmt::secs(t10),
            format!("{:.2}x", f100 / t100),
            format!("{:.2}x", f10 / t10),
        ]);
    }
    sink.print(&format!("{}c — computation/communication split", conv.role.figure()));
    sink.print(&t.render());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> Sink {
        Sink::new(None, true)
    }

    #[test]
    fn fig4_convergence_ordering() {
        let c = convergence(Role::GoogLeNet, Scale::Quick, &quiet()).unwrap();
        assert_eq!(c.runs.len(), 4);
        // every version actually trains
        for r in &c.runs {
            assert!(r.final_train_loss.is_finite());
            assert!(r.best_eval_acc > 0.3, "{}: acc {}", r.name, r.best_eval_acc);
        }
        // ADPSGD communicates less than FULLSGD by ~p̄
        assert!(c.adpsgd().syncs < c.fullsgd().syncs / 2);
        // paper: ADPSGD wire bytes ≈ 1/2 of QSGD, 1/8 of FULLSGD
        let aw = c.adpsgd().ledger.total_wire_bytes() as f64;
        let fw = c.fullsgd().ledger.total_wire_bytes() as f64;
        assert!(aw < fw / 3.0, "adpsgd wire {aw} vs full {fw}");
    }

    #[test]
    fn fig4c_time_split_shapes() {
        let c = convergence(Role::GoogLeNet, Scale::Quick, &quiet()).unwrap();
        let rows = time_split(&c, &quiet());
        let full = &rows[0];
        let adp = &rows[2];
        // ADPSGD strictly reduces modeled comm vs FULLSGD at both bands
        assert!(adp.comm_100g < full.comm_100g);
        assert!(adp.comm_10g < full.comm_10g);
        // comm grows when bandwidth shrinks
        for r in &rows {
            assert!(r.comm_10g > r.comm_100g);
        }
    }

    #[test]
    fn fig7_imagenet_geometry_runs() {
        let c = convergence(Role::ResNet50, Scale::Quick, &quiet()).unwrap();
        for r in &c.runs {
            assert!(r.final_train_loss.is_finite(), "{} diverged", r.name);
        }
        // warmup makes the first segment fully synchronous for ADPSGD:
        // effective average period must stay modest but > 1
        assert!(c.adpsgd().avg_period > 1.0);
    }
}
