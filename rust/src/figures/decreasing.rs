//! §V-B: the decreasing-period strawman (Wang & Joshi-style schedule —
//! large period first, small period later) at the *same communication
//! budget* as CPSGD p=8.
//!
//! Paper: 20-then-5 over 160 epochs (switch at half) gives 500 syncs,
//! identical to CPSGD p=8's 500 — yet its final training loss is an
//! order of magnitude worse and its accuracy lower.  This validates the
//! paper's core claim that early synchronization matters most.

use super::Sink;
use crate::config::{ExperimentConfig, StrategySpec};
use crate::coordinator::RunReport;
use crate::experiment::Campaign;
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::Result;

pub struct DecreasingStudy {
    pub decreasing: RunReport,
    /// the matched-budget "increasing" schedule (small first): the
    /// paper's strategy-1, realized via ADPSGD
    pub adpsgd: RunReport,
    pub cpsgd8: RunReport,
}

/// Run the §V-B comparison on one base config — a three-strategy
/// campaign (the 20-then-5 strawman, CPSGD p=8, ADPSGD).
pub fn decreasing_study(base: &ExperimentConfig, sink: &Sink) -> Result<DecreasingStudy> {
    let mut report = Campaign::builder("sec5b", base.clone())
        .strategy("decreasing", StrategySpec::Decreasing { first: 20, second: 5 })
        .strategy("cpsgd8", StrategySpec::Constant { period: 8 })
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .build()?
        .run()?;
    let decreasing = report.take("decreasing");
    let cpsgd8 = report.take("cpsgd8");
    let adpsgd = report.take("adpsgd");

    for r in [&decreasing, &cpsgd8, &adpsgd] {
        sink.write(&format!("sec5b_{}", r.name), &r.recorder)?;
    }

    let mut t = Table::new(&["schedule", "final loss", "min loss", "best acc", "syncs"]);
    for r in [&adpsgd, &cpsgd8, &decreasing] {
        t.row(&[
            r.name.clone(),
            format!("{:.4}", r.final_train_loss),
            format!("{:.4}", r.min_train_loss),
            format!("{:.4}", r.best_eval_acc),
            r.syncs.to_string(),
        ]);
    }
    sink.print("§V-B — decreasing-period strawman at matched communication budget");
    sink.print(&t.render());

    Ok(DecreasingStudy { decreasing, adpsgd, cpsgd8 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, googlenet_role, Scale};

    #[test]
    fn decreasing_schedule_is_worse_at_same_budget() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        let s = decreasing_study(&base, &Sink::new(None, true)).unwrap();

        // budget parity: 20-then-5 over K with switch at K/2 gives the
        // same sync count as p=8 (paper: 500 = 500)
        let d = s.decreasing.syncs as f64;
        let c = s.cpsgd8.syncs as f64;
        assert!((d - c).abs() / c < 0.05, "budgets diverged: {d} vs {c}");

        // the paper's claim: decreasing-period converges worse than the
        // constant-period baseline, which in turn is no better than ADPSGD
        assert!(
            s.decreasing.final_train_loss > s.adpsgd.final_train_loss,
            "decreasing {} should be worse than adpsgd {}",
            s.decreasing.final_train_loss,
            s.adpsgd.final_train_loss
        );
    }
}
