//! Figure/table regenerators — one function per table and figure in the
//! paper's evaluation (DESIGN.md §4 maps each to its experiment id).
//!
//! Every function here is pure library code shared by three callers:
//! the `examples/` binaries (full-scale regeneration), the `benches/`
//! harnesses (timed quick-scale runs), and the integration tests (shape
//! assertions on quick-scale outputs).  Each returns a structured result
//! *and* can render the rows/series the paper reports.
//!
//! Every multi-run sweep in this tree is a declarative
//! [`crate::experiment::Campaign`] definition — the figure modules
//! describe their run families (strategy axes, period sweeps, lr
//! sweeps) and post-process the ordered
//! [`crate::experiment::CampaignReport`] rows; none
//! of them hand-rolls a train-loop-per-sweep-point anymore.

pub mod ablation;
pub mod convergence;
pub mod decreasing;
pub mod robustness;
pub mod speedup;
pub mod table1;
pub mod variance;

use crate::config::{ExperimentConfig, StrategySpec};
use crate::coordinator::RunReport;
use crate::experiment::{Campaign, Experiment};
use crate::period::Strategy;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// How large to run an experiment family.
///
/// `Paper` mirrors the paper's geometry (16 nodes, K=4000, B=128/node —
/// minutes of CPU); `Quick` shrinks every axis so the same code path
/// finishes in seconds (tests, benches, smoke runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Paper,
}

impl Scale {
    pub fn from_flag(quick: bool) -> Scale {
        if quick {
            Scale::Quick
        } else {
            Scale::Paper
        }
    }

    /// Total iterations K for the CIFAR-geometry experiments.
    pub fn iters(self) -> usize {
        match self {
            Scale::Quick => 400,
            Scale::Paper => 4000,
        }
    }

    pub fn nodes(self) -> usize {
        match self {
            Scale::Quick => 8,
            Scale::Paper => 16,
        }
    }

    /// Per-node batch. The paper uses 128 (M = 2048); this testbed has a
    /// single core, so Paper scale keeps the full K/nodes/schedule
    /// geometry but runs M = 512 (the V_t statistics and period dynamics
    /// depend on the noise scale γ/M, which stays in regime — DESIGN.md
    /// §1 records the substitution).
    pub fn batch_per_node(self) -> usize {
        match self {
            Scale::Quick => 16,
            Scale::Paper => 32,
        }
    }
}

/// Output sink for a figure run: where CSVs go (if anywhere) and whether
/// tables print to stdout.
#[derive(Debug, Clone, Default)]
pub struct Sink {
    pub out_dir: Option<PathBuf>,
    pub quiet: bool,
}

impl Sink {
    pub fn new(out_dir: Option<&str>, quiet: bool) -> Self {
        Sink { out_dir: out_dir.map(PathBuf::from), quiet }
    }

    pub fn print(&self, text: &str) {
        if !self.quiet {
            println!("{text}");
        }
    }

    pub fn dir(&self) -> Option<&Path> {
        self.out_dir.as_deref()
    }

    /// Write a recorder's series under `prefix` if an out dir is set.
    pub fn write(&self, prefix: &str, rec: &crate::metrics::Recorder) -> Result<()> {
        if let Some(dir) = self.dir() {
            rec.write_csvs(dir, prefix)
                .with_context(|| format!("writing CSVs for {prefix}"))?;
        }
        Ok(())
    }
}

/// Baseline config with the paper's CIFAR geometry at the given scale:
/// step-decay LR 0.1 → 0.01 → 0.001 at 50%/75% of K (paper: epochs
/// 80/120 of 160 ⇒ iterations 2000/3000 of 4000), momentum 0.9,
/// 16 nodes × 128 batch.
pub fn cifar_base(scale: Scale) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    let k = scale.iters();
    cfg.nodes = scale.nodes();
    cfg.iters = k;
    cfg.batch_per_node = scale.batch_per_node();
    cfg.eval_every = k / 20;
    cfg.optim.lr0 = 0.1;
    cfg.optim.momentum = 0.9;
    cfg.optim.schedule =
        crate::config::LrSchedule::StepDecay { boundaries: vec![k / 2, 3 * k / 4], factor: 0.1 };
    cfg.sync.warmup_iters = k / 160; // "averaging period of 1 for the first epoch"
    cfg.sync.p_init = 4;
    cfg.sync.ks_frac = 0.25;
    cfg
}

/// The "GoogLeNet role": compute-heavy relative to its parameter count.
pub fn googlenet_role(cfg: &mut ExperimentConfig, scale: Scale) {
    cfg.workload.backend = crate::config::Backend::Native("mlp_deep".into());
    match scale {
        Scale::Quick => {
            cfg.workload.input_dim = 64;
            cfg.workload.hidden = 48;
        }
        Scale::Paper => {
            cfg.workload.input_dim = 96;
            cfg.workload.hidden = 64;
        }
    }
}

/// The "VGG16 role": parameter-heavy (communication-bound).
pub fn vgg_role(cfg: &mut ExperimentConfig, scale: Scale) {
    cfg.workload.backend = crate::config::Backend::Native("mlp_wide".into());
    match scale {
        Scale::Quick => {
            cfg.workload.input_dim = 64;
            cfg.workload.hidden = 64;
        }
        Scale::Paper => {
            cfg.workload.input_dim = 96;
            cfg.workload.hidden = 64; // widened 8x inside mlp_wide -> 512
        }
    }
}

/// Run one strategy variant of a base config (single run, through the
/// session API).
pub fn run_strategy(base: &ExperimentConfig, strategy: Strategy, name: &str) -> Result<RunReport> {
    let mut cfg = base.clone();
    cfg.sync.strategy = strategy;
    cfg.name = name.to_string();
    Experiment::from_config(cfg)?.run()
}

/// The paper's four comparison strategies (FULLSGD, CPSGD, ADPSGD,
/// QSGD) as a campaign over one base config, with the specs projected
/// from the base's knobs.
pub fn quartet_campaign(base: &ExperimentConfig) -> Result<Campaign> {
    let s = &base.sync;
    Campaign::builder("quartet", base.clone())
        .strategy("fullsgd", StrategySpec::Full)
        .strategy("cpsgd", s.spec_of(Strategy::Constant))
        .strategy("adpsgd", s.spec_of(Strategy::Adaptive))
        .strategy("qsgd", s.spec_of(Strategy::Qsgd))
        .build()
}

/// Run the quartet; reports in the paper's order (FULLSGD, CPSGD,
/// ADPSGD, QSGD).
pub fn run_quartet(base: &ExperimentConfig) -> Result<Vec<RunReport>> {
    Ok(quartet_campaign(base)?.run()?.reports())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_are_ordered() {
        assert!(Scale::Quick.iters() < Scale::Paper.iters());
        assert!(Scale::Quick.nodes() <= Scale::Paper.nodes());
    }

    #[test]
    fn cifar_base_validates() {
        cifar_base(Scale::Quick).validate().unwrap();
        cifar_base(Scale::Paper).validate().unwrap();
    }

    #[test]
    fn roles_differ_in_param_count() {
        let mut g = cifar_base(Scale::Quick);
        googlenet_role(&mut g, Scale::Quick);
        let mut v = cifar_base(Scale::Quick);
        vgg_role(&mut v, Scale::Quick);
        let gp = match &g.workload.backend {
            crate::config::Backend::Native(n) => {
                crate::workload::build(n, &g.workload).unwrap().n_params()
            }
            _ => unreachable!(),
        };
        let vp = match &v.workload.backend {
            crate::config::Backend::Native(n) => {
                crate::workload::build(n, &v.workload).unwrap().n_params()
            }
            _ => unreachable!(),
        };
        assert!(vp > gp, "vgg role must be parameter-heavier: {vp} vs {gp}");
    }

    #[test]
    fn sink_quiet_suppresses_nothing_structural() {
        let s = Sink::new(None, true);
        s.print("never shown");
        assert!(s.dir().is_none());
    }
}
