//! Robustness study: the five synchronization strategies under
//! heterogeneous clusters — ADPSGD and CPSGD (the paper's pair) against
//! the related-work zoo (AdaComm, PR-SGD, DaSGD) across a
//! skew × fault × network grid.
//!
//! The sweep is one declarative [`Campaign`] over three axes:
//!
//! * **strategy** — adpsgd / cpsgd / adacomm / prsgd / dasgd, each
//!   projected from the base config's knobs via `spec_of`;
//! * **network** — `ib100` (100 Gbps InfiniBand) vs `eth10`
//!   (10 Gbps Ethernet);
//! * **scenario** — `uniform` (homogeneous baseline), `skew`
//!   (4× straggler + 10% seeded per-step jitter), `faulty` (the same
//!   skew plus deterministic node pauses and packet-delay spikes).
//!
//! Heterogeneity moves **modeled clocks only** — for a given strategy
//! and seed, the `skew`/`faulty` runs produce bit-identical parameters
//! (and therefore identical losses, sync counts, and wire bytes) to the
//! `uniform` run; what changes is `modeled_wall_secs`. The per-cell
//! `slowdown` column quantifies how much of the injected heterogeneity
//! each strategy absorbs: infrequent averagers amortize stragglers over
//! their local-step windows, and DaSGD's delayed apply overlaps
//! communication with compute entirely.

use super::{Scale, Sink};
use crate::config::{ExperimentConfig, NetConfig};
use crate::experiment::{Campaign, CampaignReport};
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::{Context, Result};

/// Strategy axis, in presentation order.
pub const STRATEGIES: [&str; 5] = ["adpsgd", "cpsgd", "adacomm", "prsgd", "dasgd"];

/// Network axis labels.
pub const NETS: [&str; 2] = ["ib100", "eth10"];

/// Scenario axis labels (`uniform` is the reference for slowdowns).
pub const SCENARIOS: [&str; 3] = ["uniform", "skew", "faulty"];

/// Apply one scenario's cluster knobs to a config. `uniform` leaves the
/// default homogeneous model in place; the other two inject the same
/// 4× straggler so their wall clocks are directly comparable.
pub fn apply_scenario(cfg: &mut ExperimentConfig, scenario: &str) {
    match scenario {
        "uniform" => {}
        "skew" => {
            cfg.cluster.skew = "straggler:4.0".into();
            cfg.cluster.jitter = 0.1;
        }
        "faulty" => {
            cfg.cluster.skew = "straggler:4.0".into();
            cfg.cluster.jitter = 0.1;
            cfg.cluster.faults.pauses = 2;
            cfg.cluster.faults.pause_secs = 0.05;
            cfg.cluster.faults.spikes = 2;
            cfg.cluster.faults.spike_secs = 2e-3;
            cfg.cluster.faults.spike_len = 8;
        }
        other => panic!("unknown robustness scenario {other:?}"),
    }
}

/// One (strategy, net, scenario) cell of the robustness grid.
#[derive(Debug, Clone)]
pub struct RobustnessCell {
    pub strategy: Strategy,
    pub label: String,
    pub net: &'static str,
    pub scenario: &'static str,
    pub final_loss: f64,
    pub syncs: u64,
    pub wire_mb: f64,
    pub modeled_wall_secs: f64,
    /// modeled wall clock relative to the `uniform` scenario of the same
    /// (strategy, net) pair — 1.0 means the heterogeneity cost nothing
    pub slowdown: f64,
}

pub struct Robustness {
    pub cells: Vec<RobustnessCell>,
    pub report: CampaignReport,
}

impl Robustness {
    pub fn cell(&self, strategy: &str, net: &str, scenario: &str) -> &RobustnessCell {
        self.cells
            .iter()
            .find(|c| c.label == format!("{strategy}_{net}_{scenario}"))
            .unwrap_or_else(|| panic!("no robustness cell {strategy}_{net}_{scenario}"))
    }
}

/// The robustness campaign definition: 5 strategies × 2 networks ×
/// 3 scenarios = 30 runs, all from one base config.
pub fn campaign(base: &ExperimentConfig) -> Result<Campaign> {
    let s = &base.sync;
    let mut b = Campaign::builder("robustness", base.clone())
        .strategy("adpsgd", s.spec_of(Strategy::Adaptive))
        .strategy("cpsgd", s.spec_of(Strategy::Constant))
        .strategy("adacomm", s.spec_of(Strategy::AdaComm))
        .strategy("prsgd", s.spec_of(Strategy::PrSgd))
        .strategy("dasgd", s.spec_of(Strategy::DaSgd))
        .net("ib100", NetConfig::infiniband_100g())
        .net("eth10", NetConfig::ethernet_10g());
    for scenario in SCENARIOS {
        b = b.variant(scenario, move |cfg| apply_scenario(cfg, scenario));
    }
    b.build()
}

/// Run the robustness sweep, render the grid, and (when the sink has an
/// out dir) write the byte-stable campaign summary to
/// `robustness.campaign.json` — re-running against a warm cache, with a
/// different `--jobs`, or on another host reproduces it byte for byte.
pub fn robustness(base: &ExperimentConfig, _scale: Scale, sink: &Sink) -> Result<Robustness> {
    let report = campaign(base)?.run()?;

    let mut cells = Vec::new();
    for &strategy in &STRATEGIES {
        for &net in &NETS {
            let uniform_wall =
                report.get(&format!("{strategy}_{net}_uniform")).modeled_wall_secs;
            for &scenario in &SCENARIOS {
                let label = format!("{strategy}_{net}_{scenario}");
                let rep = report.get(&label);
                cells.push(RobustnessCell {
                    strategy: rep.strategy,
                    label,
                    net,
                    scenario,
                    final_loss: rep.final_train_loss,
                    syncs: rep.syncs,
                    wire_mb: rep.ledger.total_wire_bytes() as f64 / 1e6,
                    modeled_wall_secs: rep.modeled_wall_secs,
                    slowdown: rep.modeled_wall_secs / uniform_wall.max(1e-12),
                });
            }
        }
    }

    let mut t = Table::new(&[
        "strategy", "net", "scenario", "final loss", "syncs", "wire MB", "wall(model)",
        "slowdown",
    ]);
    for c in &cells {
        t.row(&[
            c.strategy.to_string(),
            c.net.to_string(),
            c.scenario.to_string(),
            format!("{:.4}", c.final_loss),
            c.syncs.to_string(),
            format!("{:.2}", c.wire_mb),
            crate::util::fmt::secs(c.modeled_wall_secs),
            format!("{:.2}x", c.slowdown),
        ]);
    }
    sink.print(&format!(
        "Robustness — {} strategies × {} nets × {} scenarios (K={}, n={})",
        STRATEGIES.len(),
        NETS.len(),
        SCENARIOS.len(),
        base.iters,
        base.nodes,
    ));
    sink.print(&t.render());

    if let Some(dir) = sink.dir() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join("robustness.campaign.json");
        std::fs::write(&path, report.to_json_stable().to_string_compact())
            .with_context(|| format!("writing {}", path.display()))?;
        sink.print(&format!("wrote {}", path.display()));
    }

    Ok(Robustness { cells, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::cifar_base;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = cifar_base(Scale::Quick);
        cfg.nodes = 4;
        cfg.iters = 120;
        cfg.batch_per_node = 8;
        cfg.eval_every = 60;
        cfg.workload.input_dim = 24;
        cfg.workload.hidden = 12;
        cfg.workload.eval_batches = 2;
        cfg.sync.warmup_iters = 4;
        cfg
    }

    #[test]
    fn campaign_covers_the_full_grid() {
        let c = campaign(&tiny_base()).unwrap();
        assert_eq!(c.len(), STRATEGIES.len() * NETS.len() * SCENARIOS.len());
    }

    #[test]
    fn heterogeneity_moves_clocks_never_parameters() {
        let r = robustness(&tiny_base(), Scale::Quick, &Sink::new(None, true)).unwrap();
        assert_eq!(r.cells.len(), 30);
        for &strategy in &STRATEGIES {
            for &net in &NETS {
                let uni = r.cell(strategy, net, "uniform");
                for scenario in ["skew", "faulty"] {
                    let het = r.cell(strategy, net, scenario);
                    // parameter math is untouched: identical trajectory
                    assert_eq!(
                        uni.final_loss.to_bits(),
                        het.final_loss.to_bits(),
                        "{strategy}/{net}/{scenario}: loss moved"
                    );
                    assert_eq!(uni.syncs, het.syncs, "{strategy}/{net}/{scenario}");
                    assert_eq!(
                        uni.wire_mb.to_bits(),
                        het.wire_mb.to_bits(),
                        "{strategy}/{net}/{scenario}: wire bytes moved"
                    );
                    // ...but the 4x straggler costs modeled time
                    assert!(
                        het.slowdown > 1.5,
                        "{strategy}/{net}/{scenario}: slowdown {} too small",
                        het.slowdown
                    );
                }
                // the fault schedule adds pauses on top of pure skew
                let skew = r.cell(strategy, net, "skew");
                let faulty = r.cell(strategy, net, "faulty");
                assert!(
                    faulty.modeled_wall_secs >= skew.modeled_wall_secs,
                    "{strategy}/{net}: faults must not speed the cluster up"
                );
            }
        }
        // DaSGD overlaps communication with compute: under the straggler
        // it must not be slower than the barriered constant-period run
        let das = r.cell("dasgd", "eth10", "skew");
        let cps = r.cell("cpsgd", "eth10", "skew");
        assert!(
            das.modeled_wall_secs <= cps.modeled_wall_secs,
            "dasgd {} vs cpsgd {}",
            das.modeled_wall_secs,
            cps.modeled_wall_secs
        );
    }

    #[test]
    fn stable_summary_is_reproducible() {
        let base = tiny_base();
        let a = robustness(&base, Scale::Quick, &Sink::new(None, true)).unwrap();
        let b = robustness(&base, Scale::Quick, &Sink::new(None, true)).unwrap();
        assert_eq!(
            a.report.to_json_stable().to_string_compact(),
            b.report.to_json_stable().to_string_compact()
        );
    }
}
