//! Figure 6: speedups of distributed FULLSGD / ADPSGD over single-node
//! vanilla SGD, for n ∈ {2, 4, 8, 16} at 100Gbps and 10Gbps.
//!
//! The paper's comparison fixes the *work* (same dataset, same number of
//! epochs, per-node batch fixed at 128), so n nodes run K/n iterations.
//! Our testbed substitution (DESIGN.md §1): per-step compute time is
//! *calibrated* from a real single-node run (each paper GPU computes in
//! parallel, so per-node compute is contention-free), while per-sync
//! communication time comes from the α–β model applied to each run's
//! actual ledger (ADPSGD's sync count is a training outcome, so we run
//! the real coordinator at every n to obtain it).

use super::{Scale, Sink};
use crate::config::{ExperimentConfig, NetConfig, StrategySpec};
use crate::experiment::{Campaign, Experiment};
use crate::metrics::Table;
use crate::netsim::NetModel;
use crate::period::Strategy;
use anyhow::Result;

/// One (strategy, nodes) cell of Fig 6.
#[derive(Debug, Clone)]
pub struct SpeedupCell {
    pub strategy: Strategy,
    pub nodes: usize,
    pub iters: usize,
    pub syncs: u64,
    /// modeled total seconds at each bandwidth
    pub total_100g: f64,
    pub total_10g: f64,
    pub speedup_100g: f64,
    pub speedup_10g: f64,
}

pub struct Fig6 {
    pub role_name: &'static str,
    pub per_step_secs: f64,
    pub single_node_secs: f64,
    pub cells: Vec<SpeedupCell>,
}

/// Calibrate per-step compute seconds with a short single-node run.
pub fn calibrate_step_secs(base: &ExperimentConfig, calib_iters: usize) -> Result<f64> {
    let mut cfg = base.clone();
    cfg.nodes = 1;
    cfg.iters = calib_iters;
    cfg.eval_every = 0;
    cfg.variance_every = 0;
    // never sync; pure compute
    StrategySpec::Constant { period: usize::MAX / 2 }.apply_to(&mut cfg.sync);
    cfg.name = "calibrate".into();
    let rep = Experiment::from_config(cfg)?.run()?;
    Ok(rep.compute_secs / calib_iters as f64)
}

/// Fig 6 for one model role. `base` must be a single-node-geometry
/// config whose `iters` is the single-node iteration count K.  The
/// (strategy × nodes) grid is one campaign; fixed-work scaling
/// (`iters = K/n`) is its post-patch.
pub fn fig6(role_name: &'static str, base: &ExperimentConfig, scale: Scale, sink: &Sink) -> Result<Fig6> {
    let calib = match scale {
        Scale::Quick => 50,
        Scale::Paper => 200,
    };
    let per_step = calibrate_step_secs(base, calib)?;
    let k1 = base.iters;
    let single_node_secs = per_step * k1 as f64;

    let fast = NetModel::new(&NetConfig::infiniband_100g());
    let slow = NetModel::new(&NetConfig::ethernet_10g());

    let report = Campaign::builder("fig6", base.clone())
        .strategy("fig6_full", StrategySpec::Full)
        .strategy("fig6_adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .nodes(&[2, 4, 8, 16])
        .post(move |cfg| {
            cfg.iters = (k1 / cfg.nodes).max(1);
            cfg.eval_every = 0;
            cfg.variance_every = 0;
        })
        .build()?
        .run()?;

    let mut cells = Vec::new();
    for run in &report.runs {
        let rep = &run.report;
        let compute = per_step * rep.iters as f64;
        let t100 = compute + rep.ledger.modeled_secs(&fast);
        let t10 = compute + rep.ledger.modeled_secs(&slow);
        cells.push(SpeedupCell {
            strategy: rep.strategy,
            nodes: rep.nodes,
            iters: rep.iters,
            syncs: rep.syncs,
            total_100g: t100,
            total_10g: t10,
            speedup_100g: single_node_secs / t100,
            speedup_10g: single_node_secs / t10,
        });
    }

    let mut t = Table::new(&["version", "nodes", "iters", "syncs", "speedup@100G", "speedup@10G"]);
    for c in &cells {
        t.row(&[
            c.strategy.to_string(),
            c.nodes.to_string(),
            c.iters.to_string(),
            c.syncs.to_string(),
            format!("{:.2}x", c.speedup_100g),
            format!("{:.2}x", c.speedup_10g),
        ]);
    }
    sink.print(&format!("Fig 6 ({role_name}) — speedup vs single-node vanilla SGD (K={k1})"));
    sink.print(&t.render());
    Ok(Fig6 { role_name, per_step_secs: per_step, single_node_secs, cells })
}

impl Fig6 {
    pub fn cell(&self, strategy: Strategy, nodes: usize) -> &SpeedupCell {
        self.cells
            .iter()
            .find(|c| c.strategy == strategy && c.nodes == nodes)
            .expect("cell missing")
    }
}

/// Heterogeneity extension (DESIGN.md §4 ablation): the same speedup
/// analysis with per-node compute jitter.  BSP waits for the slowest sum
/// of `p` steps at each sync, so periodic averaging amortizes stragglers
/// by ~√p on top of its bandwidth savings — an effect the paper's
/// homogeneous testbed cannot show.
pub fn straggler_panel(
    per_step: f64,
    k: usize,
    jitter_frac: f64,
    sink: &Sink,
) -> Vec<(usize, f64, f64)> {
    let cm = crate::netsim::ComputeModel::new(per_step, per_step * jitter_frac);
    let mut rows = Vec::new();
    let mut t = crate::metrics::Table::new(&[
        "nodes",
        "overhead p=1 (FULLSGD)",
        "overhead p=8 (periodic)",
        "amortization",
    ]);
    for &n in &[2usize, 4, 8, 16] {
        let o1 = cm.straggler_overhead(k, 1, n);
        let o8 = cm.straggler_overhead(k, 8, n);
        t.row(&[
            n.to_string(),
            format!("{:.2}%", (o1 - 1.0) * 100.0),
            format!("{:.2}%", (o8 - 1.0) * 100.0),
            format!("{:.2}x", (o1 - 1.0) / (o8 - 1.0).max(1e-12)),
        ]);
        rows.push((n, o1, o8));
    }
    sink.print(&format!(
        "Fig 6 extension — straggler overhead at {:.0}% per-step jitter (K={k})",
        jitter_frac * 100.0
    ));
    sink.print(&t.render());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, vgg_role};

    #[test]
    fn straggler_panel_amortizes_by_sqrt_p() {
        let rows = straggler_panel(1e-3, 4000, 0.2, &Sink::new(None, true));
        for (n, o1, o8) in rows {
            assert!(o1 > o8, "n={n}: full-sync overhead must exceed periodic");
            let amort = (o1 - 1.0) / (o8 - 1.0);
            assert!((amort - 8f64.sqrt()).abs() < 0.4, "n={n}: amortization {amort}");
        }
    }

    #[test]
    fn fig6_speedup_shapes() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        vgg_role(&mut base, scale); // comm-heavy: the interesting panel
        base.iters = 320;
        let f = fig6("vgg-role", &base, scale, &Sink::new(None, true)).unwrap();
        assert!(f.per_step_secs > 0.0);

        // speedup grows with n for ADPSGD (paper: near-linear)
        let a2 = f.cell(Strategy::Adaptive, 2).speedup_100g;
        let a16 = f.cell(Strategy::Adaptive, 16).speedup_100g;
        assert!(a16 > a2, "ADPSGD speedup must grow with nodes: {a2} -> {a16}");

        for &n in &[2usize, 4, 8, 16] {
            let full = f.cell(Strategy::Full, n);
            let adp = f.cell(Strategy::Adaptive, n);
            // ADPSGD at least matches FULLSGD at the same node count
            assert!(
                adp.speedup_100g >= full.speedup_100g * 0.99,
                "n={n}: adp {} vs full {}",
                adp.speedup_100g,
                full.speedup_100g
            );
            // the bandwidth throttle hurts FULLSGD more than ADPSGD
            let full_drop = full.speedup_100g / full.speedup_10g;
            let adp_drop = adp.speedup_100g / adp.speedup_10g;
            assert!(
                adp_drop <= full_drop * 1.01,
                "n={n}: adp drop {adp_drop} vs full drop {full_drop}"
            );
        }
    }
}
