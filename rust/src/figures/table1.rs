//! Table I: best test accuracy of SMALL_BATCH / ADPSGD / CPSGD(p sweep)
//! / FULLSGD(γ₀ sweep) on the CIFAR-geometry workloads.
//!
//! Paper result: SMALL_BATCH highest, ADPSGD second, CPSGD's best sweep
//! point below ADPSGD (while needing more communication), FULLSGD unable
//! to close the large-batch generalization gap by raising γ₀.

use super::{run_strategy, Scale, Sink};
use crate::config::ExperimentConfig;
use crate::coordinator::Trainer;
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub version: String,
    pub best_acc: f64,
    /// the sweep point that achieved it ("p=7", "γ₀=0.3", "")
    pub argmax: String,
    pub syncs: u64,
}

pub struct Table1 {
    pub rows: Vec<Table1Row>,
}

impl Table1 {
    pub fn get(&self, version: &str) -> &Table1Row {
        self.rows
            .iter()
            .find(|r| r.version == version)
            .unwrap_or_else(|| panic!("row {version} missing"))
    }
}

fn cpsgd_periods(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![2, 4, 8, 16],
        Scale::Paper => (2..=16).collect(),
    }
}

fn fullsgd_lrs(scale: Scale) -> Vec<f32> {
    match scale {
        Scale::Quick => vec![0.1, 0.2, 0.4, 0.8],
        Scale::Paper => (1..=16).map(|i| i as f32 * 0.1).collect(),
    }
}

/// Regenerate Table I for one base workload config.
pub fn table1(base: &ExperimentConfig, scale: Scale, sink: &Sink) -> Result<Table1> {
    let mut rows = Vec::new();

    // (a) SMALL_BATCH: vanilla single-node SGD, same number of epochs ⇒
    //     nodes× more iterations at 1/nodes the batch.
    {
        let mut cfg = base.clone();
        let n = cfg.nodes;
        cfg.nodes = 1;
        cfg.iters = base.iters * n;
        // keep the LR boundaries at the same epoch fractions
        if let crate::config::LrSchedule::StepDecay { boundaries, .. } = &mut cfg.optim.schedule {
            boundaries.iter_mut().for_each(|b| *b *= n);
        }
        cfg.eval_every = cfg.iters / 20;
        cfg.sync.strategy = Strategy::Full;
        cfg.name = "small_batch".into();
        let rep = Trainer::new(cfg)?.run()?;
        rows.push(Table1Row {
            version: "SMALL_BATCH".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("B={}", base.batch_per_node),
            syncs: 0,
        });
    }

    // (b) ADPSGD at the paper's default knobs.
    {
        let rep = run_strategy(base, Strategy::Adaptive, "table1_adpsgd")?;
        rows.push(Table1Row {
            version: "ADPSGD".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("p̄={:.2}", rep.avg_period),
            syncs: rep.syncs,
        });
    }

    // (c) CPSGD: sweep p, report the best.
    {
        let mut best: Option<(usize, f64, u64)> = None;
        for p in cpsgd_periods(scale) {
            let mut cfg = base.clone();
            cfg.sync.period = p;
            cfg.sync.warmup_iters = 0;
            let rep = run_strategy(&cfg, Strategy::Constant, &format!("table1_cpsgd_p{p}"))?;
            if best.map(|(_, acc, _)| rep.best_eval_acc > acc).unwrap_or(true) {
                best = Some((p, rep.best_eval_acc, rep.syncs));
            }
        }
        let (p, acc, syncs) = best.unwrap();
        rows.push(Table1Row {
            version: "CPSGD".into(),
            best_acc: acc,
            argmax: format!("p={p}"),
            syncs,
        });
    }

    // (d) FULLSGD: sweep γ₀ (linear-scaling attempts), report the best.
    {
        let mut best: Option<(f32, f64)> = None;
        for lr0 in fullsgd_lrs(scale) {
            let mut cfg = base.clone();
            cfg.optim.lr0 = lr0;
            let rep = run_strategy(&cfg, Strategy::Full, &format!("table1_full_lr{lr0}"))?;
            if rep.best_eval_acc.is_finite()
                && best.map(|(_, acc)| rep.best_eval_acc > acc).unwrap_or(true)
            {
                best = Some((lr0, rep.best_eval_acc));
            }
        }
        let (lr0, acc) = best.unwrap();
        rows.push(Table1Row {
            version: "FULLSGD".into(),
            best_acc: acc,
            argmax: format!("γ₀={lr0}"),
            syncs: base.iters as u64,
        });
    }

    let mut t = Table::new(&["version", "best acc", "argmax", "syncs"]);
    for r in &rows {
        t.row(&[
            r.version.clone(),
            format!("{:.4}", r.best_acc),
            r.argmax.clone(),
            r.syncs.to_string(),
        ]);
    }
    sink.print("Table I — best test accuracy per version");
    sink.print(&t.render());
    Ok(Table1 { rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, googlenet_role};

    #[test]
    fn table1_rows_and_sanity() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        base.iters = 240; // keep the sweep quick
        base.eval_every = 40;
        if let crate::config::LrSchedule::StepDecay { boundaries, .. } = &mut base.optim.schedule {
            *boundaries = vec![120, 180];
        }
        let t = table1(&base, scale, &Sink::new(None, true)).unwrap();
        assert_eq!(t.rows.len(), 4);
        for r in &t.rows {
            assert!(
                r.best_acc.is_finite() && r.best_acc > 0.2,
                "{}: acc {}",
                r.version,
                r.best_acc
            );
        }
        // every version must clear random chance by a wide margin
        let adp = t.get("ADPSGD");
        assert!(adp.best_acc > 0.5, "ADPSGD acc {}", adp.best_acc);
    }
}
