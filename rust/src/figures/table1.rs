//! Table I: best test accuracy of SMALL_BATCH / ADPSGD / CPSGD(p sweep)
//! / FULLSGD(γ₀ sweep) on the CIFAR-geometry workloads.
//!
//! Paper result: SMALL_BATCH highest, ADPSGD second, CPSGD's best sweep
//! point below ADPSGD (while needing more communication), FULLSGD unable
//! to close the large-batch generalization gap by raising γ₀.

use super::{Scale, Sink};
use crate::config::{ExperimentConfig, StrategySpec};
use crate::experiment::Campaign;
use crate::metrics::Table;
use crate::period::Strategy;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub version: String,
    pub best_acc: f64,
    /// the sweep point that achieved it ("p=7", "γ₀=0.3", "")
    pub argmax: String,
    pub syncs: u64,
}

pub struct Table1 {
    pub rows: Vec<Table1Row>,
}

impl Table1 {
    pub fn get(&self, version: &str) -> &Table1Row {
        self.rows
            .iter()
            .find(|r| r.version == version)
            .unwrap_or_else(|| panic!("row {version} missing"))
    }
}

fn cpsgd_periods(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![2, 4, 8, 16],
        Scale::Paper => (2..=16).collect(),
    }
}

fn fullsgd_lrs(scale: Scale) -> Vec<f32> {
    match scale {
        Scale::Quick => vec![0.1, 0.2, 0.4, 0.8],
        Scale::Paper => (1..=16).map(|i| i as f32 * 0.1).collect(),
    }
}

/// Regenerate Table I for one base workload config.  The four run
/// families are four campaign definitions executed as one union:
/// (a) SMALL_BATCH — a single-run variant patch (1 node, nodes× iters);
/// (b) ADPSGD at the paper's defaults; (c) a CPSGD period sweep as a
/// strategy axis of `Constant` specs; (d) a FULLSGD γ₀ sweep as a
/// variant axis.
pub fn table1(base: &ExperimentConfig, scale: Scale, sink: &Sink) -> Result<Table1> {
    let periods = cpsgd_periods(scale);
    let lrs = fullsgd_lrs(scale);
    let n = base.nodes;

    let small_batch = Campaign::builder("table1_small", base.clone())
        .strategy("small_batch", StrategySpec::Full)
        .post(move |cfg| {
            // vanilla single-node SGD, same number of epochs ⇒ nodes×
            // more iterations at 1/nodes the batch, LR boundaries at the
            // same epoch fractions
            cfg.nodes = 1;
            cfg.iters *= n;
            if let crate::config::LrSchedule::StepDecay { boundaries, .. } =
                &mut cfg.optim.schedule
            {
                boundaries.iter_mut().for_each(|b| *b *= n);
            }
            cfg.eval_every = cfg.iters / 20;
        })
        .build()?;

    let adpsgd = Campaign::builder("table1_adp", base.clone())
        .strategy("table1_adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .build()?;

    let cpsgd_sweep = Campaign::builder("table1_cpsgd", base.clone())
        .strategies(
            periods
                .iter()
                .map(|&p| (format!("table1_cpsgd_p{p}"), StrategySpec::Constant { period: p })),
        )
        .build()?;

    let mut full_sweep = Campaign::builder("table1_full", base.clone())
        .strategy("table1_full", StrategySpec::Full);
    for &lr0 in &lrs {
        full_sweep = full_sweep.variant(format!("lr{lr0}"), move |cfg| cfg.optim.lr0 = lr0);
    }
    let full_sweep = full_sweep.build()?;

    let report =
        Campaign::union("table1", [small_batch, adpsgd, cpsgd_sweep, full_sweep])?.run()?;

    let mut rows = Vec::new();

    // (a) SMALL_BATCH
    {
        let rep = report.get("small_batch");
        rows.push(Table1Row {
            version: "SMALL_BATCH".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("B={}", base.batch_per_node),
            syncs: 0,
        });
    }

    // (b) ADPSGD at the paper's default knobs.
    {
        let rep = report.get("table1_adpsgd");
        rows.push(Table1Row {
            version: "ADPSGD".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("p̄={:.2}", rep.avg_period),
            syncs: rep.syncs,
        });
    }

    // (c) CPSGD: best point of the period sweep.
    {
        let (p, rep) = periods
            .iter()
            .map(|&p| (p, report.get(&format!("table1_cpsgd_p{p}"))))
            .max_by(|a, b| a.1.best_eval_acc.total_cmp(&b.1.best_eval_acc))
            .expect("cpsgd sweep is non-empty");
        rows.push(Table1Row {
            version: "CPSGD".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("p={p}"),
            syncs: rep.syncs,
        });
    }

    // (d) FULLSGD: best point of the γ₀ sweep.
    {
        let (lr0, rep) = lrs
            .iter()
            .map(|&lr0| (lr0, report.get(&format!("table1_full_lr{lr0}"))))
            .filter(|(_, r)| r.best_eval_acc.is_finite())
            .max_by(|a, b| a.1.best_eval_acc.total_cmp(&b.1.best_eval_acc))
            .expect("fullsgd sweep has a finite point");
        rows.push(Table1Row {
            version: "FULLSGD".into(),
            best_acc: rep.best_eval_acc,
            argmax: format!("γ₀={lr0}"),
            syncs: base.iters as u64,
        });
    }

    let mut t = Table::new(&["version", "best acc", "argmax", "syncs"]);
    for r in &rows {
        t.row(&[
            r.version.clone(),
            format!("{:.4}", r.best_acc),
            r.argmax.clone(),
            r.syncs.to_string(),
        ]);
    }
    sink.print("Table I — best test accuracy per version");
    sink.print(&t.render());
    Ok(Table1 { rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::{cifar_base, googlenet_role};

    #[test]
    fn table1_rows_and_sanity() {
        let scale = Scale::Quick;
        let mut base = cifar_base(scale);
        googlenet_role(&mut base, scale);
        base.iters = 240; // keep the sweep quick
        base.eval_every = 40;
        if let crate::config::LrSchedule::StepDecay { boundaries, .. } = &mut base.optim.schedule {
            *boundaries = vec![120, 180];
        }
        let t = table1(&base, scale, &Sink::new(None, true)).unwrap();
        assert_eq!(t.rows.len(), 4);
        for r in &t.rows {
            assert!(
                r.best_acc.is_finite() && r.best_acc > 0.2,
                "{}: acc {}",
                r.version,
                r.best_acc
            );
        }
        // every version must clear random chance by a wide margin
        let adp = t.get("ADPSGD");
        assert!(adp.best_acc > 0.5, "ADPSGD acc {}", adp.best_acc);
    }
}
