//! Figures 1–3: the parameter-variance statistics that motivate ADPSGD.
//!
//! * **Fig 1** — `V_t` (average `Var[W_k]` between two synchronizations)
//!   for CPSGD at p ∈ {2, 4, 5, 8}: large at start, ∝ γ², drops at the
//!   LR-decay boundaries.
//! * **Fig 2** — `V_t` of ADPSGD vs CPSGD p=8: ADPSGD holds `V_t` nearly
//!   flat (∝ γ) early and decays slower late.
//! * **Fig 3** — ADPSGD's averaging-period trajectory: fixed at p_init
//!   while sampling C₂, then growing, jumping up after each LR decay.

use super::{cifar_base, googlenet_role, Scale, Sink};
use crate::config::{ExperimentConfig, StrategySpec};
use crate::coordinator::RunReport;
use crate::experiment::Campaign;
use crate::metrics::{Series, Table};
use crate::period::Strategy;
use anyhow::Result;

/// `V_t` series reconstructed from the sampled `Var[W_k]` curve: mean of
/// the variance samples between consecutive synchronization points.
pub fn vt_series(report: &RunReport) -> Series {
    let mut out = Series::new("v_t");
    let Some(var) = report.recorder.get("var") else {
        return out;
    };
    let Some(syncs) = report.recorder.get("sync_at") else {
        return out;
    };
    let mut prev = 0.0f64;
    for (sx, _) in &syncs.points {
        if let Some(mean) = var.mean_y_in(prev, *sx + 0.5) {
            out.push(*sx, mean);
        }
        prev = *sx + 0.5;
    }
    out
}

/// Mean of a series' y over the x-fraction window [a, b) of `iters`.
pub fn window_mean(s: &Series, iters: usize, a: f64, b: f64) -> f64 {
    s.mean_y_in(a * iters as f64, b * iters as f64).unwrap_or(f64::NAN)
}

fn variance_base(scale: Scale) -> ExperimentConfig {
    let mut cfg = cifar_base(scale);
    googlenet_role(&mut cfg, scale);
    // dense Var[W_k] sampling — instrumentation only, not charged to comm
    cfg.variance_every = match scale {
        Scale::Quick => 2,
        Scale::Paper => 4,
    };
    cfg.eval_every = 0; // pure statistics run
    cfg
}

/// One per-period result of the Fig 1 sweep.
pub struct Fig1Row {
    pub p: usize,
    pub report: RunReport,
    pub v_t: Series,
}

pub struct Fig1 {
    pub rows: Vec<Fig1Row>,
    pub iters: usize,
}

/// Fig 1: CPSGD variance for p ∈ {2,4,5,8} — a period sweep expressed
/// as a strategy axis of four `Constant` specs.
pub fn fig1(scale: Scale, sink: &Sink) -> Result<Fig1> {
    let base = variance_base(scale);
    const PERIODS: [usize; 4] = [2, 4, 5, 8];
    let campaign = Campaign::builder("fig1", base.clone())
        .strategies(
            PERIODS
                .iter()
                .map(|&p| (format!("fig1_p{p}"), StrategySpec::Constant { period: p })),
        )
        .build()?;
    let mut rows = Vec::new();
    for (run, &p) in campaign.run()?.runs.into_iter().zip(PERIODS.iter()) {
        let report = run.report;
        let v_t = vt_series(&report);
        sink.write(&format!("fig1_p{p}"), &report.recorder)?;
        rows.push(Fig1Row { p, report, v_t });
    }

    let iters = base.iters;
    let mut t = Table::new(&["p", "V_t[0-5%]", "V_t[5-50%]", "V_t[50-75%]", "V_t[75-100%]", "syncs"]);
    for r in &rows {
        t.row(&[
            r.p.to_string(),
            format!("{:.3e}", window_mean(&r.v_t, iters, 0.0, 0.05)),
            format!("{:.3e}", window_mean(&r.v_t, iters, 0.05, 0.50)),
            format!("{:.3e}", window_mean(&r.v_t, iters, 0.50, 0.75)),
            format!("{:.3e}", window_mean(&r.v_t, iters, 0.75, 1.0)),
            r.report.syncs.to_string(),
        ]);
    }
    sink.print("Fig 1 — V_t of CPSGD (GoogLeNet-role, CIFAR geometry)");
    sink.print(&t.render());
    Ok(Fig1 { rows, iters })
}

pub struct Fig23 {
    pub adpsgd: RunReport,
    pub cpsgd8: RunReport,
    pub adpsgd_vt: Series,
    pub cpsgd_vt: Series,
    /// (k, p) trajectory — Fig 3
    pub period_traj: Series,
    pub iters: usize,
}

/// Fig 2 + Fig 3: ADPSGD variance + period trajectory vs CPSGD p=8 —
/// one two-strategy campaign (ADPSGD keeps the warmup epoch + p_init=4
/// + K_s=0.25K from `cifar_base`).
pub fn fig2_fig3(scale: Scale, sink: &Sink) -> Result<Fig23> {
    let base = variance_base(scale);
    let mut report = Campaign::builder("fig2", base.clone())
        .strategy("fig2_cpsgd8", StrategySpec::Constant { period: 8 })
        .strategy("fig2_adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .build()?
        .run()?;
    let cpsgd8 = report.take("fig2_cpsgd8");
    let adpsgd = report.take("fig2_adpsgd");

    let adpsgd_vt = vt_series(&adpsgd);
    let cpsgd_vt = vt_series(&cpsgd8);
    let period_traj = adpsgd
        .recorder
        .get("period")
        .cloned()
        .unwrap_or_else(|| Series::new("period"));

    sink.write("fig2_adpsgd", &adpsgd.recorder)?;
    sink.write("fig2_cpsgd8", &cpsgd8.recorder)?;

    let iters = base.iters;
    let mut t = Table::new(&["run", "V_t[0-50%]", "V_t[50-100%]", "syncs", "p̄", "final p"]);
    for (name, rep, vt) in
        [("ADPSGD", &adpsgd, &adpsgd_vt), ("CPSGD p=8", &cpsgd8, &cpsgd_vt)]
    {
        let final_p = if name == "ADPSGD" {
            period_traj.last_y().unwrap_or(f64::NAN)
        } else {
            8.0
        };
        t.row(&[
            name.to_string(),
            format!("{:.3e}", window_mean(vt, iters, 0.0, 0.50)),
            format!("{:.3e}", window_mean(vt, iters, 0.50, 1.0)),
            rep.syncs.to_string(),
            format!("{:.2}", rep.avg_period),
            format!("{final_p:.0}"),
        ]);
    }
    sink.print("Fig 2/3 — ADPSGD variance + period trajectory vs CPSGD p=8");
    sink.print(&t.render());

    Ok(Fig23 { adpsgd, cpsgd8, adpsgd_vt, cpsgd_vt, period_traj, iters })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> Sink {
        Sink::new(None, true)
    }

    #[test]
    fn fig1_variance_shape_matches_paper() {
        let f = fig1(Scale::Quick, &quiet()).unwrap();
        assert_eq!(f.rows.len(), 4);
        for r in &f.rows {
            assert!(!r.v_t.points.is_empty(), "p={} has no V_t points", r.p);
            // variance drops after the LR decays (paper: drops at 80/120ep)
            let early = window_mean(&r.v_t, f.iters, 0.05, 0.5);
            let late = window_mean(&r.v_t, f.iters, 0.75, 1.0);
            assert!(
                late < early,
                "p={}: V_t should fall after LR decay ({early:.3e} -> {late:.3e})",
                r.p
            );
        }
        // larger p -> larger V_t (bound (10): V_t grows with p)
        let v2 = window_mean(&f.rows[0].v_t, f.iters, 0.05, 0.5);
        let v8 = window_mean(&f.rows[3].v_t, f.iters, 0.05, 0.5);
        assert!(v8 > v2, "V_t(p=8)={v8:.3e} should exceed V_t(p=2)={v2:.3e}");
    }

    #[test]
    fn fig2_adpsgd_flatter_variance_less_comm() {
        let f = fig2_fig3(Scale::Quick, &quiet()).unwrap();
        // ADPSGD must not out-communicate CPSGD p=8 by much; paper has
        // it *below* (498 vs 500). Allow headroom at quick scale.
        assert!(
            (f.adpsgd.syncs as f64) < 1.6 * f.cpsgd8.syncs as f64,
            "adpsgd {} vs cpsgd {}",
            f.adpsgd.syncs,
            f.cpsgd8.syncs
        );
        // Fig 3 shape: the period grows over training
        let p0 = f.period_traj.points.first().map(|p| p.1).unwrap_or(0.0);
        let p1 = f.period_traj.last_y().unwrap_or(0.0);
        assert!(p1 >= p0, "period should not shrink over training: {p0} -> {p1}");
        // Fig 2 shape: early V_t of ADPSGD below CPSGD p=8 (that is the
        // whole point of the algorithm)
        let a_early = window_mean(&f.adpsgd_vt, f.iters, 0.02, 0.5);
        let c_early = window_mean(&f.cpsgd_vt, f.iters, 0.02, 0.5);
        assert!(
            a_early < c_early,
            "ADPSGD early V_t {a_early:.3e} must undercut CPSGD {c_early:.3e}"
        );
    }
}
