//! # adpsgd — Adaptive Periodic Parameter Averaging SGD
//!
//! Production-shaped reproduction of *"Adaptive Periodic Averaging: A
//! Practical Approach to Reducing Communication in Distributed Learning"*
//! (Jiang & Agrawal, 2020).
//!
//! The paper's contribution is a coordination-layer scheduling algorithm:
//! during distributed data-parallel SGD with periodic parameter averaging,
//! pick the averaging period `p` **adaptively** so that the inter-node
//! parameter variance `S_k` tracks `γ_k·C₂/M` (Algorithm 2 of the paper),
//! rather than using a constant period (Algorithm 1).  This crate is the
//! Layer-3 rust coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — worker/leader orchestration, period controllers,
//!   in-process collectives, QSGD quantization, a network cost model that
//!   reproduces the paper's 100Gbps/10Gbps wall-clock analysis, metrics,
//!   config, CLI.
//!
//! ## The synchronization subsystem
//!
//! Synchronization spans three pluggable layers:
//!
//! * **Data plane** — [`collective::Collective`], the communicator
//!   trait, with two algorithms selected by `cfg.sync.collective`:
//!   [`collective::RingComm`] (chunked reduce-scatter + all-gather;
//!   every rank reduces its own chunk in parallel — the default) and
//!   [`collective::FlatComm`] (leader-serialized reference).  Both
//!   reduce in fixed rank order, so results are bit-identical across
//!   algorithms and runs; both share abortable-barrier poison semantics
//!   for clean cluster teardown on node failure.
//! * **Pipeline** — [`coordinator::sync::SyncStep`], the per-node stage
//!   composition (period gate → payload transform → collective exchange
//!   → S_k agreement → elastic pull → ledger charge).  FULLSGD, CPSGD,
//!   ADPSGD, QSGD, TopK, and EASGD are all stage combinations of this
//!   one pipeline; compression codecs plug in through its
//!   [`coordinator::sync::GradTransform`] hook.  Per-node state lives in
//!   [`coordinator::node::Node`].
//! * **Cost model** — [`netsim::NetModel`] prices each exchange **per
//!   collective algorithm** (flat's gather+broadcast serializes `2(n−1)·B`
//!   on the leader's link; ring pipelines `2(n−1)/n·B` per link), and
//!   [`netsim::CommLedger`] accumulates those costs so
//!   `RunReport::modeled_total_secs` reflects the configured algorithm
//!   under any bandwidth preset.
//! * **L2 (python/compile/model.py, build-time only)** — the model zoo as
//!   pure functions over flat `f32[P]` parameter vectors, AOT-lowered to
//!   HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/, build-time only)** — Pallas kernels
//!   (blocked matmul, fused momentum update, squared-deviation reduction,
//!   QSGD quantizer) baked into those artifacts.
//!
//! The [`runtime`] module loads the artifacts via the PJRT C API and
//! executes them from the training hot path; python never runs at train
//! time.
//!
//! ## Quick start — one experiment
//!
//! Experiments are described by a typed [`config::StrategySpec`] (each
//! strategy carries exactly its own knobs) and run through the session
//! API:
//!
//! ```no_run
//! use adpsgd::config::StrategySpec;
//! use adpsgd::experiment::Experiment;
//!
//! let report = Experiment::builder()
//!     .name("quickstart")
//!     .nodes(8)
//!     .iters(2_000)
//!     .strategy(StrategySpec::Adaptive {
//!         p_init: 4, warmup_iters: 25, ks_frac: 0.25, low: 0.7, high: 1.3,
//!     })
//!     .build()
//!     .unwrap()
//!     .run()
//!     .unwrap();
//! println!("final loss {:.4}", report.final_train_loss);
//! ```
//!
//! Observers ([`experiment::RunObserver`]) tap the coordinator's typed
//! event stream (`IterEnd`, `SyncDone`, `CheckpointDue`, …) — the
//! built-in metrics recorder and checkpoint writer are themselves
//! observers.  Custom period controllers plug in through
//! [`period::registry`] or per-session via
//! `ExperimentBuilder::period_controller`.
//!
//! ## Quick start — a campaign
//!
//! Multi-run sweeps are declarative [`experiment::Campaign`]s (strategy
//! × nodes × network × collective), with bounded-parallel scheduling
//! and shared dataset caching:
//!
//! ```no_run
//! use adpsgd::collective::Algo;
//! use adpsgd::config::{ExperimentConfig, StrategySpec};
//! use adpsgd::experiment::Campaign;
//! use adpsgd::period::Strategy;
//!
//! let base = ExperimentConfig::default();
//! let report = Campaign::builder("demo", base.clone())
//!     .strategy("fullsgd", StrategySpec::Full)
//!     .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
//!     .collectives(&[Algo::Ring, Algo::Flat])
//!     .parallelism(2)
//!     .build()
//!     .unwrap()
//!     .run()
//!     .unwrap();
//! println!("{}", report.table().render());
//! ```
//!
//! Campaign execution routes through the [`dispatch`] subsystem: a
//! persistent content-addressed run cache (same resolved config →
//! cached [`coordinator::RunReport`], bit-identical, probed on the
//! pool's own threads and bounded by `RunCache::gc` /
//! `adpsgd cache-gc` — with `--dry-run` to preview evictions), a
//! work-stealing pool of in-process threads, `adpsgd worker`
//! subprocesses (a line-delimited JSON protocol; crashed **or hung**
//! workers — detected by heartbeat deadline, `--hang-timeout` — retry
//! on another slot), and/or **remote `adpsgd agent` daemons** over the
//! [`dispatch::net`] TCP transport (`--remote host:port`, `--workers
//! remote`; mixed local+remote slots drain one queue, agents probe
//! their own cache before executing, and a silent or disconnected
//! agent is handled exactly like a hung child), and a deterministic
//! merge — so `--jobs 8`, a warm cache, or a rack of agents change
//! wall-clock, never results: the stable campaign summary is
//! byte-identical across local, cached, and remote execution.
//! [`dispatch::fleet`] makes the remote membership *elastic*: agents
//! announce themselves to an `adpsgd registry` under a liveness lease
//! and `--fleet host:port` resolves them at poll time, so capacity can
//! join a campaign already in flight; a dropped agent is redialed
//! under capped exponential backoff with jitter (completed runs are
//! never redriven), warm-start snapshots are staged content-addressed
//! over blob frames only to agents that lack them, connections are
//! authenticated by a challenge-response keyed digest (the shared
//! token never travels the wire), and a cancel frame kills orphaned
//! runs in agents' worker children.  Subprocess children live in a
//! process-wide shared [`dispatch::WorkerPool`] (agents reuse the same
//! pool for their own children), so sequential campaigns reuse warm
//! workers and teardown is graceful (stdin EOF, bounded wait, then
//! kill).  Wire frames are versioned: a version-skewed peer is
//! rejected with a clear rebuild-both-ends error, never a generic
//! parse failure.  See [`dispatch`] for the experiment → dispatch →
//! coordinator layering.
//!
//! ## Scenarios — heterogeneous clusters
//!
//! The homogeneous network model generalizes to a full cluster model:
//! [`netsim::cluster::ClusterModel`] carries per-node compute
//! multipliers (`[cluster] skew = "straggler:4.0"` / `"linear:1.5"` or
//! explicit `factors`), per-link bandwidth/latency asymmetry
//! (`link_bw_gbps`, `link_latency_us` — collectives bottleneck on the
//! slowest member), seeded per-step jitter, and a **deterministic fault
//! schedule** (`[cluster.faults]`: node pauses and packet-delay spikes
//! concretized from the run seed).  [`netsim::cluster::ClusterClock`]
//! advances one modeled clock per node — compute steps scale by the
//! node's factor, BSP syncs barrier every clock to the straggler, and
//! DaSGD's delayed apply only waits for its in-flight average's modeled
//! arrival.  Heterogeneity moves **modeled clocks and the ledger
//! only**: the parameter trajectory is bit-identical with skew/faults
//! on or off (the invariant the property tests pin), while
//! `RunReport::modeled_wall_secs` — deterministic, config-declared
//! `cluster.step_us`, never measured time — shows what each strategy
//! pays.  Every `[cluster]` knob is result-affecting for the run-cache
//! digest; `net.preset` names the paper's bandwidth presets with
//! parse-time validation.
//!
//! The strategy zoo covers the related work under these scenarios:
//! AdaComm (`adacomm`, arXiv 1810.08313 — τ from the loss ratio),
//! Parallel Restarted SGD (`prsgd`, arXiv 1807.06629 — local SGD with
//! momentum restarts), and delayed-averaging DaSGD (`dasgd`, arXiv
//! 2006.00441 — averages applied `delay` iterations late to overlap
//! communication with compute).  `adpsgd figures --only robustness`
//! ([`figures::robustness`]) sweeps all five strategies across
//! skew × fault × network axes and writes a byte-stable summary.
//!
//! ## Performance
//!
//! The flat-vector kernels in [`tensor`] (dot, norms, axpy, fused
//! momentum, elastic pull, row means) are written as explicit 8-lane
//! loops over fixed 4096-element chunks and dispatch across a small
//! owned thread pool ([`tensor::par`]).  Work is partitioned on the
//! same chunk boundaries the serial reductions already used and chunk
//! partials are folded in chunk order, so **every result is
//! bit-identical at any thread count** — parallelism is a pure
//! wall-clock knob, never a numerics knob.  `cfg.perf.threads`
//! (CLI `--perf.threads`) selects the width: `0` = auto (all cores),
//! `1` = serial; like the scheduler's `jobs` it is excluded from run
//! digests, so changing it never invalidates the run cache.  The QSGD
//! quantizer computes bucket norms through the same pool (its
//! stochastic level walk stays sequential to preserve RNG draw order)
//! and exposes scratch-reusing entry points ([`quant::encode_into`],
//! [`quant::quantize_inplace_with`]) so per-sync hot paths never
//! reallocate.
//!
//! On the wire, protocol v4 ships bulk payloads — run-result metric
//! series and `blob` artifacts — as length-delimited *binary* frames on
//! the TCP transport ([`dispatch::net::transport`]), skipping JSON
//! float formatting for multi-MB series; control frames stay JSON, and
//! the stdio worker protocol stays pure JSONL.  v4 adds the
//! challenge-response handshake, blob staging, and cancel frames for
//! the fleet layer; v5 adds the optional trace-id field on run
//! requests and the `stats_request`/`stats` frames behind
//! `adpsgd status`; v6 adds the batched `events` frames that stream
//! worker/agent observer events back into the driver's campaign
//! journal.  `cargo bench` reports serial-vs-parallel speedup
//! columns (`bench_tensor`, `bench_quant`, `bench_step`),
//! JSON-vs-binary wire bytes per run, fleet join latency, blob
//! bytes staged per warm-start run, and the journal's and event
//! stream's wall-clock overhead per run (`bench_dispatch`).
//!
//! ## Observability
//!
//! The [`obs`] module is the process-wide telemetry layer — metrics,
//! journal, and logging — spanning coordinator → dispatch → fleet →
//! agent:
//!
//! * **Structured event journal.**  `adpsgd campaign` writes
//!   `results/<name>.campaign.jsonl` next to the stable summary
//!   (suppress with `--no-journal`): one self-describing JSON line per
//!   event — `{"schema":1,"ts":"…Z","event":"run.start","trace":
//!   "9f2c…",…}` — covering the campaign span (`campaign.start/end`),
//!   the dispatch fabric (`run.queued`, `run.cache_hit`,
//!   `cache.store`, `run.crashed`), and the coordinator's
//!   [`experiment::RunObserver`] events bridged by
//!   [`obs::JournalObserver`] (`run.sync`, `run.eval`, …; the
//!   per-iteration `IterEnd` is deliberately skipped).  Every run gets
//!   a `trace_id` minted at the driver ([`obs::mint_trace_id`]) and
//!   propagated through proto run-request frames, so one grep
//!   follows a run driver → agent → worker child.  Journaling is a
//!   pure observer: stable campaign summaries are byte-identical with
//!   it on or off.
//! * **Event streaming.**  Since proto v6 those same observer lines
//!   also stream *back* from subprocess worker children (stdio) and
//!   remote agents (TCP, interleaved with heartbeats) as batched
//!   `events` frames; [`obs::Journal::merge_line`] validates each and
//!   splices in an `origin` tag (`"node"` / `"agent:<addr>"`), so the
//!   one campaign journal is identically shaped across local,
//!   subprocess, remote, and fleet execution.  Streaming is
//!   best-effort — dropped or stale batches only bump the
//!   `obs.event_drops` counter — and never result-affecting
//!   (`--no-stream` turns it off; summaries stay byte-identical
//!   either way).
//! * **Timeline analysis.**  `adpsgd trace <name>.campaign.jsonl`
//!   ([`obs::trace`]) groups journal lines per run and attributes each
//!   run's `modeled_wall_secs` into per-node compute / barrier-wait /
//!   comm buckets from the streamed `run.sync`/`run.end` events, with
//!   the critical path and per-round straggler counts;
//!   `--emit-cluster` harvests the observed skew as a paste-ready
//!   `[cluster] factors` block validated against the config parser
//!   (closing the loop into [`netsim::cluster`]'s replay model).
//! * **Metrics registry.**  [`obs::metrics()`] hands out process-wide
//!   counters/gauges/histograms (queue depth, cache hit/miss,
//!   crash-requeues, backoff attempts, blob bytes staged, slot
//!   utilization — glossary in [`obs::metrics`]) that snapshot to
//!   deterministic JSON; histogram snapshots carry count/sum/min/max
//!   plus p50/p95/p99 estimated from fixed log2 buckets.
//! * **`adpsgd status`.**  Queries a live fleet: registry membership
//!   with lease ages (`--fleet`), plus each agent's advertised slots,
//!   in-flight runs, cache hit-rate, and metrics snapshot over a
//!   proto `stats_request` (`--remote`, repeatable; `--json` for
//!   machines; byte-valued metrics humanized in the table view).
//! * **Unified diagnostics.**  Fabric messages funnel through
//!   `obs::log!` with ISO-8601 timestamps and component tags, so
//!   interleaved slot/poller/agent output stays attributable.
//!
//! (The historical `Trainer::new(cfg)?.run()` front-door is gone; every
//! caller goes through [`experiment::Experiment`] now.)

pub mod analysis;
pub mod checkpoint;
pub mod cli;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod experiment;
pub mod figures;
pub mod metrics;
pub mod netsim;
pub mod obs;
pub mod optim;
pub mod period;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod util;
pub mod workload;

pub use config::{ExperimentConfig, StrategySpec};
pub use coordinator::RunReport;
pub use experiment::{Campaign, Experiment};
pub use period::Strategy;
