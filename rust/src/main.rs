//! `adpsgd` — the launcher.
//!
//! ```text
//! adpsgd run      [--config exp.toml] [--sync.strategy=adpsgd] [--nodes 16] ...
//! adpsgd campaign [--strategies full,cpsgd,adpsgd,qsgd] [--jobs 8]
//!                 [--workers subprocess|remote] [--remote host:7070]
//!                 [--cache-dir DIR] [--hang-timeout 10] ...
//! adpsgd figures  [--only fig1,fig4,...] [--quick] [--cache-dir DIR]
//!                 [--jobs 8] [--remote host:7070] [--out results]
//! adpsgd agent    --listen 0.0.0.0:7070 [--slots 8] [--token T] [--cache-dir DIR]
//!                 [--fleet host:7000] [--cache-max-bytes N]
//! adpsgd registry --listen 0.0.0.0:7000
//! adpsgd status   [--fleet host:7000] [--remote host:7070[,...]] [--json]
//! adpsgd trace    results/name.campaign.jsonl [--json | --emit-cluster]
//! adpsgd cache-gc [--cache-dir DIR] [--max-bytes N] [--max-age-secs S] [--dry-run]
//! adpsgd models   [--artifacts artifacts]
//! adpsgd worker
//! adpsgd help
//! ```
//!
//! `run` executes one experiment described by a TOML config plus dotted
//! CLI overrides (through the session API); `campaign` executes a
//! declarative strategy × nodes × bandwidth × collective sweep through
//! the dispatch subsystem (worker pool + persistent run cache + remote
//! agents) and writes a JSON summary; `figures` regenerates every paper
//! table/figure (see DESIGN.md §4) under the same dispatch flags;
//! `agent` serves campaign runs over TCP for `--remote` dispatchers
//! (the cross-machine end of the worker fabric); `registry` is the
//! fleet phonebook agents announce themselves to and `--fleet`
//! dispatchers resolve members from; `status` is the live fleet/agent
//! view (membership, lease ages, in-flight runs, cache hit-rates over
//! the proto `Stats` frame); `trace` reconstructs per-run timelines
//! (per-node compute/wait/comm attribution, critical path, straggler
//! counts, `--emit-cluster` skew harvesting) from a written campaign
//! journal; `models` lists the AOT
//! artifacts the PJRT runtime can load; `worker` is the subprocess end
//! of the dispatcher's line-delimited JSON protocol (not for
//! interactive use).

use adpsgd::cli::Args;
use adpsgd::collective::Algo;
use adpsgd::config::{ExperimentConfig, NetConfig, StrategySpec};
use adpsgd::dispatch::{self, DispatchOptions, WorkerKind};
use adpsgd::experiment::{Campaign, Experiment};
use adpsgd::figures::{self, Scale, Sink};
use adpsgd::period::Strategy;
use anyhow::{bail, Context, Result};

const HELP: &str = "\
adpsgd — Adaptive Periodic Parameter Averaging SGD (Jiang & Agrawal 2020)

USAGE:
    adpsgd run      [--config FILE] [--out DIR] [--json [--series]]
                    [--key.subkey=value ...]
    adpsgd campaign [--config FILE] [--name NAME] [--strategies LIST]
                    [--sweep-nodes LIST] [--bandwidths LIST] [--collectives LIST]
                    [--jobs N] [--workers thread|subprocess|remote]
                    [--remote HOST:PORT[,...]] [--fleet HOST:PORT]
                    [--remote-token T]
                    [--cache-dir DIR] [--no-cache] [--retries N]
                    [--hang-timeout SECS] [--cache-max-bytes N]
                    [--quick] [--json] [--out DIR] [--no-journal] [--no-stream]
    adpsgd figures  [--only LIST] [--quick] [--out DIR]
                    [--jobs N] [--workers thread|subprocess|remote]
                    [--remote HOST:PORT[,...]] [--fleet HOST:PORT]
                    [--remote-token T]
                    [--cache-dir DIR] [--no-cache] [--retries N]
                    [--hang-timeout SECS]
    adpsgd agent    --listen HOST:PORT [--slots N] [--token T]
                    [--cache-dir DIR] [--cache-max-bytes N]
                    [--fleet HOST:PORT] [--advertise HOST:PORT]
                    [--hang-timeout SECS]
    adpsgd registry --listen HOST:PORT
    adpsgd status   [--fleet HOST:PORT] [--remote HOST:PORT[,...]]
                    [--remote-token T] [--timeout-secs S] [--json]
    adpsgd trace    DIR/NAME.campaign.jsonl [--json | --emit-cluster]
    adpsgd cache-gc [--cache-dir DIR] [--max-bytes N] [--max-age-secs S]
                    [--tmp-grace-secs S] [--dry-run]
    adpsgd models   [--artifacts DIR]
    adpsgd worker   (dispatcher subprocess; speaks JSONL on stdin/stdout)
    adpsgd help

RUN OVERRIDES (dotted keys mirror the TOML schema):
    --nodes 16 --iters 4000 --batch_per_node 128 --seed 42
    --sync.strategy {full|cpsgd|adpsgd|decreasing|qsgd|piecewise|easgd|topk|
                     adacomm|prsgd|dasgd}
    --sync.<strategy>.<knob>        typed per-strategy knobs, e.g.:
        --sync.adaptive.p_init 4 --sync.adaptive.ks_frac 0.25
        --sync.constant.period 8
        --sync.qsgd.levels 255 --sync.qsgd.bucket 512
        --sync.easgd.period 8 --sync.easgd.alpha 0.5
        --sync.adacomm.tau0 16
        --sync.prsgd.period 8
        --sync.dasgd.period 8 --sync.dasgd.delay 2
    --sync.collective {ring|flat}   (allreduce algorithm: chunked-parallel
                                     ring, or the leader-serialized flat)
    --workload.backend {native|hlo} --workload.model mlp_small
    --optim.lr0 0.1 --optim.schedule {const|step|warmup}
    --net.preset {infiniband_100g|ethernet_10g}   (unknown names are
                                     rejected with the valid preset list)
    --net.bandwidth_gbps 100 --net.latency_us 2
    Legacy flat keys (--sync.p_init, --sync.qsgd_levels, ...) still load
    (deprecated).  A knob that does not belong to the chosen strategy is
    rejected with the valid key list.

CAMPAIGN (cartesian sweep; every run is a full coordinator cluster):
    --strategies  full,cpsgd,adpsgd,qsgd   (default)  strategy axis
    --collectives ring,flat                (default)  collective axis
    --sweep-nodes 4,8,16                   optional   cluster-size axis
    --bandwidths  100,10                   optional   Gbps axis (100 and 10
                                           use the paper's latency presets)
    --jobs N                               concurrent run slots
                                           (default min(cores, runs);
                                           --parallel N is a legacy alias)
    --workers {thread|subprocess|remote}   run slots in-process (default), as
                                           `adpsgd worker` children over a
                                           line-delimited JSON protocol, or
                                           remote-only on `adpsgd agent`
                                           daemons (requires --remote);
                                           crashed children are retried on
                                           another slot (--retries, default 3);
                                           children are pooled process-wide, so
                                           sequential campaigns reuse warm
                                           workers instead of respawning
    --hang-timeout SECS                    declare a subprocess worker hung
                                           after this much mid-run silence
                                           (it heartbeats every 0.5s), kill
                                           it, and retry the run on another
                                           slot (default 10)
    --cache-dir DIR                        persistent content-addressed run
                                           cache: the same fully-resolved run
                                           config (strategy knobs, seed,
                                           geometry, collective, network, and
                                           snapshot/manifest *content*) is
                                           answered from disk bit-identically
                                           with zero training; any
                                           result-affecting knob busts the key
                                           ($ADPSGD_RUN_CACHE sets a default)
    --no-cache                             ignore any default cache dir
    --cache-max-bytes N                    after the campaign, GC the run
                                           cache down to N bytes (oldest
                                           entries evicted first)
    --quick                                small base geometry (no --config)
    --out DIR                              writes <name>.campaign.json there
                                           (the *stable* summary: re-running
                                           against a warm cache is
                                           byte-identical)
    Dotted overrides patch the base config like `run`; strategy knobs
    are accepted for ANY swept strategy, e.g.
    `--strategies adpsgd,qsgd --sync.qsgd.levels 15`.
    The merged results are deterministic for any --jobs/--workers level.

REMOTE WORKERS (cross-machine campaign execution; two-machine quickstart):
    machine B (worker):  adpsgd agent --listen 0.0.0.0:7070 --slots 8 \
                             --token sesame --cache-dir /var/adpsgd-cache
    machine A (driver):  adpsgd campaign --remote b.example:7070 \
                             --remote-token sesame [--workers remote] ...
    --remote host:port[,host:port...]      lease slots on these agents; each
                                           contributes its advertised capacity
                                           to the same work-stealing queue as
                                           the local slots (mixed local+remote
                                           is the default when both are given);
                                           empty, whitespace, and duplicate
                                           entries are rejected at parse time
    --workers remote                       remote-only: no local slots
                                           (requires --remote and/or --fleet)
    --remote-token T                       shared secret for the challenge-
                                           response handshake (must match the
                                           agent's --token; never sent on the
                                           wire — only a keyed digest of the
                                           agent's nonce travels)
    Agents probe their own --cache-dir before executing, so a warm agent
    answers repeats without recomputation.  A silent or disconnected agent
    is treated exactly like a hung worker: its lease is killed and its runs
    requeue onto the surviving slots.  The merged report and the stable
    summary are byte-identical to a local run.  Version-skewed peers and
    bad tokens are rejected at the handshake with a clear error.

FLEET (elastic membership: agents come and go mid-campaign):
    registry (machine R):  adpsgd registry --listen 0.0.0.0:7000
    workers  (B, C, ...):  adpsgd agent --listen 0.0.0.0:7070 --slots 8 \
                               --token sesame --fleet r.example:7000
    driver   (machine A):  adpsgd campaign --fleet r.example:7000 \
                               --remote-token sesame [--workers remote] ...
    --fleet host:port    resolve agent membership from this registry instead
                         of (or in addition to) a static --remote list: the
                         dispatcher polls it during the campaign and adds
                         slots as members join — an agent started *after* the
                         campaign did still contributes.  Agents announce
                         under a liveness lease and re-announce, so crashed
                         members age out.  The registry is a phonebook, not a
                         broker: it holds no secrets, and authentication
                         stays end-to-end between dispatcher and agent.
    Reconnect: a dropped or restarted agent is redialed under capped
    exponential backoff with jitter; completed runs are never re-driven
    (results are merged once and the run cache memoizes), in-flight runs
    requeue like any crashed worker.  Artifact staging: a warm-start
    snapshot the agent lacks is pulled from the dispatcher by content
    digest over the run connection (blob frames), stored in the agent's
    blob store, and reused on every later run that names the same bytes.
    Cancellation: when the dispatcher abandons a run (campaign aborted,
    slot hung), it sends a cancel frame so the agent kills the orphaned
    worker child instead of letting it train to completion.

AGENT (the daemon behind --remote / --fleet):
    --listen HOST:PORT   bind address (port 0 picks a free port; the bound
                         address is printed on stdout either way)
    --slots N            advertised concurrent-run capacity (default: cores)
    --token T            require this shared secret from every client
                         (verified by challenge-response; never on the wire)
    --cache-dir DIR      agent-side run cache ($ADPSGD_RUN_CACHE if omitted;
                         probed before executing, written after); staged
                         blobs live under DIR/blobs
    --cache-max-bytes N  GC the run cache and blob store down to N bytes at
                         startup and after every client session (oldest
                         entries evicted first)
    --fleet HOST:PORT    announce this agent to a fleet registry under a
                         liveness lease (re-announced automatically)
    --advertise H:P      the dialable address to announce (defaults to the
                         bound listen address; set it when agents sit
                         behind NAT or bind 0.0.0.0)
    --hang-timeout SECS  supervision deadline for the agent's own worker
                         children (default 10)

REGISTRY (the fleet phonebook):
    --listen HOST:PORT   bind address (port 0 picks a free port; the bound
                         address is printed on stdout).  One JSON line in,
                         one out: agents announce, dispatchers list.  It
                         schedules nothing and holds no secrets.

FIGURES:
    --only fig1,fig2,fig4,fig5,fig6,fig7,fig8,table1,sec5b,ablation,robustness
                   (default: all)
    --quick        shrink every axis (seconds instead of minutes)
    --cache-dir DIR  run cache shared by every figure campaign (regenerating
                   a subset of figures reuses the others' finished runs)
    --out DIR      write the CSV series behind each panel
    Figure campaigns take the same dispatch flags as `campaign`
    (--jobs/--workers/--remote/--fleet/--remote-token/--retries/
    --hang-timeout/--no-cache): the whole figure sweep gets the same
    pool, supervision, and remote/fleet capacity.

SCENARIOS (heterogeneous clusters: the [cluster] TOML table):
    [cluster] models per-node compute skew, per-link network asymmetry,
    and a deterministic fault schedule.  Every key moves *modeled clocks
    and the communication ledger only* — for a fixed seed the trained
    parameters are bit-identical with heterogeneity on or off, so the
    run-cache digest includes every [cluster] knob but the trajectory
    never changes.  Keys (dotted CLI overrides mirror them):
    --cluster.skew {none|linear:S|straggler:F}
                         per-node compute multipliers: `linear:1.5`
                         ramps 1.0→1.5 across ranks, `straggler:4.0`
                         makes the last rank 4x slower
    --cluster.factors [1.0,1.0,2.5,...]   explicit multipliers (one per
                         node; overrides --cluster.skew)
    --cluster.step_us 1000     modeled per-step compute microseconds at
                         factor 1.0 (config-declared, never measured —
                         this keeps summaries byte-stable across hosts)
    --cluster.jitter 0.1       seeded relative per-step jitter (0..1)
    --cluster.link_bw_gbps     per-node link bandwidths (one per node;
                         collectives bottleneck on the slowest member)
    --cluster.link_latency_us  per-node link latencies
    [cluster.faults] — deterministic from (seed, nodes, iters):
    --cluster.faults.seed 0        0 = derive from the run seed
    --cluster.faults.pauses 2      node pauses (stop-the-world stalls)
    --cluster.faults.pause_secs 0.05
    --cluster.faults.spikes 2      packet-delay spikes on the network
    --cluster.faults.spike_secs 0.002
    --cluster.faults.spike_len 8   iterations each spike lasts
    Sweep examples (cluster knobs are campaign axes like any other):
        adpsgd run --cluster.skew straggler:4.0 --cluster.jitter 0.1
        adpsgd campaign --strategies cpsgd,adpsgd,dasgd \
            --cluster.skew straggler:4.0 --cluster.faults.pauses 2
    Robustness quickstart (5 strategies x 2 networks x 3 scenarios;
    writes robustness.campaign.json, byte-stable across --jobs levels
    and cold/warm cache):
        adpsgd figures --only robustness --quick --out results

PERFORMANCE:
    --perf.threads N     kernel-parallelism width for the tensor/quant hot
                         loops (0 = auto/all cores, the default; 1 = serial).
                         Reductions partition on fixed chunk boundaries and
                         fold partials in chunk order, so results are
                         bit-identical at ANY setting — like --jobs it is
                         excluded from run-cache digests and never busts a
                         cached run.  Works on `run`, `campaign`, `figures`.
    Bulk wire frames (run results, blobs) travel binary on the TCP agent
    fabric since proto v3 (control frames stay JSON; version-skewed peers
    still get the clear rebuild-both-ends error); proto v4 adds the
    challenge-response handshake, blob staging, and cancel frames.
    `cargo bench` prints serial-vs-parallel speedup columns
    (bench_tensor/bench_quant/bench_step) and JSON-vs-binary proto bytes
    per run plus fleet join/staging columns (bench_dispatch).

OBSERVABILITY (see the crate docs' Observability section):
    `campaign` writes a structured event journal next to the stable
    summary — <out>/<name>.campaign.jsonl, one JSON object per line
    ({\"schema\":1,\"ts\":\"...\",\"event\":\"run.start\",\"trace\":\"...\",...})
    covering the whole run lifecycle (campaign.start, run.queued,
    run.cache_hit, run.start, run.done/failed/crashed, cache.store,
    blob.request/blob.staged, campaign.end).  Every run gets a trace id
    minted at the driver and carried through the proto RunRequest frame
    to remote agents and their worker children, so one grep follows a
    run across machines.
    Since proto v6 the per-run coordinator events (run.start, run.sync
    with per-node barrier waits, run.eval, run.end with per-node
    clocks, ...) *stream back* from subprocess workers and remote
    agents as batched Events frames and merge into the same journal,
    tagged with an origin (\"node\" / \"agent:HOST:PORT\") — the journal
    is identically shaped whether a run executed in-process, in a
    child, or on a remote agent.  Streaming is best-effort (dropped
    batches count in the obs.event_drops metric) and never
    result-affecting: the stable <name>.campaign.json is byte-identical
    with journaling/streaming on or off.
    --no-journal         do not write the campaign event journal
    --no-stream          keep the journal but do not stream observer
                         events back from subprocess/remote executors
    Process-wide metrics (queue depth, cache hit/miss, crash requeues,
    backoff attempts, blob bytes staged, heartbeat gaps, ...) are kept
    in an in-process registry; agents snapshot theirs into the `Stats`
    reply that `adpsgd status` renders (histograms with count/sum/
    min/max and estimated p50/p95/p99).

TRACE (reconstruct run timelines from a campaign journal):
    adpsgd trace results/sweep.campaign.jsonl
    Groups journal lines per run (by trace id) and attributes each
    run's modeled_wall_secs into per-node compute / barrier-wait / comm
    buckets from the streamed run.sync + run.end events, with the
    critical path and a per-node straggler count (which node arrived at
    each barrier last).  Runs that executed without streamed events
    fall back to the dispatch summary line (wall clock only).
    --json               machine-readable report
    --emit-cluster       harvest the observed per-node skew as a
                         paste-ready [cluster] config block, validated
                         against the config parser before printing:
        adpsgd trace results/sweep.campaign.jsonl --emit-cluster
          [cluster]
          factors = [1.0000, 1.1873, 2.9941, 1.0438]
        append it to a config file (or pass --cluster.factors ...) and
        the next campaign replays the measured heterogeneity.

STATUS (live fleet/agent view):
    adpsgd status --fleet r.example:7000 --remote-token sesame
    --fleet HOST:PORT    list registry membership first (address, slots,
                         remaining lease age), then query every member
    --remote H:P[,...]   query these agents (in addition to any fleet
                         members) for slots, in-flight runs, runs
                         served, cache hit-rate, and metrics
    --remote-token T     shared secret, as for campaign --remote
    --timeout-secs S     per-agent dial/reply deadline (default 5)
    --json               machine-readable: fleet members plus each
                         agent's raw stats/metrics snapshot
    An unreachable agent is reported and skipped; status itself only
    fails when no agent could be queried at all.

CACHE-GC (bound a long-lived run-cache directory):
    --cache-dir DIR      directory to collect ($ADPSGD_RUN_CACHE if omitted)
    --max-bytes N        evict oldest entries until the total fits N bytes
    --max-age-secs S     evict entries older than S seconds
    --tmp-grace-secs S   sweep orphaned .tmp files older than S (default 900)
    --dry-run            print what would be evicted (paths, bytes, ages)
                         without deleting anything
    Eviction is always safe: an evicted key is recomputed on its next probe.
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse_env(&[
        "quick",
        "quiet",
        "json",
        "series",
        "no-cache",
        "dry-run",
        "no-journal",
        "no-stream",
        "emit-cluster",
    ])?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("figures") => cmd_figures(&args),
        Some("cache-gc") => cmd_cache_gc(&args),
        Some("models") => cmd_models(&args),
        // the dispatcher's subprocess end: serve run requests over
        // stdin/stdout until EOF
        Some("worker") => {
            adpsgd::dispatch::proto::serve(std::io::stdin().lock(), std::io::stdout())
        }
        // the remote end of `--remote`: serve campaign runs over TCP
        Some("agent") => cmd_agent(&args),
        // the fleet phonebook: agents announce, dispatchers list
        Some("registry") => cmd_registry(&args),
        // live fleet/agent view: membership, leases, in-flight runs
        Some("status") => cmd_status(&args),
        // timeline analysis of a written campaign journal
        Some("trace") => cmd_trace(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} (try `adpsgd help`)"),
    }
}

/// Top-level config keys accepted without a dot by `run`/`campaign`.
const SHORTCUT_KEYS: [&str; 7] =
    ["name", "seed", "nodes", "iters", "batch_per_node", "eval_every", "variance_every"];

/// Collect dotted overrides plus the common top-level keys.
fn cli_overrides(args: &Args) -> Vec<(String, String)> {
    let mut overrides = args.config_overrides();
    for k in SHORTCUT_KEYS {
        if let Some(v) = args.get(k) {
            overrides.push((k.to_string(), v.to_string()));
        }
    }
    overrides
}

/// Reject misspelled dotless options (`--bandwidth` for `--bandwidths`)
/// instead of silently ignoring them — dotted keys are validated
/// separately against the config schema.
fn reject_unknown_options(args: &Args, extra: &[&str]) -> Result<()> {
    for key in args.options.keys() {
        if key.contains('.') {
            continue;
        }
        if !extra.contains(&key.as_str()) && !SHORTCUT_KEYS.contains(&key.as_str()) {
            let mut valid: Vec<&str> = extra.to_vec();
            valid.extend(SHORTCUT_KEYS);
            bail!("unknown option --{key} (valid options: --{})", valid.join(", --"));
        }
    }
    Ok(())
}

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let overrides = cli_overrides(args);
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path, &overrides),
        None => ExperimentConfig::from_overrides(&overrides),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    reject_unknown_options(args, &["config", "out"])?;
    let cfg = build_config(args)?;
    let json_out = args.flag("json");
    if !json_out {
        println!(
            "run: {} | {} nodes × {} iters | strategy {} | backend {:?}",
            cfg.name, cfg.nodes, cfg.iters, cfg.sync.strategy, cfg.workload.backend
        );
    }
    let report = Experiment::from_config(cfg)?.run().context("training run failed")?;
    if json_out {
        println!("{}", report.to_json(args.flag("series")).to_string_compact());
    } else {
        println!("{}", report.one_line());
        println!("--- communication ledger ---\n{}", report.ledger.summary());
    }
    if let Some(dir) = args.get("out") {
        let files = report.recorder.write_csvs(std::path::Path::new(dir), &report.name)?;
        if !json_out {
            println!("wrote {} series to {dir}/", files.len());
        }
    }
    Ok(())
}

/// A small base geometry for `campaign --quick` (no --config): the
/// quartet finishes in seconds.
fn quick_campaign_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "campaign_quick".into();
    cfg.nodes = 4;
    cfg.iters = 160;
    cfg.batch_per_node = 16;
    cfg.eval_every = 40;
    cfg.workload.input_dim = 48;
    cfg.workload.hidden = 24;
    cfg.workload.eval_batches = 4;
    cfg.optim.schedule =
        adpsgd::config::LrSchedule::StepDecay { boundaries: vec![80, 120], factor: 0.1 };
    cfg.sync.warmup_iters = 4;
    cfg.sync.p_init = 2;
    cfg
}

fn csv_list(args: &Args, key: &str) -> Option<Vec<String>> {
    args.get(key).map(|s| {
        s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
    })
}

/// Dispatch profile from the campaign/figures flags: `--jobs` (with the
/// legacy `--parallel` alias), `--workers`, `--remote`/`--fleet`/
/// `--remote-token`, `--cache-dir`/`--no-cache`, `--retries`,
/// `--hang-timeout`.
fn dispatch_options(args: &Args) -> Result<DispatchOptions> {
    let mut opts = DispatchOptions::default();
    opts.jobs = match (args.get("jobs"), args.get("parallel")) {
        (Some(j), _) => Some(j.parse::<usize>().context("--jobs")?),
        (None, Some(p)) => Some(p.parse::<usize>().context("--parallel")?),
        (None, None) => None, // min(cores, runs)
    };
    opts.workers = match args.get_or("workers", "thread") {
        "thread" => WorkerKind::Thread,
        "subprocess" => WorkerKind::Subprocess,
        "remote" => WorkerKind::Remote,
        other => bail!("--workers must be thread|subprocess|remote, got {other:?}"),
    };
    if let Some(endpoints) = args.get("remote") {
        // keep empty entries: validate_endpoints rejects them with the
        // exact position instead of silently dropping a typo like
        // "a:7070,,b:7070"
        opts.remote = endpoints.split(',').map(|a| a.trim().to_string()).collect();
        adpsgd::dispatch::fleet::validate_endpoints(&opts.remote)?;
    }
    opts.fleet = args.get("fleet").map(String::from);
    opts.remote_token = args.get("remote-token").map(String::from);
    if matches!(opts.workers, WorkerKind::Remote) && opts.remote.is_empty() && opts.fleet.is_none()
    {
        bail!(
            "--workers remote needs at least one agent \
             (--remote host:port[,host:port...] and/or --fleet host:port)"
        );
    }
    if args.flag("no-cache") {
        opts.cache_dir = None;
    } else if let Some(dir) = args.get("cache-dir") {
        opts.cache_dir = Some(dir.into());
    }
    opts.max_attempts = args.get_usize("retries", opts.max_attempts)?.max(1);
    if let Some(secs) = args.get("hang-timeout") {
        let secs: f64 = secs.parse().context("--hang-timeout")?;
        // the upper bound keeps Duration::from_secs_f64 from panicking
        // on absurd-but-finite values
        if !secs.is_finite() || secs <= 0.0 || secs > 86_400.0 * 365.0 {
            bail!("--hang-timeout must be a positive number of seconds (≤ 1 year), got {secs}");
        }
        opts.heartbeat_timeout = std::time::Duration::from_secs_f64(secs);
    }
    Ok(opts)
}

fn cmd_campaign(args: &Args) -> Result<()> {
    reject_unknown_options(
        args,
        &[
            "config",
            "out",
            "strategies",
            "sweep-nodes",
            "bandwidths",
            "collectives",
            "parallel",
            "jobs",
            "workers",
            "remote",
            "fleet",
            "remote-token",
            "cache-dir",
            "retries",
            "hang-timeout",
            "cache-max-bytes",
        ],
    )?;
    let overrides = cli_overrides(args);
    let strategy_names = csv_list(args, "strategies")
        .unwrap_or_else(|| vec!["full".into(), "cpsgd".into(), "adpsgd".into(), "qsgd".into()]);
    let mut kinds: Vec<Strategy> = Vec::new();
    for s in &strategy_names {
        kinds.push(s.parse()?);
    }

    // load the base leniently, then validate strategy-knob overrides
    // against the whole *swept* set — `--sync.qsgd.levels 15` is valid
    // whenever qsgd is being swept, regardless of the base's strategy
    let base = match args.get("config") {
        Some(path) => ExperimentConfig::from_file_lenient(path, &overrides)?,
        None => {
            let mut b =
                if args.flag("quick") { quick_campaign_base() } else { ExperimentConfig::default() };
            b.apply_overrides_lenient(&overrides)?;
            b
        }
    };
    let mut checked = kinds.clone();
    if !checked.contains(&base.sync.strategy) {
        checked.push(base.sync.strategy);
    }
    ExperimentConfig::check_override_keys(&checked, &overrides)?;

    let name = args.get_or("name", "campaign").to_string();
    let mut builder = Campaign::builder(name.clone(), base.clone());
    let specs: Vec<(String, StrategySpec)> = strategy_names
        .iter()
        .zip(&kinds)
        .map(|(s, kind)| (s.clone(), base.sync.spec_of(*kind)))
        .collect();
    builder = builder.strategies(specs);

    if let Some(nodes) = csv_list(args, "sweep-nodes") {
        let ns: Vec<usize> = nodes
            .iter()
            .map(|n| n.parse().with_context(|| format!("--sweep-nodes entry {n:?}")))
            .collect::<Result<_>>()?;
        builder = builder.nodes(&ns);
    }

    if let Some(bands) = csv_list(args, "bandwidths") {
        for b in &bands {
            let gbps: f64 = b.parse().with_context(|| format!("--bandwidths entry {b:?}"))?;
            // the paper's presets carry their own latencies; other rates
            // keep the base latency
            let net = if (gbps - 100.0).abs() < 1e-9 {
                NetConfig::infiniband_100g()
            } else if (gbps - 10.0).abs() < 1e-9 {
                NetConfig::ethernet_10g()
            } else {
                NetConfig { bandwidth_gbps: gbps, latency_us: base.net.latency_us }
            };
            // label with the exact rate (Display round-trips f64, so
            // distinct rates always get distinct labels; the builder
            // additionally rejects duplicate labels)
            builder = builder.net(format!("{gbps}g"), net);
        }
    }

    let collective_names =
        csv_list(args, "collectives").unwrap_or_else(|| vec!["ring".into(), "flat".into()]);
    let algos: Vec<Algo> =
        collective_names.iter().map(|c| c.parse()).collect::<Result<_>>()?;
    builder = builder.collectives(&algos);

    let mut opts = dispatch_options(args)?;
    // validate the post-campaign GC request up front: a bad flag must
    // fail *before* hours of sweep, not after
    let cache_max_bytes: Option<u64> = match args.get("cache-max-bytes") {
        Some(max) => {
            let max = max.parse().context("--cache-max-bytes")?;
            if opts.cache_dir.is_none() {
                bail!("--cache-max-bytes needs a run cache (--cache-dir or $ADPSGD_RUN_CACHE)");
            }
            Some(max)
        }
        None => None,
    };
    let campaign = builder.build()?;

    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    // the event journal rides next to the stable summary; it is a pure
    // observer, so the summary stays byte-identical with or without it
    if !args.flag("no-journal") {
        let jpath = out_dir.join(format!("{name}.campaign.jsonl"));
        opts.journal = Some(
            adpsgd::obs::Journal::create(&jpath)
                .with_context(|| format!("creating event journal {}", jpath.display()))?,
        );
    }
    opts.stream_events = !args.flag("no-stream");

    let json_out = args.flag("json");
    if !json_out {
        let jobs = opts
            .jobs
            .map(|j| j.to_string())
            .unwrap_or_else(|| "min(cores, runs)".into());
        println!(
            "campaign {name}: {} runs ({} strategies × axes), jobs={jobs}, workers={:?}{}{}",
            campaign.len(),
            strategy_names.len(),
            opts.workers,
            if opts.remote.is_empty() {
                String::new()
            } else {
                format!(", remote=[{}]", opts.remote.join(", "))
            },
            opts.cache_dir
                .as_ref()
                .map(|d| format!(", cache={}", d.display()))
                .unwrap_or_default(),
        );
    }
    let report = campaign.execute(&opts).context("campaign failed")?;

    if json_out {
        println!("{}", report.to_json().to_string_compact());
    } else {
        println!("{}", report.table().render());
        println!(
            "campaign {name}: {} runs in {} ({:.2} runs/sec, {} cache hits), total modeled comm {}",
            report.runs.len(),
            adpsgd::util::fmt::secs(report.wall_secs),
            report.runs_per_sec(),
            report.cache_hits(),
            adpsgd::util::fmt::secs(report.total_modeled_comm_secs()),
        );
    }

    let path = out_dir.join(format!("{name}.campaign.json"));
    // the stable summary: byte-identical when re-run against a warm cache
    std::fs::write(&path, report.to_json_stable().to_string_compact())
        .with_context(|| format!("writing {}", path.display()))?;
    if !json_out {
        println!("wrote {}", path.display());
    }

    if let Some(max) = cache_max_bytes {
        let dir = opts.cache_dir.as_ref().expect("validated before the campaign ran");
        let stats = adpsgd::dispatch::RunCache::new(dir)
            .gc(&adpsgd::dispatch::GcPolicy { max_bytes: Some(max), ..Default::default() })
            .with_context(|| format!("collecting run cache {}", dir.display()))?;
        if !json_out {
            println!("{}", gc_summary(dir, &stats));
        }
    }
    Ok(())
}

fn gc_summary(dir: &std::path::Path, stats: &adpsgd::dispatch::GcStats) -> String {
    format!(
        "cache-gc {}: {} entries scanned, {} evicted ({}), {} kept ({}), {} orphaned tmp swept",
        dir.display(),
        stats.scanned,
        stats.evicted,
        adpsgd::util::fmt::bytes(stats.evicted_bytes),
        stats.kept,
        adpsgd::util::fmt::bytes(stats.kept_bytes),
        stats.tmp_swept,
    )
}

/// `adpsgd cache-gc`: bound a long-lived run-cache directory by size
/// and/or age, and sweep orphaned temp files.  `--dry-run` prints the
/// exact victims (paths, bytes, ages) without deleting anything.
fn cmd_cache_gc(args: &Args) -> Result<()> {
    reject_unknown_options(
        args,
        &["cache-dir", "max-bytes", "max-age-secs", "tmp-grace-secs"],
    )?;
    let dir = args
        .get("cache-dir")
        .map(std::path::PathBuf::from)
        .or_else(dispatch::default_cache_dir)
        .ok_or_else(|| {
            anyhow::anyhow!("no cache directory (pass --cache-dir or set $ADPSGD_RUN_CACHE)")
        })?;
    let mut policy = adpsgd::dispatch::GcPolicy::default();
    if let Some(b) = args.get("max-bytes") {
        policy.max_bytes = Some(b.parse().context("--max-bytes")?);
    }
    if let Some(s) = args.get("max-age-secs") {
        policy.max_age = Some(std::time::Duration::from_secs(s.parse().context("--max-age-secs")?));
    }
    if let Some(s) = args.get("tmp-grace-secs") {
        policy.tmp_grace = std::time::Duration::from_secs(s.parse().context("--tmp-grace-secs")?);
    }
    let cache = adpsgd::dispatch::RunCache::new(&dir);
    if args.flag("dry-run") {
        let plan = cache
            .gc_plan(&policy)
            .with_context(|| format!("planning gc of run cache {}", dir.display()))?;
        for v in &plan.evict {
            println!(
                "would evict {}  ({}, age {:.0}s)",
                v.path.display(),
                adpsgd::util::fmt::bytes(v.bytes),
                v.age.as_secs_f64()
            );
        }
        for v in &plan.tmp_sweep {
            println!(
                "would sweep {}  ({}, age {:.0}s)",
                v.path.display(),
                adpsgd::util::fmt::bytes(v.bytes),
                v.age.as_secs_f64()
            );
        }
        println!(
            "cache-gc {} (dry run): {} entries scanned, {} would be evicted ({}), \
             {} kept ({}), {} orphaned tmp would be swept",
            dir.display(),
            plan.scanned,
            plan.evict.len(),
            adpsgd::util::fmt::bytes(plan.evicted_bytes()),
            plan.kept,
            adpsgd::util::fmt::bytes(plan.kept_bytes),
            plan.tmp_sweep.len(),
        );
        return Ok(());
    }
    let stats = cache
        .gc(&policy)
        .with_context(|| format!("collecting run cache {}", dir.display()))?;
    println!("{}", gc_summary(&dir, &stats));
    Ok(())
}

/// `adpsgd agent`: serve campaign runs over TCP for `--remote`
/// dispatchers (the remote end of the worker fabric; see HELP).
fn cmd_agent(args: &Args) -> Result<()> {
    reject_unknown_options(
        args,
        &[
            "listen",
            "slots",
            "token",
            "cache-dir",
            "cache-max-bytes",
            "fleet",
            "advertise",
            "hang-timeout",
        ],
    )?;
    let listen = args.get("listen").ok_or_else(|| {
        anyhow::anyhow!("agent needs --listen HOST:PORT (e.g. --listen 0.0.0.0:7070)")
    })?;
    let cores = std::thread::available_parallelism().map(usize::from).unwrap_or(2);
    let mut cfg = adpsgd::dispatch::AgentConfig {
        listen: listen.to_string(),
        slots: args.get_usize("slots", cores)?.max(1),
        token: args.get("token").map(String::from),
        // $ADPSGD_RUN_CACHE gives a warm agent its cache by default
        cache_dir: args.get("cache-dir").map(Into::into).or_else(dispatch::default_cache_dir),
        cache_max_bytes: match args.get("cache-max-bytes") {
            Some(max) => Some(max.parse().context("--cache-max-bytes")?),
            None => None,
        },
        fleet: args.get("fleet").map(String::from),
        advertise: args.get("advertise").map(String::from),
        worker_exe: None, // this binary has the `worker` subcommand
        ..adpsgd::dispatch::AgentConfig::default()
    };
    if let Some(secs) = args.get("hang-timeout") {
        let secs: f64 = secs.parse().context("--hang-timeout")?;
        if !secs.is_finite() || secs <= 0.0 || secs > 86_400.0 * 365.0 {
            bail!("--hang-timeout must be a positive number of seconds (≤ 1 year), got {secs}");
        }
        cfg.heartbeat_timeout = std::time::Duration::from_secs_f64(secs);
    }
    adpsgd::dispatch::Agent::bind(cfg)?.serve()
}

/// `adpsgd registry`: the fleet phonebook — agents announce themselves
/// under a liveness lease, dispatchers resolve the member set (see HELP
/// FLEET).  It schedules nothing and holds no secrets.
fn cmd_registry(args: &Args) -> Result<()> {
    reject_unknown_options(args, &["listen"])?;
    let listen = args.get("listen").ok_or_else(|| {
        anyhow::anyhow!("registry needs --listen HOST:PORT (e.g. --listen 0.0.0.0:7000)")
    })?;
    adpsgd::dispatch::Registry::bind(listen)?.serve()
}

/// `adpsgd status`: the live fleet/agent view.  Lists `--fleet`
/// registry membership (address, advertised slots, remaining lease),
/// then queries every member plus any static `--remote` agents over
/// the proto `Stats` frame for slots, in-flight runs, runs served,
/// cache hit-rate, and (with `--json`) the agent's full metrics
/// snapshot.  Unreachable agents are reported and skipped; the command
/// only fails when no agent could be queried at all.
fn cmd_status(args: &Args) -> Result<()> {
    use adpsgd::util::json::Json;
    reject_unknown_options(args, &["fleet", "remote", "remote-token", "timeout-secs"])?;
    let secs = args.get_f64("timeout-secs", 5.0).context("--timeout-secs")?;
    if !secs.is_finite() || secs <= 0.0 || secs > 86_400.0 {
        bail!("--timeout-secs must be a positive number of seconds (≤ 1 day), got {secs}");
    }
    let timeout = std::time::Duration::from_secs_f64(secs);
    let token = args.get("remote-token");
    let json_out = args.flag("json");

    let mut endpoints: Vec<String> = Vec::new();
    if let Some(list) = args.get("remote") {
        endpoints = list.split(',').map(|a| a.trim().to_string()).collect();
        adpsgd::dispatch::fleet::validate_endpoints(&endpoints)?;
    }
    let mut fleet_members: Vec<Json> = Vec::new();
    if let Some(registry) = args.get("fleet") {
        let members = adpsgd::dispatch::fleet::registry::members(registry)
            .with_context(|| format!("listing fleet registry {registry}"))?;
        if !json_out {
            println!("fleet {registry}: {} member(s)", members.len());
            for m in &members {
                println!(
                    "  {}  slots {}  lease {:.1}s",
                    m.addr,
                    m.slots,
                    m.lease_ms as f64 / 1e3
                );
            }
        }
        for m in &members {
            fleet_members.push(Json::obj(vec![
                ("addr", Json::str(m.addr.clone())),
                ("slots", Json::num(m.slots as f64)),
                ("lease_ms", Json::num(m.lease_ms as f64)),
            ]));
            if !endpoints.contains(&m.addr) {
                endpoints.push(m.addr.clone());
            }
        }
    }
    if endpoints.is_empty() {
        bail!(
            "status needs at least one agent \
             (--remote host:port[,host:port...] and/or --fleet host:port)"
        );
    }

    let mut agents: Vec<Json> = Vec::new();
    let mut reached = 0usize;
    for addr in &endpoints {
        let stats = adpsgd::dispatch::RemoteAgentClient::connect(addr, token, timeout)
            .and_then(|client| client.stats(timeout));
        match stats {
            Ok(stats) => {
                reached += 1;
                if !json_out {
                    let f = |k: &str| stats.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                    let (served, hits) = (f("served"), f("cache_hits"));
                    let rate = if served > 0.0 { 100.0 * hits / served } else { 0.0 };
                    println!(
                        "agent {addr}: slots {}, in-flight {}, served {}, \
                         cache hits {} ({rate:.0}%)",
                        f("slots"),
                        f("in_flight"),
                        served,
                        hits,
                    );
                    print_agent_metrics(&stats);
                }
                agents.push(Json::obj(vec![
                    ("addr", Json::str(addr.clone())),
                    ("stats", stats),
                ]));
            }
            Err(e) => {
                if !json_out {
                    println!("agent {addr}: unreachable ({e:#})");
                }
                agents.push(Json::obj(vec![
                    ("addr", Json::str(addr.clone())),
                    ("error", Json::str(format!("{e:#}"))),
                ]));
            }
        }
    }
    if json_out {
        let out = Json::obj(vec![
            ("fleet", Json::Arr(fleet_members)),
            ("agents", Json::Arr(agents)),
        ]);
        println!("{}", out.to_string_compact());
    }
    if reached == 0 {
        bail!("no agent answered a status query ({} tried)", endpoints.len());
    }
    Ok(())
}

/// Human rendering of an agent's metrics snapshot: byte-valued
/// counters/gauges humanized via [`adpsgd::util::fmt::bytes`], and each
/// non-empty histogram summarized as mean plus the estimated
/// p50/p95/p99.
fn print_agent_metrics(stats: &adpsgd::util::json::Json) {
    use adpsgd::util::json::Json;
    let Some(metrics) = stats.get("metrics") else { return };
    for kind in ["counters", "gauges"] {
        let Some(map) = metrics.get(kind).and_then(Json::as_obj) else { continue };
        for (name, v) in map {
            let Some(v) = v.as_f64() else { continue };
            if v == 0.0 {
                continue;
            }
            // byte-valued metrics are named *_bytes_* by convention
            if name.contains("bytes") {
                println!("  {name} = {}", adpsgd::util::fmt::bytes(v as u64));
            } else {
                println!("  {name} = {v}");
            }
        }
    }
    let Some(histos) = metrics.get("histograms").and_then(Json::as_obj) else { return };
    for (name, h) in histos {
        let f = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let count = f("count");
        if count == 0.0 {
            continue;
        }
        println!(
            "  {name}: n={count} mean={:.3} p50={:.3} p95={:.3} p99={:.3}",
            f("sum") / count,
            f("p50"),
            f("p95"),
            f("p99"),
        );
    }
}

/// `adpsgd trace`: reconstruct per-run timelines from a campaign event
/// journal (see the TRACE section of HELP).
fn cmd_trace(args: &Args) -> Result<()> {
    reject_unknown_options(args, &[])?;
    let [path] = args.positional.as_slice() else {
        bail!(
            "trace expects exactly one journal path: \
             adpsgd trace <out>/<name>.campaign.jsonl"
        );
    };
    let report = adpsgd::obs::trace::analyze_file(std::path::Path::new(path))?;
    if args.flag("emit-cluster") {
        print!("{}", report.emit_cluster()?);
    } else if args.flag("json") {
        println!("{}", report.to_json().to_string_compact());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    reject_unknown_options(
        args,
        &[
            "only",
            "out",
            "cache-dir",
            "jobs",
            "parallel",
            "workers",
            "remote",
            "fleet",
            "remote-token",
            "retries",
            "hang-timeout",
        ],
    )?;
    // every figure campaign goes through Campaign::run, which consults
    // the process-default dispatch profile — one flag group gives all
    // six figure sweeps the same pool/supervision/remote treatment as
    // `adpsgd campaign` (an unset --jobs keeps each campaign's own
    // parallelism)
    let opts = dispatch_options(args)?;
    dispatch::set_default_cache_dir(opts.cache_dir.clone());
    dispatch::set_default_options(Some(opts));
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), args.flag("quiet"));
    let only: Vec<String> = args
        .get("only")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_default();
    let want = |name: &str| only.is_empty() || only.iter().any(|o| o == name);

    if want("fig1") {
        figures::variance::fig1(scale, &sink)?;
    }
    if want("fig2") || want("fig3") {
        figures::variance::fig2_fig3(scale, &sink)?;
    }
    for (key, role) in [
        ("fig4", figures::convergence::Role::GoogLeNet),
        ("fig5", figures::convergence::Role::Vgg16),
        ("fig7", figures::convergence::Role::ResNet50),
        ("fig8", figures::convergence::Role::AlexNet),
    ] {
        if want(key) {
            let conv = figures::convergence::convergence(role, scale, &sink)?;
            figures::convergence::time_split(&conv, &sink);
        }
    }
    if want("fig6") {
        let mut g = figures::cifar_base(scale);
        figures::googlenet_role(&mut g, scale);
        figures::speedup::fig6("googlenet-role", &g, scale, &sink)?;
        let mut v = figures::cifar_base(scale);
        figures::vgg_role(&mut v, scale);
        figures::speedup::fig6("vgg-role", &v, scale, &sink)?;
    }
    if want("table1") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::table1::table1(&base, scale, &sink)?;
    }
    if want("sec5b") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::decreasing::decreasing_study(&base, &sink)?;
    }
    if want("ablation") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::ablation::ablation(&base, scale, &sink)?;
    }
    if want("robustness") {
        let base = figures::cifar_base(scale);
        figures::robustness::robustness(&base, scale, &sink)?;
    }
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    reject_unknown_options(args, &["artifacts"])?;
    let dir = args.get_or("artifacts", "artifacts");
    let man = adpsgd::runtime::Manifest::load(dir)?;
    println!("{:<12} {:>10} {:>8} {:>6} kind", "model", "params", "batch", "files");
    for (name, spec) in &man.models {
        println!(
            "{:<12} {:>10} {:>8} {:>6} {}",
            name,
            spec.param_count,
            spec.batch,
            spec.files.len(),
            spec.kind
        );
    }
    Ok(())
}
