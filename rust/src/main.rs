//! `adpsgd` — the launcher.
//!
//! ```text
//! adpsgd run      [--config exp.toml] [--sync.strategy=adpsgd] [--nodes 16] ...
//! adpsgd figures  [--only fig1,fig4,...] [--quick] [--out results]
//! adpsgd models   [--artifacts artifacts]
//! adpsgd help
//! ```
//!
//! `run` executes one experiment described by a TOML config plus dotted
//! CLI overrides; `figures` regenerates every paper table/figure (see
//! DESIGN.md §4); `models` lists the AOT artifacts the PJRT runtime can
//! load.

use adpsgd::cli::Args;
use adpsgd::config::ExperimentConfig;
use adpsgd::coordinator::Trainer;
use adpsgd::figures::{self, Scale, Sink};
use anyhow::{bail, Context, Result};

const HELP: &str = "\
adpsgd — Adaptive Periodic Parameter Averaging SGD (Jiang & Agrawal 2020)

USAGE:
    adpsgd run     [--config FILE] [--out DIR] [--json [--series]]
                   [--key.subkey=value ...]
    adpsgd figures [--only LIST] [--quick] [--out DIR]
    adpsgd models  [--artifacts DIR]
    adpsgd help

RUN OVERRIDES (dotted keys mirror the TOML schema):
    --nodes 16 --iters 4000 --batch_per_node 128 --seed 42
    --sync.strategy {full|cpsgd|adpsgd|decreasing|qsgd|piecewise|easgd|topk}
    --sync.period 8 --sync.p_init 4 --sync.ks_frac 0.25
    --sync.collective {ring|flat}   (allreduce algorithm: chunked-parallel
                                     ring, or the leader-serialized flat)
    --workload.backend {native|hlo} --workload.model mlp_small
    --optim.lr0 0.1 --optim.schedule {const|step|warmup}
    --net.bandwidth_gbps 100 --net.latency_us 2

FIGURES:
    --only fig1,fig2,fig4,fig5,fig6,fig7,fig8,table1,sec5b,ablation  (default: all)
    --quick        shrink every axis (seconds instead of minutes)
    --out DIR      write the CSV series behind each panel
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse_env(&["quick", "quiet", "json", "series"])?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("figures") => cmd_figures(&args),
        Some("models") => cmd_models(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} (try `adpsgd help`)"),
    }
}

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let mut overrides = args.config_overrides();
    // allow the common top-level keys without a dot, too
    for k in ["name", "seed", "nodes", "iters", "batch_per_node", "eval_every", "variance_every"] {
        if let Some(v) = args.get(k) {
            overrides.push((k.to_string(), v.to_string()));
        }
    }
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path, &overrides),
        None => {
            // synthesize a TOML document from the overrides alone
            let text = String::new();
            let mut doc = adpsgd::config::toml::TomlDoc::parse(&text)
                .map_err(|e| anyhow::anyhow!("internal: {e}"))?;
            for (k, v) in &overrides {
                let val = adpsgd::config::toml::TomlDoc::parse(&format!("x = {v}"))
                    .ok()
                    .and_then(|d| d.get("x").cloned())
                    .unwrap_or(adpsgd::config::toml::TomlValue::Str(v.clone()));
                doc.entries.insert(k.clone(), val);
            }
            ExperimentConfig::from_doc(&doc)
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let json_out = args.flag("json");
    if !json_out {
        println!(
            "run: {} | {} nodes × {} iters | strategy {} | backend {:?}",
            cfg.name, cfg.nodes, cfg.iters, cfg.sync.strategy, cfg.workload.backend
        );
    }
    let report = Trainer::new(cfg)?.run().context("training run failed")?;
    if json_out {
        println!("{}", report.to_json(args.flag("series")).to_string_compact());
    } else {
        println!("{}", report.one_line());
        println!("--- communication ledger ---\n{}", report.ledger.summary());
    }
    if let Some(dir) = args.get("out") {
        let files = report.recorder.write_csvs(std::path::Path::new(dir), &report.name)?;
        if !json_out {
            println!("wrote {} series to {dir}/", files.len());
        }
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let scale = Scale::from_flag(args.flag("quick"));
    let sink = Sink::new(args.get("out"), args.flag("quiet"));
    let only: Vec<String> = args
        .get("only")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_default();
    let want = |name: &str| only.is_empty() || only.iter().any(|o| o == name);

    if want("fig1") {
        figures::variance::fig1(scale, &sink)?;
    }
    if want("fig2") || want("fig3") {
        figures::variance::fig2_fig3(scale, &sink)?;
    }
    for (key, role) in [
        ("fig4", figures::convergence::Role::GoogLeNet),
        ("fig5", figures::convergence::Role::Vgg16),
        ("fig7", figures::convergence::Role::ResNet50),
        ("fig8", figures::convergence::Role::AlexNet),
    ] {
        if want(key) {
            let conv = figures::convergence::convergence(role, scale, &sink)?;
            figures::convergence::time_split(&conv, &sink);
        }
    }
    if want("fig6") {
        let mut g = figures::cifar_base(scale);
        figures::googlenet_role(&mut g, scale);
        figures::speedup::fig6("googlenet-role", &g, scale, &sink)?;
        let mut v = figures::cifar_base(scale);
        figures::vgg_role(&mut v, scale);
        figures::speedup::fig6("vgg-role", &v, scale, &sink)?;
    }
    if want("table1") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::table1::table1(&base, scale, &sink)?;
    }
    if want("sec5b") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::decreasing::decreasing_study(&base, &sink)?;
    }
    if want("ablation") {
        let mut base = figures::cifar_base(scale);
        figures::googlenet_role(&mut base, scale);
        figures::ablation::ablation(&base, scale, &sink)?;
    }
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let man = adpsgd::runtime::Manifest::load(dir)?;
    println!("{:<12} {:>10} {:>8} {:>6} kind", "model", "params", "batch", "files");
    for (name, spec) in &man.models {
        println!(
            "{:<12} {:>10} {:>8} {:>6} {}",
            name,
            spec.param_count,
            spec.batch,
            spec.files.len(),
            spec.kind
        );
    }
    Ok(())
}
