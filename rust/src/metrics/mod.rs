//! Run metrics: named time series + CSV/summary emission.
//!
//! Every curve in the paper's figures is a `Series` here; the figure
//! harness writes them as CSV under `results/` and prints the rows the
//! paper reports.

pub mod plot;

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One named (x, y) series, e.g. ("train_loss", iter -> loss).
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn last_y(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn min_y(&self) -> Option<f64> {
        self.points.iter().map(|p| p.1).min_by(|a, b| a.total_cmp(b))
    }

    pub fn max_y(&self) -> Option<f64> {
        self.points.iter().map(|p| p.1).max_by(|a, b| a.total_cmp(b))
    }

    /// Mean of y over points with x in [x0, x1).
    pub fn mean_y_in(&self, x0: f64, x1: f64) -> Option<f64> {
        let pts: Vec<f64> =
            self.points.iter().filter(|p| p.0 >= x0 && p.0 < x1).map(|p| p.1).collect();
        if pts.is_empty() {
            None
        } else {
            Some(pts.iter().sum::<f64>() / pts.len() as f64)
        }
    }

    /// Tail mean (last `k` points) — a stable "final loss" readout.
    pub fn tail_mean(&self, k: usize) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let n = self.points.len();
        let s = n.saturating_sub(k);
        let pts = &self.points[s..];
        Some(pts.iter().map(|p| p.1).sum::<f64>() / pts.len() as f64)
    }
}

/// A bag of series, keyed by name.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, x: f64, y: f64) {
        self.series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(name))
            .push(x, y);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Write one CSV per series: `<dir>/<prefix>.<series>.csv` with
    /// header `x,y`.
    pub fn write_csvs(&self, dir: &Path, prefix: &str) -> Result<Vec<std::path::PathBuf>> {
        std::fs::create_dir_all(dir)?;
        let mut written = Vec::new();
        for (name, s) in &self.series {
            let path = dir.join(format!("{prefix}.{name}.csv"));
            let mut f = std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?;
            writeln!(f, "x,y")?;
            for (x, y) in &s.points {
                writeln!(f, "{x},{y}")?;
            }
            written.push(path);
        }
        Ok(written)
    }

    /// Merge another recorder's series under a name prefix (for
    /// multi-run figure assembly: "adpsgd.train_loss" etc.).
    pub fn merge_prefixed(&mut self, prefix: &str, other: &Recorder) {
        for (name, s) in &other.series {
            let full = format!("{prefix}.{name}");
            let entry =
                self.series.entry(full.clone()).or_insert_with(|| Series::new(full.clone()));
            entry.points.extend_from_slice(&s.points);
        }
    }
}

/// Simple aligned-table printer for figure/bench output.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::new("loss");
        for i in 0..10 {
            s.push(i as f64, (10 - i) as f64);
        }
        assert_eq!(s.last_y(), Some(1.0));
        assert_eq!(s.min_y(), Some(1.0));
        assert_eq!(s.max_y(), Some(10.0));
        assert_eq!(s.mean_y_in(0.0, 2.0), Some(9.5));
        assert_eq!(s.tail_mean(2), Some(1.5));
    }

    #[test]
    fn recorder_csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("adpsgd_test_{}", std::process::id()));
        let mut r = Recorder::new();
        r.push("a", 0.0, 1.0);
        r.push("a", 1.0, 2.0);
        r.push("b", 0.0, -1.0);
        let files = r.write_csvs(&dir, "run1").unwrap();
        assert_eq!(files.len(), 2);
        let text = std::fs::read_to_string(&files[0]).unwrap();
        assert!(text.starts_with("x,y\n"));
        assert!(text.contains("1,2"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_prefixed_namespaces() {
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        b.push("loss", 0.0, 3.0);
        a.merge_prefixed("adpsgd", &b);
        assert!(a.get("adpsgd.loss").is_some());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["x".into(), "1.0".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }
}
