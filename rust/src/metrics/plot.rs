//! Terminal line plots for the figure examples — renders one or more
//! [`Series`](crate::metrics::Series) as a braille-free ASCII chart so
//! `cargo run --example variance_study` shows the paper's curves
//! directly in the log, next to the CSVs it writes.

use crate::metrics::Series;

/// Plot configuration.
#[derive(Debug, Clone)]
pub struct PlotCfg {
    pub width: usize,
    pub height: usize,
    /// log10-scale the y axis (variance plots span 6+ decades)
    pub log_y: bool,
    pub title: String,
}

impl Default for PlotCfg {
    fn default() -> Self {
        PlotCfg { width: 72, height: 16, log_y: false, title: String::new() }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

fn transform(y: f64, log_y: bool) -> Option<f64> {
    if !y.is_finite() {
        return None;
    }
    if log_y {
        if y <= 0.0 {
            None
        } else {
            Some(y.log10())
        }
    } else {
        Some(y)
    }
}

/// Render `series` (name, points) into an ASCII chart.
pub fn render(series: &[&Series], cfg: &PlotCfg) -> String {
    let (w, h) = (cfg.width.max(16), cfg.height.max(4));
    // data ranges
    let mut xmin = f64::INFINITY;
    let mut xmax = f64::NEG_INFINITY;
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for s in series {
        for &(x, y) in &s.points {
            let Some(ty) = transform(y, cfg.log_y) else { continue };
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(ty);
            ymax = ymax.max(ty);
        }
    }
    if !(xmin.is_finite() && ymin.is_finite()) {
        return format!("{} (no finite data)\n", cfg.title);
    }
    if (xmax - xmin).abs() < 1e-30 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-30 {
        ymax = ymin + 1.0;
    }

    let mut grid = vec![vec![' '; w]; h];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let Some(ty) = transform(y, cfg.log_y) else { continue };
            let cx = ((x - xmin) / (xmax - xmin) * (w - 1) as f64).round() as usize;
            let cy = ((ty - ymin) / (ymax - ymin) * (h - 1) as f64).round() as usize;
            let r = h - 1 - cy.min(h - 1);
            grid[r][cx.min(w - 1)] = mark;
        }
    }

    let y_label = |v: f64| -> String {
        let v = if cfg.log_y { 10f64.powf(v) } else { v };
        format!("{v:>9.2e}")
    };

    let mut out = String::new();
    if !cfg.title.is_empty() {
        out.push_str(&format!("  {}\n", cfg.title));
    }
    for (r, rowv) in grid.iter().enumerate() {
        let label = if r == 0 {
            y_label(ymax)
        } else if r == h - 1 {
            y_label(ymin)
        } else {
            " ".repeat(9)
        };
        out.push_str(&format!("{label} |"));
        out.extend(rowv.iter());
        out.push('\n');
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(9), "-".repeat(w)));
    out.push_str(&format!("{}{:<12.6}{}{:>12.6}\n", " ".repeat(11), xmin, " ".repeat(w - 22), xmax));
    // legend
    out.push_str("          ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

/// One-call helper: plot a recorder's series by name.
pub fn plot_series(
    rec: &crate::metrics::Recorder,
    names: &[&str],
    cfg: &PlotCfg,
) -> String {
    let series: Vec<&Series> = names.iter().filter_map(|n| rec.get(n)).collect();
    if series.is_empty() {
        return format!("{} (series not recorded: {names:?})\n", cfg.title);
    }
    render(&series, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(name: &str, f: impl Fn(f64) -> f64) -> Series {
        let mut s = Series::new(name);
        for i in 0..100 {
            s.push(i as f64, f(i as f64));
        }
        s
    }

    #[test]
    fn renders_linear_series() {
        let s = mk("line", |x| x * 2.0);
        let out = render(&[&s], &PlotCfg::default());
        assert!(out.contains('*'));
        assert!(out.lines().count() > 10);
        assert!(out.contains("line"));
    }

    #[test]
    fn log_scale_skips_nonpositive() {
        let mut s = mk("decay", |x| (-x / 10.0).exp());
        s.push(200.0, 0.0); // must be skipped, not crash
        let out = render(&[&s], &PlotCfg { log_y: true, ..Default::default() });
        assert!(out.contains('*'));
    }

    #[test]
    fn multiple_series_get_distinct_marks() {
        let a = mk("a", |x| x);
        let b = mk("b", |x| 100.0 - x);
        let out = render(&[&a, &b], &PlotCfg::default());
        assert!(out.contains('*') && out.contains('o'));
        assert!(out.contains("a") && out.contains("b"));
    }

    #[test]
    fn empty_series_is_graceful() {
        let s = Series::new("empty");
        let out = render(&[&s], &PlotCfg::default());
        assert!(out.contains("no finite data"));
    }

    #[test]
    fn constant_series_no_div_by_zero() {
        let s = mk("flat", |_| 5.0);
        let out = render(&[&s], &PlotCfg::default());
        assert!(out.contains('*'));
    }
}
