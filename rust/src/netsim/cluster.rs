//! Heterogeneity-aware cluster model: per-node compute skew, per-link
//! asymmetry, and deterministic fault injection.
//!
//! The homogeneous [`super::ComputeModel`] / [`super::NetModel`] pair
//! prices every node and every link identically — the paper's testbed
//! assumption.  This module removes it for the coordinator's *modeled*
//! time without ever touching parameter math: a [`ClusterModel`] is
//! built once per run from the typed `[cluster]` config table, and a
//! [`ClusterClock`] advances one modeled clock per node.  Collectives
//! are BSP — they complete when the slowest participant arrives — so
//! stragglers (static skew, seeded jitter, injected pauses) delay the
//! synchronization barrier every strategy pays for, which is exactly
//! the regime the related-work strategies (AdaComm / PR-SGD / DaSGD)
//! were designed around.
//!
//! Everything here is deterministic given the config: skew factors are
//! declared explicitly or derived from a spec string, jitter is a
//! seeded per-`(node, iteration)` stream, and the fault schedule is
//! concretized from `(seed, nodes, iters)` at build time.  Modeled
//! clocks therefore survive the dispatch layer's byte-identity
//! requirements (same digest ⇒ same report bytes) across thread
//! counts, job counts, and cache states.  Every rank replicates the
//! full n-clock vector locally — sync decisions are already replicated,
//! so the clocks need zero extra communication.

use super::NetModel;
use crate::config::{ClusterConfig, FaultConfig, NetConfig};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

// ------------------------------------------------------------------ skew

/// Per-node compute-speed skew, parsed from the `cluster.skew` spec
/// string.  Factors multiply the nominal per-step compute time, so a
/// factor of 3.0 means "this node is 3× slower".
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Skew {
    /// every node at the nominal speed
    Uniform,
    /// factors spread linearly from 1.0 (rank 0) to 1.0 + spread
    /// (last rank)
    Linear(f64),
    /// one straggler: the last rank runs `factor`× slower, the rest
    /// nominal — the classic DaSGD scenario
    Straggler(f64),
}

impl std::str::FromStr for Skew {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Skew> {
        if s == "none" {
            return Ok(Skew::Uniform);
        }
        if let Some(v) = s.strip_prefix("linear:") {
            let spread: f64 =
                v.parse().with_context(|| format!("cluster.skew: bad spread in {s:?}"))?;
            if !spread.is_finite() || spread < 0.0 {
                bail!("cluster.skew: linear spread must be >= 0, got {spread}");
            }
            return Ok(Skew::Linear(spread));
        }
        if let Some(v) = s.strip_prefix("straggler:") {
            let factor: f64 =
                v.parse().with_context(|| format!("cluster.skew: bad factor in {s:?}"))?;
            if !factor.is_finite() || factor < 1.0 {
                bail!("cluster.skew: straggler factor must be >= 1, got {factor}");
            }
            return Ok(Skew::Straggler(factor));
        }
        bail!(
            "cluster.skew: unknown spec {s:?} (expected \"none\", \"linear:<spread>\", \
             or \"straggler:<factor>\")"
        )
    }
}

impl Skew {
    /// Concrete per-node factors for an n-node cluster.
    pub fn factors(self, n: usize) -> Vec<f64> {
        match self {
            Skew::Uniform => vec![1.0; n],
            Skew::Linear(spread) => {
                if n <= 1 {
                    return vec![1.0; n];
                }
                (0..n).map(|i| 1.0 + spread * i as f64 / (n - 1) as f64).collect()
            }
            Skew::Straggler(factor) => {
                let mut v = vec![1.0; n];
                if let Some(last) = v.last_mut() {
                    *last = factor;
                }
                v
            }
        }
    }
}

// ---------------------------------------------------------------- faults

/// A concrete, fully deterministic fault schedule: which node pauses at
/// which iteration, and when network latency spikes.  Generated once
/// per run from `(fault seed, nodes, iters)`.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    /// (iteration, node) → extra pause seconds added to that step
    pauses: BTreeMap<(usize, usize), f64>,
    /// packet-delay spikes: (start iteration, length, extra latency s)
    spikes: Vec<(usize, usize, f64)>,
}

impl FaultSchedule {
    /// Concretize the declared fault *counts* into scheduled events.
    /// `seed` is the experiment seed; `faults.seed` overrides it when
    /// nonzero so fault placement can be swept independently of data.
    pub fn generate(faults: &FaultConfig, seed: u64, n: usize, iters: usize) -> FaultSchedule {
        let mut s = FaultSchedule::default();
        if n == 0 || iters == 0 {
            return s;
        }
        let seed = if faults.seed != 0 { faults.seed } else { seed ^ 0xFA17_5EED };
        // independent streams so adding spikes never moves pauses
        let mut pr = Rng::new(seed, 0xFA01);
        for _ in 0..faults.pauses {
            let k = pr.below(iters);
            let node = pr.below(n);
            *s.pauses.entry((k, node)).or_insert(0.0) += faults.pause_secs;
        }
        let mut sr = Rng::new(seed, 0xFA02);
        for _ in 0..faults.spikes {
            let k = sr.below(iters);
            s.spikes.push((k, faults.spike_len.max(1), faults.spike_secs));
        }
        s
    }

    /// Extra pause seconds node `node` suffers at iteration `k`.
    pub fn pause(&self, node: usize, k: usize) -> f64 {
        self.pauses.get(&(k, node)).copied().unwrap_or(0.0)
    }

    /// Extra per-message latency from spikes active at iteration `k`.
    pub fn spike_alpha(&self, k: usize) -> f64 {
        self.spikes
            .iter()
            .filter(|(start, len, _)| k >= *start && k < start + len)
            .map(|(_, _, secs)| *secs)
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.pauses.is_empty() && self.spikes.is_empty()
    }

    pub fn pause_events(&self) -> usize {
        self.pauses.len()
    }

    pub fn spike_events(&self) -> usize {
        self.spikes.len()
    }
}

// ----------------------------------------------------------------- model

/// The full heterogeneous cluster: per-node compute factors, per-node
/// uplink models, seeded step jitter, and the fault schedule.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    pub n: usize,
    /// per-node compute multipliers (1.0 = nominal)
    pub factors: Vec<f64>,
    /// nominal modeled per-step compute seconds
    pub step_secs: f64,
    /// per-step jitter as a fraction of the node's own step time
    pub jitter: f64,
    /// per-node uplink models; a collective is bottlenecked by the
    /// slowest of them
    pub links: Vec<NetModel>,
    pub faults: FaultSchedule,
    seed: u64,
}

impl ClusterModel {
    /// Build from the typed config.  `iters` bounds the fault schedule;
    /// `seed` is the experiment seed (fault placement derives from it
    /// unless `cluster.faults.seed` overrides).
    pub fn from_config(
        cl: &ClusterConfig,
        net: &NetConfig,
        n: usize,
        iters: usize,
        seed: u64,
    ) -> Result<ClusterModel> {
        let factors = if !cl.factors.is_empty() {
            if cl.factors.len() != n {
                bail!("cluster.factors has {} entries for {n} nodes", cl.factors.len());
            }
            cl.factors.clone()
        } else {
            cl.skew.parse::<Skew>()?.factors(n)
        };
        if let Some(f) = factors.iter().find(|f| !f.is_finite() || **f <= 0.0) {
            bail!("cluster.factors: factor {f} must be a positive finite number");
        }
        for (name, arr) in
            [("cluster.link_bw_gbps", &cl.link_bw_gbps), ("cluster.link_latency_us", &cl.link_latency_us)]
        {
            if !arr.is_empty() && arr.len() != n {
                bail!("{name} has {} entries for {n} nodes", arr.len());
            }
            if let Some(v) = arr.iter().find(|v| !v.is_finite() || **v < 0.0) {
                bail!("{name}: {v} must be a non-negative finite number");
            }
        }
        let base = NetModel::new(net);
        let links = (0..n)
            .map(|i| NetModel {
                bw: cl.link_bw_gbps.get(i).map(|g| g * 1e9 / 8.0).unwrap_or(base.bw),
                alpha: cl.link_latency_us.get(i).map(|us| us * 1e-6).unwrap_or(base.alpha),
            })
            .collect();
        Ok(ClusterModel {
            n,
            factors,
            step_secs: cl.step_us * 1e-6,
            jitter: cl.jitter,
            links,
            faults: FaultSchedule::generate(&cl.faults, seed, n, iters),
            seed,
        })
    }

    /// A uniform cluster over `net` with the default `[cluster]` table —
    /// what every run before the cluster model behaved like.
    pub fn uniform(net: &NetConfig, n: usize) -> ClusterModel {
        Self::from_config(&ClusterConfig::default(), net, n, 0, 0)
            .expect("default cluster config is valid")
    }

    /// Modeled compute seconds node `node` spends on iteration `k`:
    /// nominal step × skew factor, ± seeded jitter, + injected pauses.
    pub fn step_secs_at(&self, node: usize, k: usize) -> f64 {
        let base = self.step_secs * self.factors[node];
        let jit = if self.jitter > 0.0 {
            let u = Rng::new(self.seed ^ 0xC10C_0000, ((node as u64) << 40) ^ k as u64).f64();
            base * self.jitter * (2.0 * u - 1.0)
        } else {
            0.0
        };
        (base + jit).max(0.0) + self.faults.pause(node, k)
    }

    /// Effective network model for a collective launched at iteration
    /// `k`: bottlenecked by the slowest link, plus any active
    /// packet-delay spike.
    pub fn net_at(&self, k: usize) -> NetModel {
        let mut bw = f64::INFINITY;
        let mut alpha = 0.0f64;
        for l in &self.links {
            bw = bw.min(l.bw);
            alpha = alpha.max(l.alpha);
        }
        if !bw.is_finite() {
            bw = 1.0; // n = 0 never reaches a collective; keep the model sane
        }
        NetModel { bw, alpha: alpha + self.faults.spike_alpha(k) }
    }
}

// ----------------------------------------------------------------- clock

/// Per-node modeled clocks, advanced in lockstep with the training
/// loop.  Replicated on every rank (the inputs are config-deterministic
/// and sync decisions are identical on all ranks), so the coordinator
/// reads rank 0's copy for the run report.
#[derive(Debug, Clone)]
pub struct ClusterClock {
    model: ClusterModel,
    t: Vec<f64>,
    /// per-node barrier-wait seconds accumulated since the last
    /// [`ClusterClock::sync_lap`] — how long each node idled for the
    /// slowest arrival (plus DaSGD wire waits)
    lap_waits: Vec<f64>,
    /// modeled communication seconds accumulated since the last lap
    lap_comm: f64,
}

impl ClusterClock {
    pub fn new(model: ClusterModel) -> ClusterClock {
        let t = vec![0.0; model.n];
        let lap_waits = vec![0.0; model.n];
        ClusterClock { model, t, lap_waits, lap_comm: 0.0 }
    }

    pub fn model(&self) -> &ClusterModel {
        &self.model
    }

    /// The network a collective launched at iteration `k` sees.
    pub fn net_at(&self, k: usize) -> NetModel {
        self.model.net_at(k)
    }

    /// Advance every node's clock by its modeled compute for
    /// iteration `k`.
    pub fn step(&mut self, k: usize) {
        for (i, t) in self.t.iter_mut().enumerate() {
            *t += self.model.step_secs_at(i, k);
        }
    }

    /// BSP barrier + blocking collective: everyone leaves at the
    /// slowest arrival plus the modeled communication time.
    pub fn barrier(&mut self, comm_secs: f64) {
        let m0 = self.max();
        for i in 0..self.t.len() {
            self.lap_waits[i] += m0 - self.t[i];
            self.t[i] = m0 + comm_secs;
        }
        self.lap_comm += comm_secs;
    }

    /// Deferred completion (DaSGD): a collective launched at modeled
    /// time `floor - comm_secs` finishes at `floor`; nodes that are
    /// still computing hide it entirely, nodes that got ahead wait.
    /// No inter-node barrier — each node only syncs with the wire, so
    /// the lap accounting books a node's wire wait as wait time, not
    /// communication (DaSGD's whole point is that overlap hides it).
    pub fn wait_until(&mut self, floor: f64) {
        for i in 0..self.t.len() {
            self.lap_waits[i] += (floor - self.t[i]).max(0.0);
            if self.t[i] < floor {
                self.t[i] = floor;
            }
        }
    }

    /// Drain the wait/comm accounting accumulated since the previous
    /// lap: copies per-node barrier-wait seconds into `waits` (resized
    /// to `n`) and returns the modeled communication seconds, then
    /// resets both.  The coordinator laps the clock once per completed
    /// sync, which is what gives [`crate::coordinator::observer::
    /// RunEvent::SyncDone`] its per-node attribution.
    pub fn sync_lap(&mut self, waits: &mut Vec<f64>) -> f64 {
        waits.clear();
        waits.extend_from_slice(&self.lap_waits);
        for w in &mut self.lap_waits {
            *w = 0.0;
        }
        std::mem::replace(&mut self.lap_comm, 0.0)
    }

    /// Every node's modeled clock (rank order).
    pub fn nodes(&self) -> &[f64] {
        &self.t
    }

    /// Modeled time of node `i`.
    pub fn node(&self, i: usize) -> f64 {
        self.t[i]
    }

    /// Modeled wall-clock so far: the slowest node's clock.
    pub fn max(&self) -> f64 {
        self.t.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cl() -> ClusterConfig {
        ClusterConfig::default()
    }

    fn net() -> NetConfig {
        NetConfig::infiniband_100g()
    }

    #[test]
    fn skew_spec_parses() {
        assert_eq!("none".parse::<Skew>().unwrap(), Skew::Uniform);
        assert_eq!("linear:0.5".parse::<Skew>().unwrap(), Skew::Linear(0.5));
        assert_eq!("straggler:4".parse::<Skew>().unwrap(), Skew::Straggler(4.0));
        for bad in ["", "nope", "linear:", "linear:-1", "straggler:0.5", "straggler:x"] {
            let err = bad.parse::<Skew>().unwrap_err().to_string();
            assert!(err.contains("cluster.skew"), "{bad:?}: {err}");
        }
        // the unknown-name error teaches the valid grammar
        let err = "zipf:2".parse::<Skew>().unwrap_err().to_string();
        assert!(err.contains("linear:") && err.contains("straggler:"), "{err}");
    }

    #[test]
    fn skew_factor_shapes() {
        assert_eq!(Skew::Uniform.factors(4), vec![1.0; 4]);
        let lin = Skew::Linear(1.0).factors(5);
        assert_eq!(lin[0], 1.0);
        assert_eq!(lin[4], 2.0);
        assert!(lin.windows(2).all(|w| w[1] > w[0]), "{lin:?}");
        let st = Skew::Straggler(3.0).factors(4);
        assert_eq!(st, vec![1.0, 1.0, 1.0, 3.0]);
        // degenerate sizes never panic
        assert_eq!(Skew::Linear(2.0).factors(1), vec![1.0]);
        assert!(Skew::Straggler(2.0).factors(0).is_empty());
    }

    #[test]
    fn fault_schedule_is_deterministic_and_counted() {
        let f = FaultConfig {
            seed: 0,
            pauses: 5,
            pause_secs: 0.5,
            spikes: 3,
            spike_secs: 1e-3,
            spike_len: 4,
        };
        let a = FaultSchedule::generate(&f, 42, 8, 400);
        let b = FaultSchedule::generate(&f, 42, 8, 400);
        assert_eq!(a.pauses, b.pauses);
        assert_eq!(a.spikes, b.spikes);
        assert_eq!(a.spike_events(), 3);
        assert!(a.pause_events() >= 4, "collisions may merge, most survive");
        // a different seed moves the schedule
        let c = FaultSchedule::generate(&f, 43, 8, 400);
        assert_ne!(a.pauses, c.pauses);
        // explicit fault seed wins over the experiment seed
        let f2 = FaultConfig { seed: 99, ..f };
        let d1 = FaultSchedule::generate(&f2, 42, 8, 400);
        let d2 = FaultSchedule::generate(&f2, 1234, 8, 400);
        assert_eq!(d1.pauses, d2.pauses);
        // zero counts → empty schedule
        assert!(FaultSchedule::generate(&FaultConfig::default(), 42, 8, 400).is_empty());
    }

    #[test]
    fn spike_alpha_active_only_in_window() {
        let f = FaultConfig {
            pauses: 0,
            spikes: 1,
            spike_secs: 2e-3,
            spike_len: 5,
            ..FaultConfig::default()
        };
        let s = FaultSchedule::generate(&f, 7, 4, 100);
        let start = (0..100).find(|&k| s.spike_alpha(k) > 0.0).unwrap();
        for k in start..start + 5 {
            assert_eq!(s.spike_alpha(k), 2e-3);
        }
        assert_eq!(s.spike_alpha(start + 5), 0.0);
    }

    #[test]
    fn model_rejects_bad_shapes() {
        let mut c = cl();
        c.factors = vec![1.0, 2.0];
        assert!(ClusterModel::from_config(&c, &net(), 4, 100, 1).is_err());
        let mut c = cl();
        c.link_bw_gbps = vec![100.0; 3];
        assert!(ClusterModel::from_config(&c, &net(), 4, 100, 1).is_err());
        let mut c = cl();
        c.factors = vec![1.0, 0.0, 1.0, 1.0];
        assert!(ClusterModel::from_config(&c, &net(), 4, 100, 1).is_err());
        let mut c = cl();
        c.skew = "bogus".into();
        assert!(ClusterModel::from_config(&c, &net(), 4, 100, 1).is_err());
    }

    #[test]
    fn explicit_factors_win_over_skew() {
        let mut c = cl();
        c.skew = "straggler:8".into();
        c.factors = vec![1.0, 2.0, 3.0, 4.0];
        let m = ClusterModel::from_config(&c, &net(), 4, 100, 1).unwrap();
        assert_eq!(m.factors, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn link_overrides_bottleneck_the_collective() {
        let mut c = cl();
        c.link_bw_gbps = vec![100.0, 100.0, 10.0, 100.0];
        c.link_latency_us = vec![2.0, 2.0, 50.0, 2.0];
        let m = ClusterModel::from_config(&c, &net(), 4, 100, 1).unwrap();
        let eff = m.net_at(0);
        assert_eq!(eff.bw, 10.0 * 1e9 / 8.0);
        assert_eq!(eff.alpha, 50.0 * 1e-6);
        // uniform links reproduce the base NetModel exactly
        let u = ClusterModel::uniform(&net(), 4).net_at(0);
        assert_eq!(u, NetModel::new(&net()));
    }

    #[test]
    fn straggler_delays_the_barrier() {
        let mut c = cl();
        c.skew = "straggler:4".into();
        c.step_us = 1000.0;
        let m = ClusterModel::from_config(&c, &net(), 4, 100, 1).unwrap();
        let mut skewed = ClusterClock::new(m);
        let mut uniform = ClusterClock::new(ClusterModel::uniform(&net(), 4));
        for k in 0..10 {
            skewed.step(k);
            uniform.step(k);
        }
        // straggler: 10 steps at 4x nominal = 40ms vs 10ms
        assert!((skewed.max() - 40e-3).abs() < 1e-12, "{}", skewed.max());
        assert!((uniform.max() - 10e-3).abs() < 1e-12, "{}", uniform.max());
        // the barrier drags every node to the straggler's clock
        skewed.barrier(1e-3);
        for i in 0..4 {
            assert_eq!(skewed.node(i), 41e-3);
        }
    }

    #[test]
    fn jitter_is_bounded_and_seeded() {
        let mut c = cl();
        c.jitter = 0.3;
        c.step_us = 1000.0;
        let m = ClusterModel::from_config(&c, &net(), 4, 100, 9).unwrap();
        for k in 0..50 {
            for i in 0..4 {
                let s = m.step_secs_at(i, k);
                assert!((0.7e-3..=1.3e-3).contains(&s), "step {s}");
                assert_eq!(s, m.step_secs_at(i, k), "same (node, k) must replay");
            }
        }
        // jitter varies across iterations (not a constant offset)
        let s0 = m.step_secs_at(0, 0);
        assert!((0..50).any(|k| m.step_secs_at(0, k) != s0));
    }

    #[test]
    fn wait_until_only_lifts_laggards() {
        let mut clock = ClusterClock::new(ClusterModel::uniform(&net(), 3));
        clock.step(0);
        let before = clock.node(0);
        clock.wait_until(before - 1e-6);
        assert_eq!(clock.node(0), before, "already past the floor");
        clock.wait_until(before + 5e-3);
        for i in 0..3 {
            assert_eq!(clock.node(i), before + 5e-3);
        }
    }

    #[test]
    fn sync_lap_attributes_waits_and_comm() {
        let mut c = cl();
        c.skew = "straggler:4".into();
        c.step_us = 1000.0;
        let m = ClusterModel::from_config(&c, &net(), 4, 100, 1).unwrap();
        let mut clock = ClusterClock::new(m);
        clock.step(0); // fast nodes at 1ms, straggler at 4ms
        clock.barrier(1e-3);
        let mut waits = Vec::new();
        let comm = clock.sync_lap(&mut waits);
        assert_eq!(comm, 1e-3);
        assert_eq!(waits.len(), 4);
        assert!((waits[0] - 3e-3).abs() < 1e-12, "{waits:?}");
        assert_eq!(waits[3], 0.0, "the straggler never waits");
        // the lap drains: a second lap with no sync reports zeros
        assert_eq!(clock.sync_lap(&mut waits), 0.0);
        assert!(waits.iter().all(|w| *w == 0.0), "{waits:?}");
        // nodes() exposes the flattened post-barrier clocks
        assert!(clock.nodes().iter().all(|t| (*t - 5e-3).abs() < 1e-12));
        // deferred completion books wire waits, never comm
        clock.wait_until(6e-3);
        assert_eq!(clock.sync_lap(&mut waits), 0.0);
        assert!(waits.iter().all(|w| (*w - 1e-3).abs() < 1e-12), "{waits:?}");
    }

    #[test]
    fn pauses_hit_exactly_one_node_step() {
        let f = FaultConfig {
            pauses: 1,
            pause_secs: 2.0,
            ..FaultConfig::default()
        };
        let s = FaultSchedule::generate(&f, 5, 4, 50);
        let hit: Vec<(usize, usize)> = (0..50)
            .flat_map(|k| (0..4).map(move |i| (k, i)))
            .filter(|&(k, i)| s.pause(i, k) > 0.0)
            .collect();
        assert_eq!(hit.len(), 1);
        assert_eq!(s.pause(hit[0].1, hit[0].0), 2.0);
    }
}
